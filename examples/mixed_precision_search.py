"""Paper Fig. 12: mixed-precision (q, g) search over sublayer types.

Trains a small LM, then explores per-sublayer (attention vs FFN vs LM head)
BCQ configs and prints the (compression, PPL) Pareto frontier.

PYTHONPATH=src python examples/mixed_precision_search.py
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import MarkovCorpus, batch_iterator
from repro.models import forward, init_params, reduced
from repro.quant import QuantPolicy, quantize_params, quantized_bytes
from repro.train import adamw_init, cross_entropy, make_train_step


def main():
    cfg = reduced(
        get_config("llama3.2-3b"), d_model=192, n_layers=3, n_kv_heads=4,
        d_ff=512, vocab=512,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=2e-3))
    corpus = MarkovCorpus(cfg.vocab, seed=5)
    it = batch_iterator(corpus, batch=16, seq_len=64)
    for _ in range(100):
        b = next(it)
        params, opt, _ = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})

    eval_fn = jax.jit(lambda p, t, l: cross_entropy(forward(cfg, p, tokens=t)[0], l))
    ev = batch_iterator(corpus, batch=16, seq_len=64, seed=777)
    def ppl(p):
        nll = [float(eval_fn(p, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"])))
               for b in (next(ev) for _ in range(3))]
        return float(np.exp(np.mean(nll)))

    base_ppl, base_bytes = ppl(params), quantized_bytes(params)
    print(f"dense: ppl={base_ppl:.3f}")
    results = []
    grid = [(3, 64), (4, 64), (4, 128), (5, 128)]
    for attn_cfg, ffn_cfg in itertools.product(grid, grid):
        pol = QuantPolicy(attn=attn_cfg, ffn=ffn_cfg, iters=5)
        qp = quantize_params(params, pol)
        r = base_bytes / quantized_bytes(qp)
        d = ppl(qp) - base_ppl
        results.append((r, d, attn_cfg, ffn_cfg))
        print(f"attn(q,g)={attn_cfg} ffn(q,g)={ffn_cfg}: comp={r:.2f}x ppl_deg={d:+.3f}")

    print("\nPareto frontier (max compression at each PPL budget):")
    results.sort(key=lambda t: (-t[0], t[1]))
    best = np.inf
    for r, d, a, f in results:
        if d < best:
            best = d
            print(f"  comp={r:.2f}x ppl_deg={d:+.3f} attn={a} ffn={f}")


if __name__ == "__main__":
    main()
