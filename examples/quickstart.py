"""Quickstart: group-wise BCQ quantization + LUT-GEMM in ~30 lines.

PYTHONPATH=src python examples/quickstart.py

This exercises the single-matmul building block. End-to-end generation goes
through ``repro.infer.Engine``, whose decode runs as one on-device
``lax.scan`` by default (``generate(..., scan=True)``; pass ``scan=False``
for the per-token step loop) with QKV/gate-up projections fused into single
kernel passes — see DESIGN.md §2.3/§3.

Serving many concurrent requests goes through the continuous-batching
scheduler (DESIGN.md §4) instead of one-shot ``generate``:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \\
        --q 4 --g 128 --requests 12 --slots 4 --rate 8 --speculate 2:4

Requests are continuously batched into a ``--slots``-wide decode batch with
per-request temperature/seed/budget; ``--sequential`` serves the same
workload with one-shot ``generate`` calls for comparison (BENCH_serve.json),
and ``--rate`` simulates Poisson arrivals. ``--speculate q':γ`` decodes
self-speculatively from the nested q'-bit draft (DESIGN.md §5), reporting
the draft acceptance rate alongside tok/s. Programmatic use::

    from repro.infer import Engine, Request, Scheduler, SpecConfig
    eng = Engine(cfg, params, max_seq=64)
    res = eng.generate(prompt[None], 16, speculate=SpecConfig(q_draft=2, gamma=4))
    print(res.spec_stats["accept_rate"])       # greedy output == plain greedy
    sched = Scheduler(eng, n_slots=4, speculate=SpecConfig(2, 4))
    sched.submit(Request(prompt, max_new_tokens=16, temperature=0.7))
    completions = sched.run()   # greedy rows token-identical to solo generate()

Tensor-parallel serving (DESIGN.md §7) shards the same engine over an N-way
``model`` mesh — weights column/row-parallel, KV caches kv-head-sharded,
greedy tokens bit-identical to the single-device engine::

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \\
        --q 4 --g 64 --requests 12 --slots 4 --tp 4

    from repro.parallel.tp import make_tp_mesh
    eng = Engine(cfg, params, max_seq=64, mesh=make_tp_mesh(4))
    # generate/Scheduler/speculate all work unchanged on the sharded engine

(group size caveat: row-parallel weights need ``(k/g) % tp == 0`` so scale
groups shard with their k-rows — the engine raises naming the leaf if not;
``examples/serve_quantized.py --tp N`` demos the same end-to-end.)

Real clients stream over the async front end (DESIGN.md §9): a WebSocket
server with per-request state machines, cancellation (disconnect = cancel),
TTFT/total deadlines, bounded-queue backpressure and TTFT/TPOT percentile
metrics — the scheduler's hardening guarantees that whatever happens to one
request (cancel, timeout, injected fault, NaN row), every *surviving*
request's tokens stay bit-identical to an undisturbed run::

    PYTHONPATH=src python -m repro.launch.server --arch llama3.2-3b \\
        --q 4 --g 128 --slots 4 --port 8777
    # ws://127.0.0.1:8777/v1/stream — send one JSON request per socket,
    # receive streamed token frames; GET /v1/metrics for percentiles

    import asyncio
    from repro.launch.server import ServeSession     # no aiohttp needed
    async def demo():
        async with ServeSession(eng, n_slots=4) as sess:
            stream = await sess.submit_stream(Request(prompt, max_new_tokens=32))
            async for ev in stream:                  # accepted/tokens/done
                if ev.kind == "tokens" and boring(ev.tokens):
                    stream.cancel("lost interest")   # slot reclaimed next chunk
    asyncio.run(demo())

Serving traffic that repeats a system prompt gets a prefix cache
(DESIGN.md §12): a radix trie over committed token prefixes shares
device-resident KV blocks across requests, so a warm hit prefills only the
uncached tail — in bucket-padded chunks, so long prompts neither retrace XLA
per length nor block short neighbours' decode — while served tokens stay
bit-identical to cold solo ``generate`` across formats, speculation and TP::

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \\
        --q 4 --g 128 --requests 12 --slots 4 \\
        --prefix-cache-mb 64 --prefix-block 8 --shared-prefix-len 24

    from repro.infer import PrefixCache
    eng = Engine(cfg, params, max_seq=64,
                 prefix_cache=PrefixCache(block_tokens=8))
    sched = Scheduler(eng, n_slots=4, prefill_chunk=8)  # chunked prefill
    ...
    print(eng.prefix_cache.stats())   # hits/misses/evictions, cached bytes

The WebSocket server takes ``--prefix-cache-mb``/``--prefill-chunk`` too and
also speaks SSE: ``POST /v1/generate`` streams the same accepted/tokens/done
frames as ``data:`` events for plain-HTTP clients (curl works; disconnect
cancels the request, exactly like a dropped socket).

Everything above is observable (DESIGN.md §11): attach `repro.obs`'s span
tracer + metrics registry to any scheduler and serving stays bit-identical
while every request lifecycle, decode chunk and kernel dispatch is recorded
— `--trace-out t.json` on the serve CLI dumps a Chrome/Perfetto trace,
`--profile-dir d/` wraps the run in a ``jax.profiler.trace`` capture, the
WebSocket server exposes ``/v1/metrics?format=prometheus`` and
``/v1/trace``, and ``python -m repro.obs.trace --out t.json`` captures a
self-contained fault-injected demo serve::

    from repro.obs import MetricsRegistry, Tracer
    tracer, registry = Tracer(), MetricsRegistry()
    sched = Scheduler(eng, n_slots=4, tracer=tracer, metrics=registry)
    ...
    json.dump(tracer.to_chrome(), open("t.json", "w"))  # ui.perfetto.dev
    print(registry.snapshot()["serve_ttft_seconds"])    # p50/p95/p99
"""

import jax.numpy as jnp
import numpy as np

from repro.core import compression_ratio, format_names, get_format, quantize_tensor
from repro.kernels import qmatmul, quantized_matmul

rng = np.random.default_rng(0)

# a weight matrix and a single-token activation (the paper's generation stage)
w = jnp.asarray(rng.standard_normal((4096, 1024)), jnp.float32)
x = jnp.asarray(rng.standard_normal((1, 4096)), jnp.float32)

# quantize: q=4 bits, scale shared by groups of g=128 weights (paper §III.A)
qt = quantize_tensor(w, q=4, g=128, iters=8)
dense_bytes = w.size * 2  # bf16 baseline
print(f"packed {dense_bytes/2**20:.1f} MiB (bf16) -> {qt.nbytes()/2**20:.1f} MiB "
      f"(~{compression_ratio(4, 128):.1f}x, paper Eq. 3)")

# the memory-bound matvec runs straight off the packed format
y_dense = x @ w
for impl in ("ref", "bcq_mm", "lutgemm"):  # oracle, TPU-native, paper-faithful
    y = quantized_matmul(x, qt, impl=impl, interpret=True)
    rel = float(jnp.linalg.norm(y - y_dense) / jnp.linalg.norm(y_dense))
    print(f"{impl:8s}: rel error vs dense = {rel:.4f}")

# BCQ is nested (paper §III.A): the first q' planes ARE the q'-bit model —
# every quantized model carries its own cheaper draft for speculative decoding
for q_draft in (1, 2, 3):
    qd = qt.truncate(q_draft)
    rel = float(jnp.linalg.norm(qd.dequantize() - w) / jnp.linalg.norm(w))
    print(f"nested q'={q_draft}: {qd.nbytes()/2**20:.1f} MiB, "
          f"weight rel error = {rel:.4f} (monotone in q')")

# the format registry (DESIGN.md §2.4): the same qmatmul dispatch serves BCQ,
# FineQuant-style group-wise uniform int-q, the paper's dequantize-then-matmul
# baseline, FLUTE-style arbitrary-codebook (k-means centroids; method="nf4"
# for the fixed QLoRA grid), and T-MAC-style ternary (2 bits + one alpha per
# group; truncation-capable like bcq) — `python -m repro.launch.serve
# --format NAME` runs each end-to-end (choices track the registry);
# benchmarks/kernel_bench.py records the comparison rows
print(f"\nregistered formats: {format_names()}")
for fmt in format_names():
    qf = quantize_tensor(w, q=4, g=128, iters=4, fmt=fmt)
    (y,) = qmatmul(fmt, x, qf, impl="ref")
    rel = float(jnp.linalg.norm(y - y_dense) / jnp.linalg.norm(y_dense))
    kernels = ", ".join(get_format(fmt).impls)
    print(f"{fmt:8s}: {qf.nbytes()/2**20:.1f} MiB, rel error = {rel:.4f}, "
          f"kernels = [{kernels}]")
