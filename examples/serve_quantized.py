"""End-to-end serving driver (the paper's kind: inference) — batched requests
through the prefill/decode split engine with packed BCQ weights (Fig. 13),
plus the other registered quantization formats (DESIGN.md §2.4: FineQuant-
style ``uniform`` int-q, the ``dequant`` dequantize-then-matmul baseline
asserted bit-identical to ``uniform`` since they share one packing, the
FLUTE-style ``codebook`` with per-group k-means centroids, and T-MAC-style
``ternary`` — which, being masked BCQ, also self-speculates),
then the same requests again with self-speculative decoding (DESIGN.md §5):
the nested low-bit planes of the SAME packed weights draft tokens that the
full-precision model verifies, with the acceptance rate printed next to the
tok/s it buys.

``--tp N`` reruns the quantized engine tensor-parallel (DESIGN.md §7): the
same packed weights are sharded column/row-parallel over an N-way model mesh
under shard_map, the greedy output is asserted token-identical to the
single-device engine, and both tok/s are printed. (Group size drops to 48 so
the row-parallel wo's scale groups shard: (k/g) % tp must be 0.)

PYTHONPATH=src python examples/serve_quantized.py [--batch 8] [--gen 32] [--tp 2]
"""

import argparse
import time

from repro.launch._hostdev import force_host_devices_for_tp

if __name__ == "__main__":
    # script only: before the first jax import (--tp N placeholder devices);
    # importing this module must not sniff the host program's argv
    force_host_devices_for_tp()

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import MarkovCorpus, batch_iterator
from repro.infer import Engine, SpecConfig
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params, quantized_bytes
from repro.train import adamw_init, make_train_step


def streaming_demo(engine, prompts, gen):
    """Async streaming serve (DESIGN.md §9): the same quantized engine behind
    the asyncio session — tokens stream per chunk, one request is cancelled
    mid-flight (the slot frees at the next chunk boundary), the survivor is
    asserted token-identical to solo ``generate``, and the session reports
    TTFT/TPOT percentiles. ``python -m repro.launch.server`` serves the same
    thing over WebSockets."""
    import asyncio

    from repro.infer import Request
    from repro.launch.server import ServeSession

    solo = engine.generate(prompts[:1], gen)

    async def demo():
        async with ServeSession(engine, n_slots=2, chunk=4) as sess:
            keep = await sess.submit_stream(
                Request(prompt=prompts[0], max_new_tokens=gen)
            )
            victim = await sess.submit_stream(
                Request(prompt=prompts[1], max_new_tokens=gen,
                        temperature=0.8, seed=7)
            )
            async for ev in victim:  # cancel right after its first chunk
                if ev.kind == "tokens":
                    victim.cancel("demo: client hit stop")
                    break
            _, vlast = await victim.drain()
            toks, _ = await keep.drain()
            return toks, vlast, sess.metrics(), sess.trace_json()

    toks, vlast, m, trace = asyncio.run(demo())
    assert np.array_equal(
        np.asarray(toks), solo.tokens[0, prompts.shape[1]:]
    ), "survivor of a mid-flight cancel must stay token-identical to solo"
    ttft = m["ttft_s"]
    print(
        f"streaming   : survivor streamed {len(toks)} tokens "
        f"(token-identical to solo) while neighbour was {vlast.status} "
        f"mid-flight ({vlast.reason!r}); ttft p50/p95 = "
        f"{ttft['p50'] * 1e3:.0f}/{ttft['p95'] * 1e3:.0f} ms"
    )

    # the same run left a full span trace behind (DESIGN.md §11): sessions
    # observe by default, so the lifecycle of BOTH requests — including the
    # mid-flight cancel — is already recorded. Dump it, check it is a valid
    # Chrome trace, and read the story back out of the request lanes.
    import json
    import os
    import tempfile

    from repro.obs import validate_chrome_trace

    validate_chrome_trace(trace)
    path = os.path.join(tempfile.gettempdir(), "serve_quantized_trace.json")
    with open(path, "w") as f:
        json.dump(trace, f)
    lanes = {}
    for ev in trace["traceEvents"]:
        if ev["ph"] in ("X", "i"):
            lanes.setdefault(ev["tid"], []).append(ev["name"])
    req_lanes = {
        tid: names for tid, names in lanes.items()
        if any(n in ("finished", "cancelled") for n in names)
    }
    terminals = sorted(
        names[-1] for names in req_lanes.values()
    )  # each request lane ends in exactly one terminal instant
    assert terminals == ["cancelled", "finished"], terminals
    print(
        f"trace       : {len(trace['traceEvents'])} events across "
        f"{len(lanes)} lanes -> {path} (open in ui.perfetto.dev); "
        f"request lanes end in {terminals}; metrics snapshot has "
        f"{len(m['registry'])} series families"
    )


def prefix_cache_demo(cfg, qp, prompts, gen):
    """Shared-system-prompt serving (DESIGN.md §12): every request repeats the
    same 24-token system prompt with a different user tail. A radix-trie
    prefix cache over committed KV blocks lets the second wave skip the
    shared prefill — only the 8-token tail is prefilled (in bucket-padded
    chunks, so no per-length retraces) — and the served tokens stay
    bit-identical to cold solo ``generate``. ``python -m repro.launch.serve
    --prefix-cache-mb 64 --prefix-block 8 --shared-prefix-len 24`` serves the
    same shape of workload from the CLI; the WebSocket/SSE server takes
    ``--prefix-cache-mb`` too."""
    from repro.infer import PrefixCache, Request, Scheduler

    system = prompts[0, :24]
    users = [
        np.concatenate([system, prompts[1 + i, :8]]).astype(np.int32)
        for i in range(6)
    ]
    solo = Engine(cfg, qp, max_seq=40 + gen).generate(np.stack(users), gen)

    eng = Engine(cfg, qp, max_seq=40 + gen,
                 prefix_cache=PrefixCache(block_tokens=8))
    for wave in ("populate", "warm"):
        sched = Scheduler(eng, n_slots=3, chunk=4, prefill_chunk=8)
        for u in users:
            sched.submit(Request(prompt=u, max_new_tokens=gen))
        done = {c.rid: c for c in sched.run()}
        for rid, c in done.items():
            assert np.array_equal(c.tokens, solo.tokens[rid]), (
                "warm-cache serving must stay bit-identical to solo generate"
            )
    st = eng.prefix_cache.stats()
    assert st["hits"] >= len(users), "second wave must hit the shared prefix"
    print(
        f"prefix cache: {len(users)} requests x2 waves sharing a "
        f"{system.size}-token system prompt — {st['hits']} hits / "
        f"{st['misses']} misses, {st['cached_bytes'] / 2**20:.2f} MiB in "
        f"{st['nodes']} blocks; warm wave bit-identical to solo generate"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--tp", type=int, default=1,
                    help="also serve the quantized model tensor-parallel over "
                         "an N-way model mesh (greedy output asserted "
                         "identical to single-device)")
    args = ap.parse_args()

    # a briefly-trained model so generations aren't pure noise
    cfg = reduced(
        get_config("llama3.2-3b"), d_model=192, n_layers=3, n_kv_heads=4,
        d_ff=512, vocab=512,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=2e-3))
    corpus = MarkovCorpus(cfg.vocab, seed=5)
    it = batch_iterator(corpus, batch=16, seq_len=64)
    for _ in range(args.train_steps):
        b = next(it)
        params, opt, _ = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})

    print(f"dense bytes: {quantized_bytes(params)/2**20:.2f} MiB")
    qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=6))
    print(f"BCQ q=4 g=64 bytes: {quantized_bytes(qp)/2**20:.2f} MiB")

    prompts = corpus.sample(args.batch, args.prompt_len, seed=99)[:, : args.prompt_len]
    prompts = prompts.astype(np.int32)

    # format registry (DESIGN.md §2.4): the same engine serves BCQ, uniform
    # int-q, the paper's dequantize-then-matmul baseline, the FLUTE-style
    # arbitrary codebook (per-group k-means centroids; method="nf4" would pin
    # the fixed QLoRA grid), and T-MAC-style ternary — only the QuantPolicy's
    # fmt changes. uniform/dequant share one packing, so their greedy outputs
    # are asserted bit-identical (kernel pipeline isolated).
    qp_uni = quantize_params(params, QuantPolicy(q=4, g=64, fmt="uniform"))
    qp_deq = quantize_params(params, QuantPolicy(q=4, g=64, fmt="dequant"))
    qp_cbk = quantize_params(params, QuantPolicy(q=4, g=64, iters=4, fmt="codebook"))
    qp_ter = quantize_params(params, QuantPolicy(q=4, g=64, fmt="ternary"))
    print(f"uniform q=4 g=64 bytes: {quantized_bytes(qp_uni)/2**20:.2f} MiB")
    print(f"codebook q=4 g=64 bytes: {quantized_bytes(qp_cbk)/2**20:.2f} MiB")
    print(f"ternary g=64 bytes: {quantized_bytes(qp_ter)/2**20:.2f} MiB "
          "(2 planes + one alpha/group, whatever the policy's q)")

    toks = args.batch * args.gen
    fmt_tokens = {}
    for tag, p in (
        ("dense", params), ("bcq-q4", qp), ("uniform-q4", qp_uni),
        ("dequant-q4", qp_deq), ("codebook-q4", qp_cbk), ("ternary", qp_ter),
    ):
        eng = Engine(cfg, p, max_seq=args.prompt_len + args.gen + 8)
        t0 = time.perf_counter()
        res = eng.generate(prompts, args.gen)
        dt = time.perf_counter() - t0
        fmt_tokens[tag] = res.tokens
        print(
            f"{tag:12s}: {toks} tokens in {dt:.2f}s "
            f"({toks/dt:.1f} tok/s CPU) sample={res.tokens[0, args.prompt_len:args.prompt_len+10]}"
        )
    assert np.array_equal(fmt_tokens["uniform-q4"], fmt_tokens["dequant-q4"]), (
        "uniform and dequant share one packing — greedy output must match"
    )

    # ternary is the second truncation-capable format: its masked-BCQ identity
    # hands self-speculation a 1-plane nested draft. Greedy output stays
    # token-identical to the plain ternary engine.
    eng_ter = Engine(cfg, qp_ter, max_seq=args.prompt_len + args.gen + 16)
    res_ter = eng_ter.generate(prompts, args.gen, speculate=SpecConfig(1, 3))
    assert np.array_equal(res_ter.tokens, fmt_tokens["ternary"]), (
        "ternary self-speculation must be exact"
    )
    st_ter = res_ter.spec_stats
    print(f"ternary+spec: draft q'={st_ter['q_draft']} acceptance "
          f"{st_ter['accept_rate']:.0%} — token-identical to plain ternary")

    # self-speculative decode: the nested 2-bit planes of the SAME packed
    # weights draft gamma tokens per chunk; the 4-bit model verifies them in
    # one batched forward. Greedy output is token-identical to plain greedy.
    # Both paths warmed so the tok/s comparison excludes XLA compiles.
    eng = Engine(cfg, qp, max_seq=args.prompt_len + args.gen + 16)
    spec_cfg = SpecConfig(q_draft=2, gamma=4)
    plain = eng.generate(prompts, args.gen)  # warm plain + reference tokens
    eng.generate(prompts, args.gen, speculate=spec_cfg)  # warm the spec path
    t0 = time.perf_counter()
    plain = eng.generate(prompts, args.gen)
    plain_dt = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = eng.generate(prompts, args.gen, speculate=spec_cfg)
    dt = time.perf_counter() - t0
    st = res.spec_stats
    assert np.array_equal(res.tokens, plain.tokens), "speculative greedy must be exact"
    print(f"bcq-q4 warm : {toks} tokens in {plain_dt:.2f}s "
          f"({toks/plain_dt:.1f} tok/s CPU, plain scanned decode)")
    print(
        f"bcq-q4+spec : {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s CPU, "
        f"draft q'={st['q_draft']} γ={st['gamma']}, "
        f"acceptance {st['accept_rate']:.0%} over {st['proposed']} proposals, "
        f"{st['chunks']} chunks) — output token-identical to plain greedy"
    )

    streaming_demo(eng, prompts, args.gen)

    prefix_cache_demo(cfg, qp, prompts, args.gen)

    # tensor-parallel serving (DESIGN.md §7): same packed weights, sharded
    # over an N-way model mesh under shard_map. Greedy decode must reproduce
    # the single-device engine bit-for-bit.
    if args.tp > 1:
        from repro.parallel.tp import make_tp_mesh

        # g=48 so the row-parallel wo (k = q_dim = 192) keeps whole scale
        # groups per shard: (k/g) % tp == 0 (other leaves adapt g per k)
        qp_tp = quantize_params(params, QuantPolicy(q=4, g=48, iters=6))
        solo = Engine(cfg, qp_tp, max_seq=args.prompt_len + args.gen + 8)
        ref = solo.generate(prompts, args.gen)  # warm + reference
        t0 = time.perf_counter()
        ref = solo.generate(prompts, args.gen)
        solo_dt = time.perf_counter() - t0

        eng_tp = Engine(cfg, qp_tp, max_seq=args.prompt_len + args.gen + 8,
                        mesh=make_tp_mesh(args.tp))
        res = eng_tp.generate(prompts, args.gen)  # warm
        t0 = time.perf_counter()
        res = eng_tp.generate(prompts, args.gen)
        tp_dt = time.perf_counter() - t0
        assert np.array_equal(res.tokens, ref.tokens), (
            "tensor-parallel greedy decode must be token-identical"
        )
        print(
            f"bcq-q4 g=48 : {toks} tokens in {solo_dt:.2f}s "
            f"({toks/solo_dt:.1f} tok/s CPU, single device)"
        )
        print(
            f"bcq-q4 tp={args.tp} : {toks} tokens in {tp_dt:.2f}s "
            f"({toks/tp_dt:.1f} tok/s CPU host mesh — functional demo, the "
            f"bandwidth win needs real chips) — output token-identical"
        )


if __name__ == "__main__":
    main()
