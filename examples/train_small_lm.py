"""End-to-end training driver: train a small LM on the synthetic corpus with
the full production loop (checkpoint/resume, preemption guard, straggler
detector), then post-training-quantize it and report PPL degradation.

PYTHONPATH=src python examples/train_small_lm.py [--steps 300] [--resume-demo]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import MarkovCorpus, batch_iterator
from repro.models import forward, init_params, reduced
from repro.quant import QuantPolicy, quantize_params, quantized_bytes
from repro.train import adamw_init, cross_entropy, make_train_step
from repro.train.loop import LoopConfig, PreemptionGuard, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_small_lm")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(
        get_config("llama3.2-3b"),
        d_model=args.d_model, n_layers=args.layers, n_kv_heads=4,
        d_ff=4 * args.d_model, vocab=1024,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params ({cfg.n_layers}L d={cfg.d_model})")

    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=1e-3, accum_steps=2))
    corpus = MarkovCorpus(cfg.vocab, seed=1)
    batches = (
        {k: jnp.asarray(v) for k, v in b.items()}
        for b in batch_iterator(corpus, batch=16, seq_len=128)
    )
    loop_cfg = LoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        log_every=20,
    )
    params, opt, hist = train_loop(
        step, params, opt, batches, loop_cfg, guard=PreemptionGuard()
    )

    # eval + PTQ sweep (paper Fig. 5 in miniature)
    eval_fn = jax.jit(
        lambda p, t, l: cross_entropy(forward(cfg, p, tokens=t)[0], l)
    )
    it = batch_iterator(corpus, batch=16, seq_len=128, seed=4242)
    def ppl(p):
        nll = [float(eval_fn(p, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"])))
               for b in (next(it) for _ in range(4))]
        return float(np.exp(np.mean(nll)))

    base = ppl(params)
    print(f"\ndense: ppl={base:.3f} bytes={quantized_bytes(params)/2**20:.1f}MiB")
    for q, g in ((2, 64), (3, 128), (4, 128)):
        qp = quantize_params(params, QuantPolicy(q=q, g=g, iters=6))
        print(
            f"q={q} g={g}: ppl={ppl(qp):.3f} (+{ppl(qp)-base:.3f}) "
            f"bytes={quantized_bytes(qp)/2**20:.1f}MiB"
        )


if __name__ == "__main__":
    main()
