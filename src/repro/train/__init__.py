"""Training runtime: optimizer, steps, loop, checkpointing, compression."""

from repro.train.optimizer import AdamWState, adamw_init, adamw_update, cosine_lr
from repro.train.step import (
    cross_entropy,
    loss_fn,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_lr",
    "cross_entropy",
    "loss_fn",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
]
