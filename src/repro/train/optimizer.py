"""AdamW implemented from scratch (no optax dependency).

First/second moments are f32 regardless of param dtype; the update is computed
in f32 and cast back. Moment state shards exactly like its parameter (the
optimizer is elementwise), so FSDP covers optimizer state for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array  # () int32
    m: Any  # pytree like params (f32)
    v: Any  # pytree like params (f32)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.int32(0), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, AdamWState, jax.Array]:
    """Returns (new_params, new_state, global_grad_norm)."""
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)) + 1e-12
    )
    if grad_clip:
        scale = jnp.minimum(1.0, grad_clip / gnorm)
        gf = jax.tree.map(lambda g: g * scale, gf)

    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, gf)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, gf)

    def upd(p, m, v):
        mh = m / c1
        vh = v / c2
        pf = p.astype(jnp.float32)
        out = pf - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * pf)
        return out.astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm


def cosine_lr(step: jax.Array, *, peak: float, warmup: int, total: int) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = peak * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * peak * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
