"""Fault-tolerant checkpointing: atomic, resumable, elastic.

- **Atomic**: state is written to ``<dir>/tmp.<step>`` then ``os.replace``-d to
  ``<dir>/step_<n>`` — a crash mid-write never corrupts the latest checkpoint.
- **Resumable**: ``latest_step``/``restore`` let the train loop resume from the
  newest complete checkpoint by default after any failure or preemption.
- **Elastic**: arrays are stored as *full logical* numpy arrays; ``restore``
  takes a template pytree (with shardings) and ``device_put``s each leaf onto
  it, so a run checkpointed on N chips restores onto M ≠ N chips (remeshing /
  elastic scaling). On a real multi-host pod the same layout is written per
  leader with process-subset reads; single-process semantics here.
- **Async**: ``AsyncCheckpointer`` hands the host copy to a writer thread so
  the step loop is not blocked on disk.
- **Retention**: keep the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")

# numpy can't save/cast low-precision ML dtypes — store them as uint views and
# record the true dtype in the manifest
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, state: Any, *, keep: int = 3) -> str:
    """Blocking atomic save. Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(_leaf_paths(state)):
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        if true_dtype in _EXOTIC:
            arr = arr.view(_EXOTIC[true_dtype][1])
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
        manifest["leaves"].append(
            {"key": key, "file": f"leaf_{i}.npy", "shape": list(arr.shape),
             "dtype": true_dtype}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: Any) -> Any:
    """Load into the structure (and shardings, if template leaves carry them).

    The template may be concrete arrays or ShapeDtypeStructs with ``.sharding``;
    leaves are device_put with that sharding → elastic re-meshing on restore.
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(x) for x in p)
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(path, entry["file"]))
        if entry["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[entry["dtype"]][0])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs template {leaf.shape}"
            )
        sharding = getattr(leaf, "sharding", None)
        dtype = leaf.dtype
        value = jnp.asarray(arr, dtype=dtype)
        if sharding is not None:
            leaves.append(jax.device_put(value, sharding))
        else:
            leaves.append(value)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


class AsyncCheckpointer:
    """Background-thread writer: `submit` returns immediately after the
    host-side copy; the previous write is awaited first (at most one in flight,
    like production async checkpointing)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, state = item
            try:
                save(self.ckpt_dir, step, state, keep=self.keep)
            except BaseException as e:  # surfaced on next submit/close
                self._err = e

    def submit(self, step: int, state: Any) -> None:
        if self._err:
            raise RuntimeError("async checkpoint failed") from self._err
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._q.put((step, host_state))  # blocks if one is already in flight

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise RuntimeError("async checkpoint failed") from self._err
