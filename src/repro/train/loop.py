"""The training loop: checkpoint/restart, preemption, straggler detection.

Designed for 1000+ chip runs:
- resume-by-default from the newest complete checkpoint
- SIGTERM/SIGINT → final checkpoint → clean exit (preemption handling)
- async checkpoint writer (step loop never blocks on disk)
- step-time EMA straggler/anomaly detector (on a real pod this feeds the
  controller that evicts slow hosts; here it logs and counts)
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Iterator, Optional

import jax

from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0  # step slower than EMA*factor → anomaly


class StragglerDetector:
    """EMA step-time watchdog — the single-process stand-in for fleet-level
    straggler mitigation (slow-host eviction / hot-spares)."""

    def __init__(self, factor: float = 3.0, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.count = 0
        self.anomalies = 0

    def observe(self, dt: float) -> bool:
        self.count += 1
        if self.ema is None:
            self.ema = dt
            return False
        slow = self.count > self.warmup and dt > self.factor * self.ema
        if slow:
            self.anomalies += 1
        else:
            self.ema = 0.9 * self.ema + 0.1 * dt
        return slow


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a cooperative stop flag."""

    def __init__(self, install: bool = True):
        self.requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
                signal.signal(signal.SIGINT, self._handler)
            except ValueError:
                pass  # not in main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True


def train_loop(
    train_step: Callable,
    params,
    opt_state,
    batches: Iterator[dict],
    loop_cfg: LoopConfig,
    *,
    log: Callable[[str], None] = print,
    guard: Optional[PreemptionGuard] = None,
) -> tuple:
    """Run to total_steps (resuming included). Returns (params, opt, history)."""
    start_step = 0
    async_ckpt = None
    if loop_cfg.ckpt_dir:
        latest = ckpt_lib.latest_step(loop_cfg.ckpt_dir)
        if latest is not None:
            state = ckpt_lib.restore(
                loop_cfg.ckpt_dir, latest, {"params": params, "opt": opt_state}
            )
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            log(f"[restore] resumed from step {latest}")
        async_ckpt = ckpt_lib.AsyncCheckpointer(loop_cfg.ckpt_dir, keep=loop_cfg.keep)

    guard = guard or PreemptionGuard(install=False)
    detector = StragglerDetector(loop_cfg.straggler_factor)
    history = []

    completed = start_step
    for step in range(start_step, loop_cfg.total_steps):
        batch = next(batches)
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        completed = step + 1
        dt = time.perf_counter() - t0
        if detector.observe(dt):
            log(f"[straggler] step {step}: {dt:.3f}s vs EMA {detector.ema:.3f}s")
        if step % loop_cfg.log_every == 0:
            loss = float(metrics["loss"])
            history.append((step, loss))
            log(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        if async_ckpt and completed % loop_cfg.ckpt_every == 0:
            async_ckpt.submit(completed, {"params": params, "opt": opt_state})
        if guard.requested:
            log(f"[preempt] signal at step {step}; checkpointing and exiting")
            break

    if loop_cfg.ckpt_dir:
        async_ckpt.close()
        ckpt_lib.save(
            loop_cfg.ckpt_dir, completed, {"params": params, "opt": opt_state},
            keep=loop_cfg.keep,
        )
    return params, opt_state, history
