"""Loss and train/prefill/serve step functions (the units the dry-run lowers).

``train_step`` is a full fused step: forward (scan + remat) → cross-entropy →
backward → AdamW. ``make_*_step`` return closures over the static config so
they jit/lower cleanly.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamWState, adamw_update

MOE_AUX_WEIGHT = 0.01


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; labels < 0 are masked. logits (B,S,V) f32, labels (B,S)."""
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def loss_fn(cfg: ModelConfig, params, batch: dict, *, remat: bool = True):
    kwargs = {}
    if cfg.input_kind == "tokens":
        kwargs["tokens"] = batch["tokens"]
    else:
        kwargs["embeddings"] = batch["embeddings"]
    if cfg.family == "vlm":
        kwargs["image_emb"] = batch.get("image_emb")
    logits, _, aux = forward(cfg, params, **kwargs, remat=remat)
    loss = cross_entropy(logits, batch["labels"])
    total = loss + MOE_AUX_WEIGHT * aux
    return total, {"loss": loss, "moe_aux": aux}


def make_train_step(
    cfg: ModelConfig, *, remat: bool = True, lr: float = 3e-4, accum_steps: int = 1
):
    """(params, opt_state, batch) → (params, opt_state, metrics).

    ``accum_steps > 1`` runs gradient accumulation over microbatches via
    lax.scan: live activations scale with the microbatch, which is what lets
    the 1M-token train_4k shape fit 16 GB HBM on the deep archs (the f32 grad
    accumulator costs 4·N/chips — cheap next to saved activations).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat), has_aux=True
        )(params)

    def train_step(params, opt_state: AdamWState, batch: dict):
        if accum_steps == 1:
            (_, metrics), grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(
                    accum_steps, x.shape[0] // accum_steps, *x.shape[1:]
                ),
                batch,
            )

            def body(gsum, mb):
                (_, m), g = grads_of(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return gsum, m

            gsum0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            gsum, ms = jax.lax.scan(body, gsum0, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        new_params, new_opt, gnorm = adamw_update(
            grads, opt_state, params, lr=jnp.float32(lr)
        )
        metrics = dict(metrics, grad_norm=gnorm)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, s_max: Optional[int] = None):
    """(params, batch, cache) → (last-position logits, filled cache)."""

    def prefill_step(params, batch: dict, cache):
        kwargs = {}
        if cfg.input_kind == "tokens":
            kwargs["tokens"] = batch["tokens"]
        else:
            kwargs["embeddings"] = batch["embeddings"]
        if cfg.family == "vlm":
            kwargs["image_emb"] = batch.get("image_emb")
        logits, cache, _ = forward(
            cfg, params, **kwargs, cache=cache, pos=jnp.int32(0), logits_mode="last"
        )
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, inputs, pos) → (logits (B,1,V), cache).

    This is what the ``decode_*`` / ``long_*`` dry-run shapes lower: one new
    token against a seq_len-deep KV cache / recurrent state, with weights that
    may be packed BCQ QuantizedTensors (the paper's generation stage,
    Fig. 13 right branch).
    """

    def serve_step(params, cache, batch: dict, pos):
        kwargs = {}
        if cfg.input_kind == "tokens":
            kwargs["tokens"] = batch["tokens"]
        else:
            kwargs["embeddings"] = batch["embeddings"]
        if cfg.family == "vlm":
            kwargs["image_emb"] = None  # cached cross-KV
        logits, cache, _ = forward(
            cfg, params, **kwargs, cache=cache, pos=pos, logits_mode="last"
        )
        return logits, cache

    return serve_step
