"""Gradient compression for cross-pod all-reduce, with error feedback.

At 1000+ chips the ``pod`` axis all-reduce crosses the slowest links (DCN
between pods), so gradient bytes there dominate the collective roofline term.
Two compressors, both with error-feedback residuals (so compression error is
re-injected next step and convergence is preserved, 1-bit-Adam style):

- ``bf16``: cast-to-bf16 reduce (2x bytes, lossless-ish)
- ``int8``: per-tensor-scaled int8 (4x bytes) + residual feedback

Used by ``make_compressed_train_step``: gradients are compressed *before* the
DP mean (shard_map over the dp axes, psum on the compressed payload),
decompressed after. The paper's theme — trade precision for bandwidth on the
memory/interconnect-bound path — applied to training collectives.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.compat import axis_size


def compress_int8(g: jax.Array, residual: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """→ (int8 payload, f32 scale, new residual)."""
    gf = g.astype(jnp.float32) + (residual if residual is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals, mode: str):
    """Compress every leaf; returns (payload_tree, aux_tree, new_residuals)."""
    if mode == "bf16":
        payload = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        return payload, None, residuals
    if mode == "int8":
        if residuals is None:
            residuals = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        flat = jax.tree.map(compress_int8, grads, residuals)
        payload = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        scales = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_res = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return payload, scales, new_res
    raise ValueError(f"unknown compression mode {mode!r}")


def allreduce_mean_compressed(grads, residuals, *, axis_names, mode: str = "int8"):
    """Inside shard_map: compress → psum over `axis_names` → decompress → mean.

    int8 payloads psum in int32 (exact for <= 2^23 summands), then rescale by
    the max scale — a standard conservative shared-scale reduction.
    """
    n = 1
    for a in axis_names:
        n *= axis_size(a)
    if mode == "none":
        return jax.tree.map(lambda g: jax.lax.pmean(g, axis_names), grads), residuals
    payload, aux, new_res = compress_tree(grads, residuals, mode)
    if mode == "bf16":
        summed = jax.tree.map(
            lambda p: jax.lax.psum(p.astype(jnp.float32), axis_names), payload
        )
        return jax.tree.map(lambda s: s / n, summed), new_res
    # int8: share one scale via max, re-quantise exactly is skipped (payload is
    # already int8); sum int32 then scale/mean.
    summed = jax.tree.map(
        lambda p: jax.lax.psum(p.astype(jnp.int32), axis_names), payload
    )
    max_scale = jax.tree.map(lambda s: jax.lax.pmax(s, axis_names), aux)
    out = jax.tree.map(lambda s, sc: s.astype(jnp.float32) * sc / n, summed, max_scale)
    return out, new_res
