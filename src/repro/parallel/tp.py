"""Tensor-parallel quantized serving: shard_map the BCQ decode stack.

The paper's multi-GPU latency model (§V.C) assumes the quantized GEMV shards
cleanly: group-wise BCQ keeps every scale next to the k-rows it scales, so a
weight split along either logical dim carries its packed planes *and* its
group scales with it and each device runs the same LUT/BCQ kernel on a
smaller problem — no dequantize-then-reshard step. This module turns that
into the serving topology (DESIGN.md §7):

- **column-parallel** (``wq``/``wk``/``wv``/``wqkv``, ``w_gate``/``w_up``/
  ``w_gate_up``, ``lm_head``): output dim over ``model``. Each device
  projects its own attention heads / FFN columns / vocab slice from the
  replicated activation — zero collectives. Fused multi-projection leaves
  (``wqkv``, ``w_gate_up``) need a **column re-layout first**: their output
  dim is ``[q | k | v]`` concatenated, and naively slicing ``o_total`` into
  N chunks would hand device 0 all of Q and device N-1 all of V. The fuser's
  ``o_total`` is split per-projection and re-interleaved so shard ``d`` holds
  ``[q_d | k_d | v_d]`` (:func:`relayout_fused_for_tp`) and the local
  ``linear_fused`` split keeps working with local dims.
- **row-parallel** (``wo``, ``w_down``): reduction dim over ``model``; local
  matmuls produce partial sums that ``psum`` back to the replicated residual
  stream (`models/layers.py::psum_partial`). Group scales shard with their
  groups, which requires ``(k / g) % tp == 0`` — checked loudly, below.
- **KV caches**: kv-head dim over ``model`` (``cache_specs(layout="heads")``)
  matching the column-parallel projections' local heads. Attention is then
  fully head-local; rope stays local too (it rotates ``(i, i + Dh/2)`` pairs
  *within* each head, which Dh-sharding would split across devices).
- **replicated**: norms, embeddings (token gather stays local), per-slot
  counters/PRNG/logits buffers, activations between blocks.

Collective count per decode step: one ``psum`` per attention block (after
``wo``), one per MLP (after ``w_down``), plus one ``all_gather`` of the
vocab-sharded logits — 2·L + 1 small (B, 1, D)-sized collectives, never a
weight or cache gather.

Divisibility is **strict**: :func:`tp_param_specs` raises a ``ValueError``
naming the leaf and the offending dims instead of quietly replicating (the
``_maybe`` fallback of the generic GSPMD rules) — under ``shard_map`` a
silently replicated weight would be consumed as if it were a local shard and
produce garbage, and a quietly-served replicated weight defeats the whole
point of sharding. Packed/scales spec derivation comes from the registered
format (``QuantFormat.tp_specs`` — DESIGN.md §2.4, subsuming the old
BCQ-only ``qt_specs_like`` group-divisibility logic); this module only
refuses to proceed when derivation had to drop an axis.

Entry point: :func:`shard_model` → ``(sharded_params, TPContext)``; the
engine calls ``TPContext.forward`` everywhere it used ``models.forward``
(`infer/engine.py::Engine(mesh=...)`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.formats import get_format
from repro.core.qtensor import QuantizedTensor
from repro.models.config import ModelConfig
from repro.parallel.compat import mesh_axis_names_sizes, shard_map
from repro.parallel.ctx import tp_shard_region
from repro.parallel.sharding import MeshAxes, cache_specs

# leaves that split along the output dim (heads / FFN columns / vocab)
_COLUMN_PARALLEL = frozenset(
    {"wq", "wk", "wv", "wqkv", "w_gate", "w_up", "w_gate_up", "lm_head"}
)
# leaves that split along the reduction dim (partial sums psum'd back)
_ROW_PARALLEL = frozenset({"wo", "w_down"})
# block types the shard_map decode path supports. MoE is excluded for the
# same reason as slot serving (expert capacity couples batch rows — DESIGN.md
# §4); recurrent state mixes the full width inside the per-step scan, which
# would put a collective in every timestep (the measured slstm pathology in
# sharding._slstm_specs).
_TP_BLOCKS = frozenset({"attn", "local_attn", "cross"})


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", last)))


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
        for p in path
    )


def make_tp_mesh(tp: int, axis: str = "model"):
    """A 1-D ``(tp,)`` decode mesh over the first ``tp`` visible devices."""
    n_dev = len(jax.devices())
    if n_dev < tp:
        raise RuntimeError(
            f"--tp {tp} needs {tp} XLA devices but only {n_dev} are visible; "
            f"on a CPU host set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={tp} before the first jax call"
        )
    return jax.make_mesh((tp,), (axis,))


# ---------------------------------------------------------------------------
# strict TP spec derivation (walks the ACTUAL — possibly fused — param tree)
# ---------------------------------------------------------------------------


def _require_div(dim: int, n: int, where: str, what: str, hint: str = "") -> None:
    if dim % n:
        raise ValueError(
            f"TP: cannot shard {where}: {what}={dim} is not divisible by the "
            f"model mesh axis size {n}{'; ' + hint if hint else ''} "
            "(refusing to serve a silently replicated weight)"
        )


def _qt_spec(path, qt: QuantizedTensor, ax: MeshAxes, kind: str) -> QuantizedTensor:
    n = ax.model_size
    where = _path_str(path)
    lead = qt.packed.ndim - 3  # layer-stack dims
    if kind == "col":
        _require_div(qt.o, n, where, f"output dim o (k={qt.k}, o={qt.o})")
        dense = P(*([None] * lead), None, ax.model)
    else:  # row
        _require_div(
            qt.packed.shape[-2], n, where,
            f"packed k/8 dim {qt.packed.shape[-2]} (k={qt.k})",
        )
        _require_div(
            qt.scales.shape[-2], n, where,
            f"group-scale k/g dim {qt.scales.shape[-2]} (k={qt.k}, g={qt.g})",
            hint=f"pick a group size dividing k/tp, i.e. g | {qt.k // n}",
        )
        dense = P(*([None] * lead), ax.model, None)
    # the format owns packed/scales spec derivation (QuantFormat.tp_specs —
    # group scales shard WITH their k-row groups, axes dropped if indivisible)
    spec = get_format(qt.fmt).tp_specs(dense, qt, ax)
    # belt-and-braces: qt_specs_like must not have dropped a required axis
    for plane, s in (("packed", spec.packed), ("scales", spec.scales)):
        if ax.model not in tuple(s):
            raise ValueError(
                f"TP: {qt.fmt!r} tp_specs replicated the {plane} plane of {where} "
                f"({dict(packed=qt.packed.shape, scales=qt.scales.shape)[plane]})"
                " — the dims above should have caught this"
            )
    return spec


def tp_param_specs(cfg: ModelConfig, params, ax: MeshAxes):
    """PartitionSpec tree for an actual (possibly decode-fused) param tree.

    Column/row assignment is by leaf name; everything else (norms, embed,
    biases) replicates. Raises — naming the leaf and dims — whenever a dim
    that must shard does not divide the model axis."""
    n = ax.model_size

    def visit(path, leaf):
        name = _leaf_name(path)
        where = _path_str(path)
        if isinstance(leaf, QuantizedTensor):
            if name in _COLUMN_PARALLEL:
                return _qt_spec(path, leaf, ax, "col")
            if name in _ROW_PARALLEL:
                return _qt_spec(path, leaf, ax, "row")
            return QuantizedTensor(
                packed=P(*([None] * leaf.packed.ndim)),
                scales=P(*([None] * leaf.scales.ndim)),
                g=leaf.g, k=leaf.k, o=leaf.o, fmt=leaf.fmt,
            )
        if name in _COLUMN_PARALLEL:
            _require_div(leaf.shape[-1], n, where, f"output dim {leaf.shape[-1]}")
            return P(*([None] * (leaf.ndim - 2)), None, ax.model)
        if name in _ROW_PARALLEL:
            _require_div(leaf.shape[-2], n, where, f"reduction dim {leaf.shape[-2]}")
            return P(*([None] * (leaf.ndim - 2)), ax.model, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )


# ---------------------------------------------------------------------------
# fused-leaf column re-layout
# ---------------------------------------------------------------------------


def _interleave_perm(out_dims: Sequence[int], n: int) -> np.ndarray:
    """Column permutation turning ``[p0 | p1 | ...]`` (projections whole) into
    ``[p0_0 p1_0 ... | p0_1 p1_1 ...]`` (device shards whole)."""
    starts, off = [], 0
    for d in out_dims:
        starts.append(off)
        off += d
    idx = []
    for dev in range(n):
        for st, d in zip(starts, out_dims):
            loc = d // n
            idx.extend(range(st + dev * loc, st + (dev + 1) * loc))
    return np.asarray(idx, np.int64)  # staticcheck: host-sync(host-built permutation index, no device values)


def _permute_cols(leaf, out_dims: Tuple[int, ...], n: int, where: str):
    for d in out_dims:
        _require_div(
            d, n, where,
            f"fused projection output dim {d} (of o_total split {out_dims})",
        )
    idx = _interleave_perm(out_dims, n)
    if isinstance(leaf, QuantizedTensor):
        return QuantizedTensor(
            packed=leaf.packed[..., idx], scales=leaf.scales[..., idx],
            g=leaf.g, k=leaf.k, o=leaf.o, fmt=leaf.fmt,
        )
    return leaf[..., idx]


def relayout_fused_for_tp(cfg: ModelConfig, params, n: int):
    """Re-interleave fused ``wqkv`` / ``w_gate_up`` output columns so each of
    the ``n`` contiguous shards holds that device's slice of EVERY projection
    (the local ``linear_fused`` split then uses local per-projection dims).

    Identity for ``n == 1`` and for unfused trees."""
    if n == 1:
        return params
    stages = []
    for si, (pattern, _) in enumerate(cfg.stages):
        stage_p = dict(params["stages"][si])
        for bi, _btype in enumerate(pattern):
            bp = dict(stage_p[f"b{bi}"])
            attn = bp.get("attn")
            if isinstance(attn, dict) and "wqkv" in attn:
                attn = dict(attn)
                attn["wqkv"] = _permute_cols(
                    attn["wqkv"], (cfg.q_dim, cfg.kv_dim, cfg.kv_dim), n,
                    f"stages/{si}/b{bi}/attn/wqkv",
                )
                bp["attn"] = attn
            mlp = bp.get("mlp")
            if isinstance(mlp, dict) and "w_gate_up" in mlp:
                mlp = dict(mlp)
                w = mlp["w_gate_up"]
                o = w.o if isinstance(w, QuantizedTensor) else w.shape[-1]
                mlp["w_gate_up"] = _permute_cols(
                    w, (o // 2, o // 2), n, f"stages/{si}/b{bi}/mlp/w_gate_up"
                )
                bp["mlp"] = mlp
            stage_p[f"b{bi}"] = bp
        stages.append(stage_p)
    return dict(params, stages=tuple(stages))


# ---------------------------------------------------------------------------
# the sharded-forward context
# ---------------------------------------------------------------------------


def _relocalize(params):
    """Fix QuantizedTensor static (k, o) to the per-device shard shapes.

    shard_map hands the body local ``packed``/``scales`` slices but the pytree
    statics still say the global shape; the kernels size their grids and
    output slicing from the statics, so rebuild them from the local planes
    (the format owns the packed-rows → k relation: ``QuantFormat.relocalize``)."""

    def fix(leaf):
        if isinstance(leaf, QuantizedTensor):
            return get_format(leaf.fmt).relocalize(leaf)
        return leaf

    return jax.tree.map(fix, params, is_leaf=lambda x: isinstance(x, QuantizedTensor))


class TPContext:
    """Per-engine tensor-parallel state: the mesh, the spec trees, the local
    view of the config, and the shard_map'd ``forward``."""

    def __init__(self, cfg: ModelConfig, mesh, axis: str = "model"):
        names, sizes = mesh_axis_names_sizes(mesh)
        if axis not in names:
            raise ValueError(f"mesh {names} has no {axis!r} axis")
        self.mesh = mesh
        self.axis_name = axis
        self.ax = MeshAxes((), None, axis, tuple(zip(names, sizes)))
        self.n = self.ax.model_size
        blocks = {bt for pattern, _ in cfg.stages for bt in pattern}
        bad = sorted(blocks - _TP_BLOCKS)
        if bad:
            raise NotImplementedError(
                f"tensor-parallel serving supports attention-family blocks "
                f"{sorted(_TP_BLOCKS)}; config {cfg.name!r} has {bad} "
                "(MoE couples batch rows through expert capacity; recurrent "
                "blocks would put a collective inside every scan timestep)"
            )
        if cfg.n_heads % self.n or cfg.n_kv_heads % self.n:
            raise ValueError(
                f"TP: config {cfg.name!r} heads (n_heads={cfg.n_heads}, "
                f"n_kv_heads={cfg.n_kv_heads}) not divisible by tp={self.n}"
            )
        self.cfg = cfg
        # the body computes with per-device head counts; d_head/q_dim/kv_dim
        # follow (q_dim = n_heads·d_head), everything else stays global
        self.cfg_local = dataclasses.replace(
            cfg, n_heads=cfg.n_heads // self.n, n_kv_heads=cfg.n_kv_heads // self.n
        )
        self.param_spec_tree = None  # set by shard_model
        self._cache_spec_tree = cache_specs(cfg, self.ax, 1, layout="heads")

    # -- placement ----------------------------------------------------------

    def _put(self, tree, specs):
        # QuantizedTensor nodes are placed as units (packed/scales each get
        # their spec) rather than flattened through jax.tree.map: a draft
        # tree's statics may legitimately differ from the spec tree's —
        # cross-format truncation re-tags leaves (ternary drafts are BCQ)
        # and slices the plane axis, while the plane/group dim specs still
        # apply verbatim.
        def put(x, s):
            if isinstance(x, QuantizedTensor):
                return QuantizedTensor(
                    packed=jax.device_put(
                        x.packed, NamedSharding(self.mesh, s.packed)
                    ),
                    scales=jax.device_put(
                        x.scales, NamedSharding(self.mesh, s.scales)
                    ),
                    g=x.g,
                    k=x.k,
                    o=x.o,
                    fmt=x.fmt,
                )
            return jax.device_put(x, NamedSharding(self.mesh, s))

        return jax.tree.map(
            put, tree, specs, is_leaf=lambda x: isinstance(x, QuantizedTensor)
        )

    def place_params(self, params):
        """(Re-)commit a param tree to its TP sharding. Used for the params
        themselves and for ``truncate_params`` draft views — plane truncation
        slices the q axis (and may re-tag the format: ternary drafts are
        1-plane BCQ), never the sharded dim, so the spec tree of the full
        tree applies verbatim."""
        if self.param_spec_tree is None:
            raise RuntimeError("shard_model has not placed the params yet")
        return self._put(params, self.param_spec_tree)

    def _specs_like(self, params):
        """The param spec tree with QuantizedTensor statics re-tagged to match
        ``params``. Draft trees from cross-format truncation carry different
        static metadata than the target tree the specs were built from
        (ternary drafts are 1-plane BCQ) — the dim specs apply verbatim, but
        pytree-structure-sensitive consumers (shard_map in_specs) need the
        aux data to agree."""

        def fix(s, p):
            if isinstance(s, QuantizedTensor):
                return QuantizedTensor(
                    packed=s.packed, scales=s.scales, g=p.g, k=p.k, o=p.o,
                    fmt=p.fmt,
                )
            return s

        return jax.tree.map(
            fix, self.param_spec_tree, params,
            is_leaf=lambda x: isinstance(x, QuantizedTensor),
        )

    def shard_cache(self, cache):
        """Place a fresh ``init_cache`` tree with kv-heads over ``model``."""
        return self._put(cache, self.cache_spec_tree(cache))

    def cache_spec_tree(self, cache):
        # structure mirrors init_cache for this cfg; batch stays replicated
        # under the decode mesh (slots are requests, not shards)
        return self._cache_spec_tree

    # -- the sharded forward -------------------------------------------------

    def forward(
        self,
        params,
        *,
        tokens=None,
        embeddings=None,
        image_emb=None,
        cache=None,
        pos=None,
        logits_mode: str = "all",
        chunked_decode: bool = False,
        collect_states: bool = False,
    ):
        """Drop-in for ``functools.partial(models.forward, cfg)`` on the
        decode/serve paths: one shard_map region per forward, params/cache
        consumed as local shards, logits returned replicated (gathered)."""
        from repro.models.model import forward as _forward

        if cache is None:
            raise ValueError("TPContext.forward serves decode paths: pass a cache")
        arr_kw = {
            k: v
            for k, v in dict(
                tokens=tokens, embeddings=embeddings, image_emb=image_emb, pos=pos
            ).items()
            if v is not None
        }
        names = tuple(arr_kw)
        cspecs = self.cache_spec_tree(cache)
        cfg_local, axis = self.cfg_local, self.axis_name

        def body(params, cache, *arrs):
            params = _relocalize(params)
            with tp_shard_region(axis):
                return _forward(
                    cfg_local, params, cache=cache, logits_mode=logits_mode,
                    chunked_decode=chunked_decode, collect_states=collect_states,
                    **dict(zip(names, arrs)),
                )

        rep = lambda x: P(*([None] * jax.numpy.ndim(x)))
        fn = shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._specs_like(params), cspecs)
            + tuple(rep(v) for v in arr_kw.values()),
            out_specs=(P(None, None, None), cspecs, P()),
            check_vma=False,
        )
        return fn(params, cache, *arr_kw.values())


def shard_model(cfg: ModelConfig, params, mesh, *, axis: str = "model"):
    """Place a (possibly decode-fused) param tree tensor-parallel on ``mesh``.

    Returns ``(sharded_params, TPContext)``. Fused leaves are column-
    re-interleaved first so plain output-dim sharding hands each device its
    slice of every projection; QuantizedTensor leaves get packed/scales specs
    via ``qt_specs_like`` off the dense weight's spec. Any dim that must shard
    but does not divide the mesh axis raises (leaf + dims in the message)."""
    tpc = TPContext(cfg, mesh, axis=axis)
    params = relayout_fused_for_tp(cfg, params, tpc.n)
    specs = tp_param_specs(cfg, params, tpc.ax)
    tpc.param_spec_tree = specs
    return tpc._put(params, specs), tpc
