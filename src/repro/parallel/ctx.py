"""Mesh-context-aware activation sharding constraints.

``constrain_tokens(h)`` pins (batch, seq, hidden) activations to
(dp-axes, None, None) at stage boundaries. Without these anchors GSPMD can
propagate a model-sharded hidden out of a row-parallel matmul into the LM
head, turning the logits matmul into a 13 GB/device partial-sum all-reduce
(measured on xlstm-125m train_4k — EXPERIMENTS.md §Perf iteration 2).

No-ops when there is no ambient mesh (CPU smoke tests, single-device runs).
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import PartitionSpec as P

_DP_AXES = ("pod", "data")

# ---------------------------------------------------------------------------
# shard_map TP trace context (set by parallel/tp.py while tracing its
# shard_map body). Inside that region every device holds *local* weight and
# KV shards, so the model code must (a) all-reduce row-parallel partial sums
# itself and (b) NOT emit with_sharding_constraints (GSPMD annotations are
# meaningless on manual-mode values). The helpers below are no-ops outside a
# TP region, so training / single-device serving paths are untouched.
# ---------------------------------------------------------------------------

_TP_AXIS_STACK: list = []


def tp_axis():
    """Mesh-axis name of the innermost active TP shard_map region, or None."""
    return _TP_AXIS_STACK[-1] if _TP_AXIS_STACK else None


@contextlib.contextmanager
def tp_shard_region(axis_name: str):
    """Mark (at trace time) that model code runs inside a TP shard_map body."""
    _TP_AXIS_STACK.append(axis_name)
    try:
        yield
    finally:
        _TP_AXIS_STACK.pop()


def psum_partial(x: jax.Array) -> jax.Array:
    """All-reduce a row-parallel partial sum (O / down projections) inside a
    TP region; identity everywhere else."""
    ax = tp_axis()
    if ax is None:
        return x
    return jax.lax.psum(x, ax)


def all_gather_cols(x: jax.Array) -> jax.Array:
    """Concatenate column-parallel output shards (vocab-sharded logits) along
    the last dim inside a TP region; identity everywhere else."""
    ax = tp_axis()
    if ax is None:
        return x
    return jax.lax.all_gather(x, ax, axis=x.ndim - 1, tiled=True)


def _ambient_axes():
    if tp_axis() is not None:
        # inside a shard_map body: values are device-local (manual mode);
        # GSPMD sharding constraints do not apply there
        return None
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or not getattr(mesh, "axis_names", None):
        return None
    return mesh


def constrain_qkv(q: jax.Array, k: jax.Array, v: jax.Array):
    """Pin attention operand shardings so the score einsum never contracts a
    sharded Dh dim (which turns the S×S logits into a partial-sum all-reduce —
    measured 90 GB fwd + 327 GB bwd per chip on llama3.2-3b train_4k).

    - heads divisible by the model axis → TP over heads (Megatron style);
    - otherwise → context parallelism: queries sequence-sharded over model,
      K/V replicated across it (K/V are GQA-small), logits stay local.
    """
    mesh = _ambient_axes()
    if mesh is None or q.ndim != 4:
        return q, k, v
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    if "model" not in sizes:
        return q, k, v
    m = sizes["model"]
    dp = tuple(a for a in _DP_AXES if a in sizes)
    n = 1
    for a in dp:
        n *= sizes[a]
    b_ax = dp if (dp and q.shape[0] % n == 0) else None
    wsc = jax.lax.with_sharding_constraint
    h_q, h_kv = q.shape[2], k.shape[2]
    if h_q % m == 0 and h_kv % m == 0:
        spec = P(b_ax, None, "model", None)
        return wsc(q, spec), wsc(k, spec), wsc(v, spec)
    # Non-divisible heads: leave GSPMD's choice in place for the baseline.
    # (Context-parallel q was tried: the per-layer S-shard→unshard all-gathers
    # of the residual stream cost MORE than the Dh-contraction all-reduce it
    # removes — 820 GB vs 420 GB per chip on llama3.2-3b train_4k. The proper
    # fix is full Megatron-style sequence parallelism — §Perf hillclimb.)
    return q, k, v


def constrain_tokens(h: jax.Array) -> jax.Array:
    """(B, S, D) or (B, S): batch over the dp axes present in the ambient mesh."""
    mesh = _ambient_axes()
    if mesh is None:
        return h
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    dp = tuple(a for a in _DP_AXES if a in sizes)
    if not dp:
        return h
    n = 1
    for a in dp:
        n *= sizes[a]
    if h.shape[0] % n:
        return h
    spec = P(dp, *([None] * (h.ndim - 1)))
    return jax.lax.with_sharding_constraint(h, spec)


def constrain_decode_q(q: jax.Array) -> jax.Array:
    """Decode-path q (B,1,H,Dh): shard Dh over `model` to match the Dh-sharded
    KV cache, making the score einsum a local partial + small all-reduce."""
    mesh = _ambient_axes()
    if mesh is None or q.ndim != 4:
        return q
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    if "model" not in sizes or q.shape[-1] % sizes["model"]:
        return q
    dp = tuple(a for a in _DP_AXES if a in sizes)
    n = 1
    for a in dp:
        n *= sizes[a]
    b_ax = dp if (dp and q.shape[0] % n == 0) else None
    return jax.lax.with_sharding_constraint(q, P(b_ax, None, None, "model"))
