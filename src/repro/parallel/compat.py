"""Version-guarded JAX API compatibility shims.

The distribution layer targets the *current* JAX surface (``jax.shard_map``,
``jax.sharding.get_abstract_mesh``, ``Mesh.axis_sizes``) but must run on older
releases where those live under different names (``jax.experimental.shard_map``
with ``check_rep``, thread-local physical mesh, ``Mesh.shape``). Every module
that shard_maps or inspects the ambient mesh imports from here instead of
version-guarding call sites one by one.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["shard_map", "get_abstract_mesh", "mesh_axis_names_sizes", "axis_size"]


def axis_size(axis_name) -> jax.Array:
    """Size of a mapped mesh axis from inside shard_map: ``jax.lax.axis_size``
    where it exists, else the classic ``psum(1, axis)`` idiom."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` when available, else the ``jax.experimental`` spelling.

    The replication-checking kwarg was renamed ``check_rep`` → ``check_vma``;
    callers use the new name and we translate downward.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def get_abstract_mesh():
    """The ambient mesh (entered via :func:`repro.launch.mesh.set_mesh`), or
    ``None`` when no mesh is active.

    New JAX exposes ``jax.sharding.get_abstract_mesh``; on older releases the
    active mesh lives in the thread-local resource env that ``with mesh:``
    populates.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not getattr(mesh, "axis_names", None):
            return None
        return mesh
    from jax._src import mesh as _mesh_lib

    mesh = _mesh_lib.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh


def mesh_axis_names_sizes(mesh) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    """``(axis_names, axis_sizes)`` across Mesh/AbstractMesh generations
    (``axis_sizes`` predates only the newest API; ``shape`` is the old dict)."""
    names = tuple(mesh.axis_names)
    if hasattr(mesh, "axis_sizes"):
        return names, tuple(mesh.axis_sizes)
    return names, tuple(mesh.shape[n] for n in names)
