"""Distribution layer: mesh axes + PartitionSpec rules (DP/FSDP/TP/EP/SP)."""

from repro.parallel.compat import get_abstract_mesh, mesh_axis_names_sizes, shard_map
from repro.parallel.sharding import (
    MeshAxes,
    batch_specs,
    cache_specs,
    decode_tp_axes,
    param_specs,
    single_pod_axes,
    multi_pod_axes,
)

__all__ = [
    "MeshAxes",
    "batch_specs",
    "cache_specs",
    "decode_tp_axes",
    "param_specs",
    "single_pod_axes",
    "multi_pod_axes",
    "get_abstract_mesh",
    "mesh_axis_names_sizes",
    "shard_map",
]
