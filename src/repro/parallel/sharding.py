"""PartitionSpec rules for every parameter / cache / batch tensor.

Strategy (DESIGN.md §7) — everything is expressed in axis *names* so meshes of
any size reuse the same rules:

- batch (DP) over ``dp = ("pod", "data")`` (or ``("data",)`` single-pod)
- FSDP (ZeRO-3) over ``fsdp = "data"`` — params' non-TP dim sharded in-pod,
  replicated across pods (all-gathers stay on intra-pod ICI; only gradient
  all-reduce crosses pods)
- TP over ``model``: attention heads / FFN hidden / vocab / LRU width
- EP over ``model``: MoE expert dim
- KV caches: batch over ``dp`` (when divisible), head_dim over ``model``

Spec trees mirror ``models.model.init_params`` / ``init_cache`` structurally,
including :class:`QuantizedTensor` nodes (packed/scales get specs derived from
the dense weight's spec).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.core.qtensor import QuantizedTensor
from repro.models.config import ModelConfig

Axis = Optional[str]


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...]  # batch axes (pure DP; ("pod","data") multi-pod)
    fsdp: Optional[str]  # param-shard axis (ZeRO-3); None disables FSDP
    model: str  # TP / EP axis
    sizes: Tuple[Tuple[str, int], ...]  # axis name → size

    def size(self, name) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            n = 1
            for a in name:
                n *= self.size(a)
            return n
        return dict(self.sizes)[name]

    @property
    def data_size(self) -> int:
        return self.size(self.dp)

    @property
    def model_size(self) -> int:
        return self.size(self.model)


def single_pod_axes(data: int = 16, model: int = 16) -> MeshAxes:
    return MeshAxes(("data",), "data", "model", (("data", data), ("model", model)))


def multi_pod_axes(pod: int = 2, data: int = 16, model: int = 16) -> MeshAxes:
    return MeshAxes(
        ("pod", "data"),
        "data",
        "model",
        (("pod", pod), ("data", data), ("model", model)),
    )


def decode_tp_axes(model: int) -> MeshAxes:
    """Pure tensor-parallel decode mesh: one `model` axis, no DP/FSDP.

    The serving engine's shard_map path (parallel/tp.py) uses this — batch
    rows are request slots, never sharded; only weights/caches split."""
    return MeshAxes((), None, "model", (("model", model),))


def _div(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def _maybe(axis, dim: int, size: int):
    """Use `axis` only if it divides `dim` (else replicate that dim)."""
    if axis is None:
        return None
    return axis if _div(dim, size) else None


# ---------------------------------------------------------------------------
# per-block parameter specs (mirrors models.model.init_block)
# ---------------------------------------------------------------------------


def _wspec(cfg: ModelConfig, ax: MeshAxes, k: int, o: int, k_ax, o_ax) -> P:
    """Spec for a (k, o) weight; axes dropped when they don't divide."""
    return P(_maybe(k_ax, k, ax.size(k_ax)), _maybe(o_ax, o, ax.size(o_ax)))


def _attn_specs(cfg: ModelConfig, ax: MeshAxes) -> dict:
    f, m = ax.fsdp, ax.model
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": _wspec(cfg, ax, d, qd, f, m),
        "wk": _wspec(cfg, ax, d, kvd, f, m),
        "wv": _wspec(cfg, ax, d, kvd, f, m),
        "wo": _wspec(cfg, ax, qd, d, m, f),
    }


def _mlp_specs(cfg: ModelConfig, ax: MeshAxes, d_ff: Optional[int] = None) -> dict:
    f, m = ax.fsdp, ax.model
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": _wspec(cfg, ax, d, ff, f, m),
        "w_up": _wspec(cfg, ax, d, ff, f, m),
        "w_down": _wspec(cfg, ax, ff, d, m, f),
    }


def _moe_specs(cfg: ModelConfig, ax: MeshAxes) -> dict:
    f, m = ax.fsdp, ax.model
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    em = _maybe(m, e, ax.size(m))  # EP: experts over model axis
    df = _maybe(f, d, ax.size(f))
    s = {
        "router": P(df, None),
        "w_gate": P(em, df, None),
        "w_up": P(em, df, None),
        "w_down": P(em, None, df),
    }
    if cfg.shared_expert:
        s["shared"] = _mlp_specs(cfg, ax)
    return s


def _rglru_specs(cfg: ModelConfig, ax: MeshAxes) -> dict:
    f, m = ax.fsdp, ax.model
    d, w = cfg.d_model, cfg.lru_width
    return {
        "w_x": _wspec(cfg, ax, d, w, f, m),
        "w_y": _wspec(cfg, ax, d, w, f, m),
        "conv_w": P(None, _maybe(m, w, ax.size(m))),
        "w_a": _wspec(cfg, ax, w, w, f, m),
        "w_i": _wspec(cfg, ax, w, w, f, m),
        "lam": P(_maybe(m, w, ax.size(m))),
        "w_out": _wspec(cfg, ax, w, d, m, f),
    }


def _mlstm_specs(cfg: ModelConfig, ax: MeshAxes) -> dict:
    f, m = ax.fsdp, ax.model
    d = cfg.d_model
    inner = int(d * cfg.mlstm_proj_factor)
    return {
        "w_up": _wspec(cfg, ax, d, inner, f, m),
        "w_z": _wspec(cfg, ax, d, inner, f, m),
        "wq": _wspec(cfg, ax, inner, inner, f, m),
        "wk": _wspec(cfg, ax, inner, inner, f, m),
        "wv": _wspec(cfg, ax, inner, inner, f, m),
        "w_i": P(_maybe(f, inner, ax.size(f)), None),
        "w_f": P(_maybe(f, inner, ax.size(f)), None),
        "w_down": _wspec(cfg, ax, inner, d, m, f),
        "skip_scale": P(_maybe(m, inner, ax.size(m))),
    }


def _slstm_specs(cfg: ModelConfig, ax: MeshAxes) -> dict:
    f, m = ax.fsdp, ax.model
    d = cfg.d_model
    # r_* (hidden-to-hidden, per head) must be REPLICATED: sharding them puts a
    # collective-permute inside every timestep of the sequential scan (measured:
    # made xlstm train_4k collective-bound at 2.8 s/step — EXPERIMENTS.md §Perf).
    rspec = P(None, None, None)
    s = {f"w_{g}": _wspec(cfg, ax, d, d, f, m) for g in ("z", "i", "f", "o")}
    s.update({f"r_{g}": rspec for g in ("z", "i", "f", "o")})
    s["w_out"] = _wspec(cfg, ax, d, d, m, f)
    return s


def block_specs(cfg: ModelConfig, ax: MeshAxes, btype: str) -> dict:
    s = {"ln1": P(None)}
    if btype in ("attn", "local_attn", "cross", "attn_moe"):
        s["attn"] = _attn_specs(cfg, ax)
        s["ln2"] = P(None)
        s["mlp"] = _moe_specs(cfg, ax) if btype == "attn_moe" else _mlp_specs(cfg, ax)
    elif btype == "rglru":
        s["mix"] = _rglru_specs(cfg, ax)
        s["ln2"] = P(None)
        s["mlp"] = _mlp_specs(cfg, ax)
    elif btype == "mlstm":
        s["mix"] = _mlstm_specs(cfg, ax)
    elif btype == "slstm":
        s["mix"] = _slstm_specs(cfg, ax)
    else:
        raise ValueError(btype)
    return s


def _stack(spec_tree, is_leaf=None):
    """Prepend the scanned layer dim (replicated) to every spec."""
    return jax.tree.map(
        lambda p: P(None, *p), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def param_specs(cfg: ModelConfig, ax: MeshAxes) -> dict:
    f, m = ax.fsdp, ax.model
    stages = []
    for pattern, _ in cfg.stages:
        stages.append(
            {f"b{bi}": _stack(block_specs(cfg, ax, bt)) for bi, bt in enumerate(pattern)}
        )
    specs = {
        "stages": tuple(stages),
        "final_norm": P(None),
        "lm_head": _wspec(cfg, ax, cfg.d_model, cfg.vocab, f, m),
    }
    if cfg.input_kind == "tokens":
        specs["embed"] = _wspec(cfg, ax, cfg.vocab, cfg.d_model, m, f)
    return specs


# ---------------------------------------------------------------------------
# cache specs (mirrors models.model.init_cache)
# ---------------------------------------------------------------------------


def _cache_block_specs(
    cfg: ModelConfig, ax: MeshAxes, btype: str, batch: int, layout: str = "dh"
) -> dict:
    b_ax = ax.dp if (ax.dp and _div(batch, ax.data_size)) else None
    m = ax.model
    if btype in ("attn", "attn_moe", "local_attn", "cross"):
        # Two KV layouts (DESIGN.md §7):
        # - "dh" (GSPMD decode/training): head_dim over `model`. Sequence-
        #   sharding (flash-decoding-style split-K) was tried and REJECTED: a
        #   dynamic-position update into a sequence-sharded dim makes GSPMD
        #   reshard the whole cache every step (measured 179 GB/chip/step on
        #   llama3.2-3b decode_32k). Dh-sharding keeps writes local; the
        #   per-layer score partial-sum is the cost.
        # - "heads" (shard_map TP, parallel/tp.py): kv-head dim over `model`,
        #   matching the column-parallel QKV projections' local heads — rope
        #   rotates (i, i+Dh/2) pairs, so splitting Dh would break the local
        #   rotary compute that head-sharding keeps collective-free.
        if layout == "heads":
            h_ax = _maybe(m, cfg.n_kv_heads, ax.size(m))
            s = P(None, b_ax, None, h_ax, None)  # (R, B, S, Hkv, Dh)
            sc = P(None, b_ax, None, h_ax)  # (R, B, S, Hkv) scales
        else:
            dh_ax = _maybe(m, cfg.d_head, ax.size(m))
            s = P(None, b_ax, None, None, dh_ax)
            sc = P(None, b_ax, None, None)
        if btype == "cross":
            return {"k_img": s, "v_img": s}
        if cfg.kv_cache_dtype == "int8":
            return {"k": s, "v": s, "k_scale": sc, "v_scale": sc}
        return {"k": s, "v": s}
    if btype == "rglru":
        w_ax = _maybe(m, cfg.lru_width, ax.size(m))
        return {
            "h": P(None, b_ax, w_ax),
            "conv": P(None, b_ax, None, w_ax),
        }
    if btype == "mlstm":
        inner = int(cfg.d_model * cfg.mlstm_proj_factor)
        dhi_ax = _maybe(m, inner // cfg.n_heads, ax.size(m))
        return {
            "c": P(None, b_ax, None, dhi_ax, None),
            "n": P(None, b_ax, None, dhi_ax),
            "m": P(None, b_ax, None),
        }
    if btype == "slstm":
        dh_s = _maybe(m, cfg.d_model // cfg.n_heads, ax.size(m))
        s = P(None, b_ax, None, dh_s)
        return {k: s for k in ("h", "c", "n", "m")}
    raise ValueError(btype)


def cache_specs(
    cfg: ModelConfig, ax: MeshAxes, batch: int, layout: str = "dh"
) -> dict:
    """Spec tree mirroring ``init_cache``. ``layout`` picks the KV split:
    ``"dh"`` (head_dim over model — GSPMD decode constraint path) or
    ``"heads"`` (kv-head dim over model — the shard_map TP path)."""
    if layout not in ("dh", "heads"):
        raise ValueError(f"unknown cache layout {layout!r}")
    stages = tuple(
        {
            f"b{bi}": _cache_block_specs(cfg, ax, bt, batch, layout)
            for bi, bt in enumerate(pattern)
        }
        for pattern, _ in cfg.stages
    )
    return {"stages": stages}


# ---------------------------------------------------------------------------
# batch / IO specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, ax: MeshAxes, batch: int) -> dict:
    """Specs for the input batch dict used by train/prefill/decode steps."""
    b_ax = ax.dp if (ax.dp and _div(batch, ax.data_size)) else None
    out = {}
    if cfg.input_kind == "tokens":
        out["tokens"] = P(b_ax, None)
        out["labels"] = P(b_ax, None)
    else:
        out["embeddings"] = P(b_ax, None, None)
        out["labels"] = P(b_ax, None)
    if cfg.family == "vlm":
        out["image_emb"] = P(b_ax, None, None)
    return out


def logits_spec(cfg: ModelConfig, ax: MeshAxes, batch: int) -> P:
    b_ax = ax.dp if (ax.dp and _div(batch, ax.data_size)) else None
    v_ax = _maybe(ax.model, cfg.vocab, ax.size(ax.model))
    return P(b_ax, None, v_ax)


# ---------------------------------------------------------------------------
# QuantizedTensor spec derivation
# ---------------------------------------------------------------------------


def qt_specs_like(dense_spec: P, qt: QuantizedTensor, ax: MeshAxes) -> QuantizedTensor:
    """Build a QuantizedTensor whose leaves are PartitionSpecs, matching the
    dense weight's (possibly layer-stacked) spec ``(…lead, k_ax, o_ax)``.

    Thin shim over the format's ``tp_specs`` capability (DESIGN.md §2.4/§7):
    the registered :class:`~repro.core.formats.QuantFormat` owns how its
    packed planes and group scales follow the dense weight's sharding — the
    shared-layout rule keeps scale groups WITH the k-rows they scale and
    drops (replicates) any axis that does not divide."""
    from repro.core.formats import get_format

    return get_format(qt.fmt).tp_specs(dense_spec, qt, ax)
