"""Model configuration for the decoder zoo.

A model is a stack of *stages*; each stage is a (pattern, repeat) pair where
``pattern`` is a tuple of block types executed in order and ``repeat`` is how
many times the pattern repeats (params stacked on a leading axis, applied with
``lax.scan``). This expresses every assigned architecture:

  dense       [("attn",) x L]
  moe         [("attn_moe",) x L]
  vlm         [("attn","attn","attn","attn","cross") x L/5]
  audio       [("attn",) x L]                      (frame-embedding inputs)
  hybrid      [("rglru","rglru","local_attn") x 12, ("rglru","rglru") x 1]
  ssm         [("mlstm","slstm") x L/2]

Block types: attn | attn_moe | local_attn | cross | rglru | mlstm | slstm.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

Stage = Tuple[Tuple[str, ...], int]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None  # default d_model // n_heads
    stages: Optional[Tuple[Stage, ...]] = None  # derived if None

    # attention
    rope_theta: float = 500_000.0
    window: int = 2048  # local attention window (hybrid family)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    shared_expert: bool = False  # llama4-style always-on expert
    moe_d_ff: Optional[int] = None  # per-expert hidden (defaults to d_ff)
    moe_period: int = 1  # every Nth layer is MoE (llama4 interleaves dense/MoE)

    # VLM
    cross_attn_period: int = 5  # every Nth layer is cross-attention
    n_image_tokens: int = 1601  # stub vision tower output length

    # hybrid (RG-LRU)
    lru_width: Optional[int] = None  # defaults to d_model
    conv_width: int = 4

    # ssm (xLSTM)
    mlstm_proj_factor: float = 2.0

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"  # "int8": quantized KV cache (beyond-paper)
    norm_eps: float = 1e-6

    # inputs: "tokens" or "embeddings" (modality-stub archs)
    input_kind: str = "tokens"

    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.lru_width is None:
            object.__setattr__(self, "lru_width", self.d_model)
        if self.moe_d_ff is None and self.n_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.stages is None:
            object.__setattr__(self, "stages", self._derive_stages())

    def _derive_stages(self) -> Tuple[Stage, ...]:
        L = self.n_layers
        if self.family in ("dense", "audio"):
            return ((("attn",), L),)
        if self.family == "moe":
            if self.moe_period > 1:
                p = self.moe_period
                if L % p:
                    raise ValueError(f"moe layers {L} must divide by period {p}")
                return ((("attn",) * (p - 1) + ("attn_moe",), L // p),)
            return ((("attn_moe",), L),)
        if self.family == "vlm":
            p = self.cross_attn_period
            if L % p:
                raise ValueError(f"vlm layers {L} must divide by period {p}")
            return ((("attn",) * (p - 1) + ("cross",), L // p),)
        if self.family == "hybrid":
            # Griffin 1:2 — repeat (rglru, rglru, local_attn); remainder rglru
            full, rem = divmod(L, 3)
            stages: list[Stage] = [(("rglru", "rglru", "local_attn"), full)]
            if rem:
                stages.append((("rglru",) * rem, 1))
            return tuple(stages)
        if self.family == "ssm":
            if L % 2:
                raise ValueError("ssm family expects even layer count")
            return ((("mlstm", "slstm"), L // 2),)
        raise ValueError(f"unknown family {self.family}")

    # ---- dtype helpers -------------------------------------------------
    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: no full-attention block anywhere."""
        return all(
            b in ("rglru", "local_attn", "mlstm", "slstm")
            for pattern, _ in self.stages
            for b in pattern
        )

    def block_counts(self) -> dict:
        counts: dict = {}
        for pattern, repeat in self.stages:
            for b in pattern:
                counts[b] = counts.get(b, 0) + repeat
        return counts

    # ---- parameter census (for roofline MODEL_FLOPS = 6·N·D) ----------
    def param_count(self, active_only: bool = False) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab
        n = V * D  # embed
        n += D * V  # lm head
        n += D  # final norm
        counts = self.block_counts()
        attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
        mlp = 3 * D * F
        for b, c in counts.items():
            if b in ("attn", "local_attn", "cross"):
                n += c * (attn + mlp + 2 * D)
                if b == "cross":
                    n += c * 0  # kv from image embeddings, same proj sizes
            elif b == "attn_moe":
                Fe = self.moe_d_ff
                e_active = self.top_k if active_only else self.n_experts
                n += c * (attn + 2 * D + D * self.n_experts)
                n += c * (3 * D * Fe * e_active)
                if self.shared_expert:
                    n += c * 3 * D * F
            elif b == "rglru":
                W = self.lru_width
                n += c * (2 * D * W + self.conv_width * W + 2 * W * W + W + W * D)
                n += c * (mlp + 2 * D)
            elif b == "mlstm":
                inner = int(self.d_model * self.mlstm_proj_factor)
                n += c * (2 * D * inner + 3 * inner * inner + 2 * inner * 4 + inner * D + D)
            elif b == "slstm":
                nh = self.n_heads
                dh = D // nh
                n += c * (4 * D * D + 4 * nh * dh * dh + D * D + D)
        return int(n)


# ---------------------------------------------------------------------------
# Input shape sets (assigned): every LM shape is (seq_len, global_batch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        n_layers={"vlm": cfg.cross_attn_period, "hybrid": 5, "ssm": 2}.get(
            cfg.family, 2
        ),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        rope_theta=cfg.rope_theta,
        window=16,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        shared_expert=cfg.shared_expert,
        moe_d_ff=64 if cfg.n_experts else None,
        cross_attn_period=cfg.cross_attn_period,
        n_image_tokens=8,
        lru_width=64,
        input_kind=cfg.input_kind,
        param_dtype="float32",
        compute_dtype="float32",
    )
    base.update(overrides)
    return ModelConfig(**base)
