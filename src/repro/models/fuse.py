"""Decode weight-prep: fuse per-block QKV and gate/up projections.

One-time tree surgery applied by the serving engine (``Engine(fuse=True)``,
the default): for every self-attention block the three ``wq``/``wk``/``wv``
leaves are replaced by one output-concatenated ``wqkv`` leaf, and every plain
SwiGLU MLP's ``w_gate``/``w_up`` pair by ``w_gate_up``. ``models.layers``
detects the fused keys and issues ONE projection kernel pass (packed BCQ:
:func:`repro.kernels.bcq_mm_fused.bcq_mm_fused`; dense: one XLA matmul) per
activation instead of N — the decode fast path of DESIGN.md §2.3.

Rules:
- cross-attention blocks keep ``wk``/``wv`` unfused (they project the image
  memory, not the token stream, so there is no shared activation to fuse);
- QuantizedTensor leaves fuse only when ``(k, q, g)`` and scale dtype agree
  (always true under a per-sublayer-type :class:`QuantPolicy`); mismatches
  and mixed dense/quantized triples are left untouched — the unfused layer
  path still works;
- MoE expert banks keep their own routing path (``router`` present → skipped);
- the fused tree's total parameter bytes equal the unfused tree's, so
  ``quantized_bytes`` reporting is stable across fusion. NOTE:
  ``jnp.concatenate`` materialises new buffers — the unfused projections are
  only freed once the caller drops its reference to the input tree (the
  serving launcher rebinds; keep both alive only if you need both layouts).

Training params are never fused: ``init_params`` emits the unfused layout and
checkpoints stay in it — fusion is a serving-time view, re-derived per engine.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp

from repro.core.formats import get_format
from repro.core.qtensor import QuantizedTensor, fuse_tensors
from repro.models.config import ModelConfig

_FUSABLE_ATTN = ("attn", "attn_moe", "local_attn")


def _fuse_leaves(leaves: Sequence) -> Optional[object]:
    """Fuse N projection leaves along the output dim, or None if not fusable."""
    if any(leaf is None for leaf in leaves):
        return None
    if all(isinstance(leaf, QuantizedTensor) for leaf in leaves):
        # the format's `fuse` capability gates the fused-kernel path: leaves
        # must share one registered format, and that format must support
        # output-dim fusion (mixed-format triples keep the per-projection path)
        if len({leaf.fmt for leaf in leaves}) != 1:
            return None
        if not get_format(leaves[0].fmt).supports_fuse:
            return None
        try:
            return fuse_tensors(leaves)
        except ValueError:
            return None
    if any(isinstance(leaf, QuantizedTensor) for leaf in leaves):
        return None  # mixed dense/quantized: no shared kernel to fuse into
    shapes = {leaf.shape[:-1] for leaf in leaves}
    dtypes = {leaf.dtype for leaf in leaves}
    if len(shapes) != 1 or len(dtypes) != 1:
        return None
    return jnp.concatenate(list(leaves), axis=-1)


def _fuse_attn(attn: dict) -> dict:
    fused = _fuse_leaves([attn.get("wq"), attn.get("wk"), attn.get("wv")])
    if fused is None:
        return attn
    out = {k: v for k, v in attn.items() if k not in ("wq", "wk", "wv")}
    out["wqkv"] = fused
    return out


def _fuse_mlp(mlp: dict) -> dict:
    if "router" in mlp or "w_gate" not in mlp or "w_up" not in mlp:
        return mlp
    fused = _fuse_leaves([mlp["w_gate"], mlp["w_up"]])
    if fused is None:
        return mlp
    out = {k: v for k, v in mlp.items() if k not in ("w_gate", "w_up")}
    out["w_gate_up"] = fused
    return out


def fuse_decode_projections(cfg: ModelConfig, params: dict) -> dict:
    """Return a params tree with QKV / gate-up leaves output-fused for decode."""
    stages = []
    for si, (pattern, _) in enumerate(cfg.stages):
        stage_p = dict(params["stages"][si])
        for bi, btype in enumerate(pattern):
            bp = dict(stage_p[f"b{bi}"])
            if btype in _FUSABLE_ATTN and "attn" in bp:
                bp["attn"] = _fuse_attn(bp["attn"])
            if "mlp" in bp and isinstance(bp["mlp"], dict):
                bp["mlp"] = _fuse_mlp(bp["mlp"])
            stage_p[f"b{bi}"] = bp
        stages.append(stage_p)
    out = dict(params)
    out["stages"] = tuple(stages)
    return out
