"""Model assembly: init / forward for every architecture family.

Layers are stacked per *stage* (see ``config.py``) and applied with
``jax.lax.scan`` so the HLO stays one-superblock-sized regardless of depth —
essential for 100-layer dry-run compiles and for remat during training.

One ``forward()`` serves train, prefill and decode:
  train    cache=None                       → logits (B, S, V)
  prefill  cache=init_cache(...), pos=0     → logits (B, 1, V) [last position], cache
  decode   cache=filled, pos=cur_len        → logits (B, 1, V), cache
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ops import linear
from repro.models import layers as L
from repro.parallel.ctx import all_gather_cols, constrain_tokens
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models.config import ModelConfig

Array = jax.Array

ATTN_BLOCKS = ("attn", "attn_moe", "local_attn", "cross")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, btype: str) -> dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = {"ln1": jnp.ones((d,), cfg.pdtype)}
    if btype in ATTN_BLOCKS:
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ln2"] = jnp.ones((d,), cfg.pdtype)
        if btype == "attn_moe":
            p["mlp"] = M.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
    elif btype == "rglru":
        p["mix"] = R.init_rglru(ks[0], cfg)
        p["ln2"] = jnp.ones((d,), cfg.pdtype)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif btype == "mlstm":
        p["mix"] = R.init_mlstm(ks[0], cfg)
    elif btype == "slstm":
        p["mix"] = R.init_slstm(ks[0], cfg)
    else:
        raise ValueError(f"unknown block type {btype}")
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.stages) + 3)
    stages = []
    for si, (pattern, repeat) in enumerate(cfg.stages):
        bkeys = jax.random.split(keys[si], len(pattern))
        stage_p = {}
        for bi, btype in enumerate(pattern):
            rkeys = jax.random.split(bkeys[bi], repeat)
            stage_p[f"b{bi}"] = jax.vmap(
                lambda k, bt=btype: init_block(k, cfg, bt)
            )(rkeys)
        stages.append(stage_p)
    params = {
        "stages": tuple(stages),
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
        "lm_head": L._dense_init(keys[-1], cfg.d_model, cfg.vocab, cfg.pdtype),
    }
    if cfg.input_kind == "tokens":
        params["embed"] = (
            jax.random.normal(keys[-2], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.pdtype)
    return params


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def init_block_cache(cfg: ModelConfig, btype: str, batch: int, s_max: int, dtype):
    hkv, dh, w = cfg.n_kv_heads, cfg.d_head, cfg.lru_width
    nh = cfg.n_heads
    if btype in ("attn", "attn_moe", "local_attn"):
        s_eff = min(s_max, cfg.window) if btype == "local_attn" else s_max
        shape = (batch, s_eff, hkv, dh)
        if cfg.kv_cache_dtype == "int8":
            # beyond-paper: the paper quantizes the weight stream; at batched
            # decode shapes the KV cache dominates HBM bytes — store it int8
            # with one dynamic scale per (token, head)
            return {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros((batch, s_eff, hkv), jnp.float32),
                "v_scale": jnp.zeros((batch, s_eff, hkv), jnp.float32),
            }
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if btype == "cross":
        shape = (batch, cfg.n_image_tokens, hkv, dh)
        return {"k_img": jnp.zeros(shape, dtype), "v_img": jnp.zeros(shape, dtype)}
    if btype == "rglru":
        return {
            "h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        }
    if btype == "mlstm":
        inner = int(cfg.d_model * cfg.mlstm_proj_factor)
        dhi = inner // nh
        return {
            "c": jnp.zeros((batch, nh, dhi, dhi), jnp.float32),
            "n": jnp.zeros((batch, nh, dhi), jnp.float32),
            "m": jnp.full((batch, nh), -jnp.inf, jnp.float32),
        }
    if btype == "slstm":
        dhd = cfg.d_model // nh
        z = jnp.zeros((batch, nh, dhd), jnp.float32)
        return {"h": z, "c": z, "n": z + 1e-6, "m": z - jnp.inf}
    raise ValueError(btype)


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=None) -> dict:
    """Stacked (per-stage, per-pattern-position) decoding state."""
    dtype = dtype or cfg.cdtype

    def stacked(btype, repeat):
        one = init_block_cache(cfg, btype, batch, s_max, dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (repeat,) + x.shape), one)

    stages = tuple(
        {f"b{bi}": stacked(bt, repeat) for bi, bt in enumerate(pattern)}
        for pattern, repeat in cfg.stages
    )
    return {"stages": stages}


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def apply_block(
    p: dict,
    cfg: ModelConfig,
    btype: str,
    h: Array,
    positions: Array,
    cache: Optional[dict],
    pos: Optional[Array],
    image_emb: Optional[Array],
    chunked: bool = False,
    collect: bool = False,
) -> Tuple[Array, Optional[dict], Array]:
    """Returns (h, new_cache, aux_loss).

    ``chunked``/``collect`` implement the speculative-verify contract
    (DESIGN.md §5): multi-token decode against a filled cache, with recurrent
    state returned as per-step snapshot stacks instead of finals."""
    aux = jnp.float32(0.0)
    if btype in ATTN_BLOCKS:
        window = cfg.window if btype == "local_attn" else 0
        kv_override = None
        new_cache = cache
        if btype == "cross":
            if cache is not None and pos is not None and image_emb is None:
                # decode: reuse cached projected image memory
                kv_override = (cache["k_img"], cache["v_img"])
            else:
                b, n, _ = image_emb.shape
                k_img = linear(image_emb, p["attn"]["wk"]).reshape(
                    b, n, cfg.n_kv_heads, cfg.d_head
                )
                v_img = linear(image_emb, p["attn"]["wv"]).reshape(
                    b, n, cfg.n_kv_heads, cfg.d_head
                )
                kv_override = (k_img, v_img)
                if cache is not None:
                    new_cache = {
                        "k_img": k_img.astype(cache["k_img"].dtype),
                        "v_img": v_img.astype(cache["v_img"].dtype),
                    }
            r, _ = L.attention(
                p["attn"], cfg, L.rmsnorm(p["ln1"], h), positions,
                kv_override=kv_override,
            )
        else:
            r, new_cache = L.attention(
                p["attn"], cfg, L.rmsnorm(p["ln1"], h), positions,
                cache=cache, pos=pos, window=window, chunked=chunked,
            )
        h = h + r
        x2 = L.rmsnorm(p["ln2"], h)
        if btype == "attn_moe":
            y, aux = M.moe_apply(p["mlp"], cfg, x2)
            h = h + y
        else:
            h = h + L.mlp_swiglu(p["mlp"], x2)
        return h, new_cache, aux

    if btype == "rglru":
        r, new_cache = R.rglru_block(
            p["mix"], cfg, L.rmsnorm(p["ln1"], h), cache, collect=collect
        )
        h = h + r
        h = h + L.mlp_swiglu(p["mlp"], L.rmsnorm(p["ln2"], h))
        return h, new_cache, aux

    fn = {"mlstm": R.mlstm_block, "slstm": R.slstm_block}[btype]
    r, new_cache = fn(p["mix"], cfg, L.rmsnorm(p["ln1"], h), cache, collect=collect)
    return h + r, new_cache, aux


def _apply_stage(
    stage_params: dict,
    cfg: ModelConfig,
    pattern: Tuple[str, ...],
    h: Array,
    positions: Array,
    stage_cache: Optional[dict],
    pos: Optional[Array],
    image_emb: Optional[Array],
    remat: bool,
    chunked: bool = False,
    collect: bool = False,
) -> Tuple[Array, Optional[dict], Array]:
    def body(carry, xs):
        hh, aux = carry
        layer_p, layer_c = xs
        new_c = {}
        for bi, btype in enumerate(pattern):
            c_in = None if layer_c is None else layer_c[f"b{bi}"]
            hh, c_out, a = apply_block(
                layer_p[f"b{bi}"], cfg, btype, hh, positions, c_in, pos, image_emb,
                chunked=chunked, collect=collect,
            )
            aux = aux + a
            if layer_c is not None:
                new_c[f"b{bi}"] = c_out if c_out is not None else c_in
        return (hh, aux), (new_c if layer_c is not None else None)

    if remat:
        body = jax.checkpoint(body)

    (h, aux), new_cache = jax.lax.scan(
        body, (h, jnp.float32(0.0)), (stage_params, stage_cache)
    )
    return h, new_cache, aux


def forward(
    cfg: ModelConfig,
    params: dict,
    *,
    tokens: Optional[Array] = None,
    embeddings: Optional[Array] = None,
    image_emb: Optional[Array] = None,
    positions: Optional[Array] = None,
    cache: Optional[dict] = None,
    pos: Optional[Array] = None,
    logits_mode: str = "all",  # "all" | "last"
    remat: bool = False,
    chunked_decode: bool = False,
    collect_states: bool = False,
) -> Tuple[Array, Optional[dict], Array]:
    """Run the decoder. Returns (logits f32, new_cache or None, aux_loss).

    ``chunked_decode=True`` feeds ``s > 1`` fresh tokens *mid-sequence*
    against a filled cache (speculative verify): every token attends the
    whole cache plus its intra-chunk predecessors under per-token positional
    masks, instead of the fresh-sequence-only prefill attention.
    ``collect_states=True`` additionally makes recurrent blocks return their
    state stacked over the chunk's time axis (leading ``S`` after the layer
    axis) so a rollback can select the snapshot at the commit index — the
    cache-rewind contract of DESIGN.md §5 / models/layers.py."""
    if tokens is not None:
        h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
        b, s = tokens.shape
    else:
        h = embeddings.astype(cfg.cdtype)
        b, s, _ = embeddings.shape
    if positions is None:
        if pos is None:
            base = jnp.zeros((b, 1), jnp.int32)
        elif jnp.ndim(pos) == 1:
            base = pos[:, None]  # per-slot positions (serving decode)
        else:
            base = jnp.full((b, 1), pos)
        positions = base + jnp.arange(s)[None, :]

    new_stages = []
    aux_total = jnp.float32(0.0)
    h = constrain_tokens(h)
    for si, (pattern, _) in enumerate(cfg.stages):
        sc = None if cache is None else cache["stages"][si]
        h, nsc, aux = _apply_stage(
            params["stages"][si], cfg, pattern, h, positions, sc, pos, image_emb,
            remat, chunked=chunked_decode, collect=collect_states,
        )
        h = constrain_tokens(h)  # re-anchor: keep batch on dp at stage edges
        aux_total = aux_total + aux
        new_stages.append(nsc)

    h = L.rmsnorm(params["final_norm"], h)
    if logits_mode == "last":
        h = h[:, -1:]
    # lm_head is column-parallel under TP (vocab shards): gather the full
    # (B, S, V) logits so sampling sees every token; no-op otherwise
    logits = all_gather_cols(linear(h, params["lm_head"], out_dtype=jnp.float32))
    new_cache = None if cache is None else {"stages": tuple(new_stages)}
    return logits, new_cache, aux_total
