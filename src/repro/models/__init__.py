"""Decoder-model zoo: dense GQA / MoE / VLM / audio / RG-LRU hybrid / xLSTM."""

from repro.models.config import SHAPES, ModelConfig, ShapeConfig, reduced
from repro.models.fuse import fuse_decode_projections
from repro.models.model import forward, init_cache, init_params

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "forward",
    "fuse_decode_projections",
    "init_cache",
    "init_params",
    "reduced",
]
