"""Decoder-model zoo: dense GQA / MoE / VLM / audio / RG-LRU hybrid / xLSTM."""

from repro.models.config import SHAPES, ModelConfig, ShapeConfig, reduced
from repro.models.model import forward, init_cache, init_params

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "forward",
    "init_cache",
    "init_params",
    "reduced",
]
