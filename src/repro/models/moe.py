"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Top-k routing (OLMoE: 64e/top-8; Llama-4-Maverick: 128e/top-1 + shared expert).
Dispatch is the production sort-and-bucket scheme (MegaBlocks/MaxText style):
token→expert assignments are sorted by expert id, each expert processes a
fixed-capacity contiguous buffer (grouped einsum → EP-shardable on the
"model"/expert axis), and outputs scatter back weighted by router probabilities.
Tokens past capacity are dropped (capacity_factor controls slack) — FLOPs equal
active-expert FLOPs × capacity_factor, which keeps the roofline honest.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.qtensor import QuantizedTensor
from repro.kernels.ops import linear
from repro.models.config import ModelConfig
from repro.models.layers import _dense_init, init_mlp, mlp_swiglu
from repro.parallel.compat import get_abstract_mesh, mesh_axis_names_sizes, shard_map

Array = jax.Array


def _w(w, dtype):
    """Expert weights may be packed BCQ — dequantize (register-level on TPU,
    see kernels/bcq_mm.py; plain jnp here, in the compute dtype) before the
    grouped einsum."""
    return w.dequantize(dtype=dtype) if isinstance(w, QuantizedTensor) else w


def init_moe(key, cfg: ModelConfig) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], d, e, jnp.float32),
        "w_gate": _dense_init(ks[1], d, e * f, cfg.pdtype).reshape(d, e, f).transpose(1, 0, 2),
        "w_up": _dense_init(ks[2], d, e * f, cfg.pdtype).reshape(d, e, f).transpose(1, 0, 2),
        "w_down": _dense_init(ks[3], f, e * d, cfg.pdtype).reshape(f, e, d).transpose(1, 0, 2),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], cfg)
    return p


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to sublane multiple


def _ambient_mesh():
    try:
        return get_abstract_mesh()
    except Exception:
        return None


def moe_apply(p: dict, cfg: ModelConfig, x: Array) -> Tuple[Array, Array]:
    """x: (B, S, D) → ((B, S, D), load_balance_loss scalar).

    Under an ambient mesh with a "model" axis, dispatch takes the shard_map
    path (:func:`_moe_apply_sharded`): tokens are bucketed LOCALLY on each
    chip for that chip's expert shard, experts compute local-only, and ONE
    (T_local, D) psum over `model` combines the expert groups. The global
    sort-dispatch under GSPMD materialised an (T·k, D) gather/scatter AND
    all-reduced the full combine tensor — measured 68.7 GB/layer of collective
    on olmoe prefill_32k (EXPERIMENTS.md §Perf, cell B).
    """
    mesh = _ambient_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        sizes = dict(zip(*mesh_axis_names_sizes(mesh)))
        m = sizes["model"]
        dp = tuple(a for a in ("pod", "data") if a in sizes)
        n_dp = 1
        for a in dp:
            n_dp *= sizes[a]
        b, s, _ = x.shape
        if cfg.n_experts % m == 0 and b % max(n_dp, 1) == 0:
            return _moe_apply_sharded(p, cfg, x, mesh, dp or None)
    return _moe_apply_global(p, cfg, x)


def _moe_apply_sharded(
    p: dict, cfg: ModelConfig, x: Array, mesh, dp
) -> Tuple[Array, Array]:
    """shard_map MoE: local bucket → local expert GEMM → single psum combine.

    Per-shard capacity is ``capacity_factor · T_local · k / E`` (statistically
    equivalent to the global capacity; drops may differ at shard boundaries —
    standard in production EP systems)."""
    from jax.sharding import PartitionSpec as P

    e = cfg.n_experts
    ew_spec = P("model", None, None)

    def body(xb, router, wg, wu, wd):
        bl, sl, d = xb.shape
        t = bl * sl
        xf = xb.reshape(t, d)
        e_loc = wg.shape[0]
        j = jax.lax.axis_index("model")
        lo = j * e_loc

        logits = jnp.dot(
            xf, router.astype(jnp.float32), preferred_element_type=jnp.float32
        )  # (T_loc, E) — router is replicated and tiny
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        flat_e = top_e.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t), cfg.top_k)
        flat_p = top_p.reshape(-1)
        # local expert id; non-local assignments → sacrificial bucket e_loc
        le = flat_e - lo
        local = (le >= 0) & (le < e_loc)
        le = jnp.where(local, le, e_loc)
        order = jnp.argsort(le, stable=True)
        se, st, sp = le[order], flat_t[order], flat_p[order]

        cap = _capacity(cfg, t)
        counts = jnp.bincount(le, length=e_loc + 1)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(t * cfg.top_k) - starts[se]
        keep = (rank < cap) & (se < e_loc)
        slot = jnp.where(keep, rank, cap)

        buf = jnp.zeros((e_loc + 1, cap + 1, d), xb.dtype)
        buf = buf.at[se, slot].set(xf[st].astype(xb.dtype))[:e_loc, :cap]

        gate = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", buf, wg, preferred_element_type=jnp.float32)
        )
        up = jnp.einsum("ecd,edf->ecf", buf, wu, preferred_element_type=jnp.float32)
        out_buf = jnp.einsum(
            "ecf,efd->ecd", (gate * up).astype(xb.dtype), wd,
            preferred_element_type=jnp.float32,
        )

        contrib = out_buf[
            jnp.minimum(se, e_loc - 1), jnp.minimum(slot, cap - 1)
        ] * (sp * keep)[:, None]
        partial = jnp.zeros((t, d), jnp.float32).at[st].add(contrib)
        out = jax.lax.psum(partial, "model")  # combine expert groups — ONE psum

        aux = load_balance_loss(logits, top_e, e)
        aux = jax.lax.pmean(aux, "model")
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return out.reshape(bl, sl, d).astype(xb.dtype), aux

    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),  # x: batch over dp, replicated over model
            P(None, None),  # router replicated
            ew_spec, ew_spec, ew_spec,  # experts over model (EP)
        ),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(
        x, p["router"],
        _w(p["w_gate"], x.dtype), _w(p["w_up"], x.dtype), _w(p["w_down"], x.dtype),
    )

    if cfg.shared_expert:
        out = out + mlp_swiglu(p["shared"], x)
    return out, aux


def _moe_apply_global(p: dict, cfg: ModelConfig, x: Array) -> Tuple[Array, Array]:
    """Reference global sort-dispatch (single-device / no-mesh fallback)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, t)

    router_logits = linear(xf, p["router"], out_dtype=jnp.float32)  # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renormalise

    # flatten assignments and sort by expert id
    flat_e = top_e.reshape(-1)  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(t), k)  # token index per assignment
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]

    # rank within expert group → capacity slot; drop overflow
    counts = jnp.bincount(flat_e, length=e)  # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)  # overflow lands in a sacrificial slot

    # scatter tokens into per-expert buffers (E, C+1, D); slice off overflow slot
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[se, slot].set(xf[st].astype(x.dtype))[:, :cap]

    # grouped expert SwiGLU (EP: expert axis shards on "model"); bf16 inputs,
    # f32 accumulation via preferred_element_type (no f32 weight copies)
    wg = _w(p["w_gate"], x.dtype).astype(x.dtype)
    wu = _w(p["w_up"], x.dtype).astype(x.dtype)
    wd = _w(p["w_down"], x.dtype).astype(x.dtype)
    gate = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, wg, preferred_element_type=jnp.float32)
    )
    up = jnp.einsum("ecd,edf->ecf", buf, wu, preferred_element_type=jnp.float32)
    out_buf = jnp.einsum(
        "ecf,efd->ecd", (gate * up).astype(x.dtype), wd,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)

    # gather back, weight by router prob, combine over k assignments
    contrib = out_buf[se, jnp.minimum(slot, cap - 1)] * (sp * keep)[:, None].astype(
        x.dtype
    )  # (T*K, D); dropped assignments are zero-weighted
    out = jnp.zeros((t, d), x.dtype).at[st].add(contrib)

    if cfg.shared_expert:
        out = out + mlp_swiglu(p["shared"], x).reshape(t, d)
    aux = load_balance_loss(router_logits, top_e, e)
    return out.reshape(b, s, d), aux


def load_balance_loss(router_logits: Array, top_e: Array, n_experts: int) -> Array:
    """Switch-style auxiliary loss (fraction·probability product)."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    me = probs.mean(0)
    one_hot = jax.nn.one_hot(top_e[:, 0], n_experts)
    ce = one_hot.mean(0)
    return n_experts * jnp.sum(me * ce)
