"""Transformer building blocks (pure JAX, pytree params).

Every weight application goes through :func:`repro.kernels.ops.linear`, so any
leaf may be a dense array *or* a packed :class:`QuantizedTensor` of any
registered quantization format (``core/formats.py``: BCQ, uniform int-q, the
dequant baseline — dispatched per leaf through ``ops.qmatmul``) — the paper's
technique is a per-layer switch, not a separate model, and formats mix freely
within one forward (DESIGN.md §2.4).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qtensor import QuantizedTensor
from repro.kernels.ops import linear, linear_fused
from repro.models.config import ModelConfig
from repro.parallel.ctx import constrain_decode_q, constrain_qkv, psum_partial

Array = jax.Array
NEG_INF = jnp.finfo(jnp.float32).min

# ---------------------------------------------------------------------------
# cache-rewind contract (speculative decoding, DESIGN.md §5)
#
# Speculative verification writes q'-draft tokens into the cache ahead of
# acceptance; rejected tokens must be rolled back. Every cache leaf falls in
# exactly one class, identified by its name:
#
# - POSITIONAL (k, v, k_scale, v_scale): writes land at absolute positions.
#   Rewind = reset the position counter; masked reads (`slot <= pos`, ring
#   band) guarantee rows beyond the counter are never attended, and the next
#   chunk overwrites them before they re-enter the valid range. Ring-window
#   buffers are the one exception: once wrapped, a write at position p
#   *clobbers* the live entry at p - s_max, so speculative chunks snapshot the
#   rows they will write and restore the rejected ones
#   (infer/speculative.py::snapshot_rows/restore_rows).
# - RECURRENT (h, conv, c, n, m): RG-LRU/xLSTM state folds every consumed
#   token irreversibly — it cannot be re-masked after the fact. Rewind
#   requires per-step snapshots: `forward(..., collect_states=True)` makes the
#   recurrent blocks return their state stacked over the chunk's time axis
#   (leading axis S), and rollback selects the entry at the commit index.
# - STATIC (k_img, v_img): projected image memory, never written during
#   decode; rewind is a no-op.
# ---------------------------------------------------------------------------

POSITIONAL_CACHE_LEAVES = frozenset({"k", "v", "k_scale", "v_scale"})
RECURRENT_CACHE_LEAVES = frozenset({"h", "conv", "c", "n", "m"})
STATIC_CACHE_LEAVES = frozenset({"k_img", "v_img"})


def _cache_leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", last)))


# ---------------------------------------------------------------------------
# prefix-cache install/commit ops (infer/prefix_cache.py, DESIGN.md §12)
#
# Prefix reuse applies the same leaf taxonomy as the rewind contract above,
# but at admission time instead of rollback time:
#
# - POSITIONAL: a committed prefix of length L is exactly rows [0, L) along
#   axis 2 (positions are absolute; a fresh cache has no wrap). Commit
#   gathers those rows; install writes them back into a fresh zeroed cache
#   and the suffix prefill resumes at pos=L.
# - RECURRENT: state folds tokens irreversibly, so a committed block carries
#   a snapshot of the state *at the block boundary* (captured from a
#   collect_states=True prefill); install overwrites the fresh zero state.
# - STATIC: projected image memory is prompt-independent — no-op (and the
#   prefix subsystem refuses VLM configs outright).
#
# All four functions keep the cache treedef: non-participating leaves become
# (0,)-shaped placeholders on gather (the snapshot_rows idiom) and pass
# through untouched on install.
# ---------------------------------------------------------------------------


def gather_prefix_rows(cache, start, n: int):
    """POSITIONAL leaves → their ``n`` rows starting at ``start`` along axis 2
    (``(repeat, B, n, ...)``); every other leaf → an empty placeholder.
    ``start`` may be traced; the caller guarantees ``start + n <= s_eff``
    (the ring cap), so the dynamic slice never clamps."""

    def visit(path, leaf):
        if _cache_leaf_name(path) not in POSITIONAL_CACHE_LEAVES:
            return jnp.zeros((0,), jnp.int8)
        return jax.lax.dynamic_slice_in_dim(leaf, start, n, axis=2)

    return jax.tree_util.tree_map_with_path(visit, cache)


def install_prefix_rows(cache, rows):
    """Write gathered prefix rows into rows [0, L) of every POSITIONAL leaf
    of a *fresh* cache. ``rows`` may be zero-padded past the real prefix
    length: a fresh cache is all-zero there, so the padding writes are
    no-ops by value — which is what lets install shapes bucket without
    changing the cache contents."""

    def visit(path, leaf, rw):
        if _cache_leaf_name(path) not in POSITIONAL_CACHE_LEAVES:
            return leaf
        return jax.lax.dynamic_update_slice_in_dim(
            leaf, rw.astype(leaf.dtype), 0, axis=2
        )

    return jax.tree_util.tree_map_with_path(visit, cache, rows)


def snapshot_recurrent(cache):
    """RECURRENT leaves verbatim, everything else an empty placeholder — the
    boundary-state payload a committed prefix block carries."""

    def visit(path, leaf):
        if _cache_leaf_name(path) not in RECURRENT_CACHE_LEAVES:
            return jnp.zeros((0,), jnp.int8)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, cache)


def install_recurrent(cache, snap):
    """Overwrite RECURRENT leaves from a boundary snapshot; positional and
    static leaves pass through untouched."""

    def visit(path, leaf, sn):
        if _cache_leaf_name(path) not in RECURRENT_CACHE_LEAVES:
            return leaf
        return sn.astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(visit, cache, snap)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense_init(key, k: int, o: int, dtype) -> Array:
    scale = 1.0 / jnp.sqrt(k)
    return (jax.random.normal(key, (k, o), jnp.float32) * scale).astype(dtype)


def init_attention(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], cfg.d_model, cfg.q_dim, cfg.pdtype),
        "wk": _dense_init(ks[1], cfg.d_model, cfg.kv_dim, cfg.pdtype),
        "wv": _dense_init(ks[2], cfg.d_model, cfg.kv_dim, cfg.pdtype),
        "wo": _dense_init(ks[3], cfg.q_dim, cfg.d_model, cfg.pdtype),
    }


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], cfg.d_model, d_ff, cfg.pdtype),
        "w_up": _dense_init(ks[1], cfg.d_model, d_ff, cfg.pdtype),
        "w_down": _dense_init(ks[2], d_ff, cfg.d_model, cfg.pdtype),
    }


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def rmsnorm(w: Array, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(x.dtype)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (B, S, H, Dh); positions: (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _repeat_kv(kv: Array, n_rep: int) -> Array:
    """(B, S, Hkv, Dh) → (B, S, Hkv*n_rep, Dh) for GQA."""
    if n_rep == 1:
        return kv
    b, s, h, d = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """GQA-native softmax attention. q: (B,Sq,H,Dh); k,v: (B,Sk,Hkv,Dh) with
    H = G·Hkv; mask broadcastable to (..,Sq,Sk) or None.

    - No K/V head replication is ever materialised: queries are grouped
      (B,Sq,Hkv,G,Dh) and contracted against the raw Hkv heads (the repeated
      broadcast cost 64 GB/step on decode_32k before this).
    - Inputs stay in their native (bf16) dtype; accumulation is f32 via
      preferred_element_type — no materialised f32 Q/K/V copies.
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(dh))
    if mask is not None:
        logits = jnp.where(mask[..., None, :, :] if mask.ndim == 4 else mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def _local_attention_chunked(q: Array, k: Array, v: Array, window: int) -> Array:
    """Exact sliding-window causal attention in O(S·window).

    Standard chunking: split the sequence into window-sized chunks; each chunk
    attends to itself + the previous chunk under a banded causal mask.
    q, k, v: (B, S, H, Dh) with S % window == 0 (callers pad).
    """
    b, s, h, dh = q.shape
    w = window
    if s <= w:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        mask = (ki <= qi) & (ki > qi - w)
        return _sdpa(q, k, v, mask[None, None])
    if s % w:
        # pad at the end; padded keys are "future" for every real query → masked
        pad = w - s % w
        padded = [jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v)]
        return _local_attention_chunked(*padded, window)[:, :s]
    nc = s // w
    hkv = k.shape[2]
    g = h // hkv
    qc = q.reshape(b, nc, w, hkv, g, dh)
    kc = k.reshape(b, nc, w, hkv, dh)
    vc = v.reshape(b, nc, w, hkv, dh)
    # previous chunk (zeros before chunk 0)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kk = jnp.concatenate([kprev, kc], axis=2)  # (b, nc, 2w, hkv, dh)
    vv = jnp.concatenate([vprev, vc], axis=2)
    qi = jnp.arange(w)[:, None] + w  # query abs pos within the 2w key window
    ki = jnp.arange(2 * w)[None, :]
    mask = (ki <= qi) & (ki > qi - w)  # (w, 2w)
    first = jnp.arange(nc) == 0
    # chunk 0 must not see the zero-padded "previous" keys
    mask_c = mask[None] & ~(first[:, None, None] & (ki < w)[None])  # (nc, w, 2w)
    logits = jnp.einsum(
        "bnqhgd,bnkhd->bnhgqk", qc, kk, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(dh))
    logits = jnp.where(mask_c[None, :, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bnhgqk,bnkhd->bnqhgd", probs.astype(vv.dtype), vv,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, s, h, dh).astype(q.dtype)


Q_CHUNK = 2048  # query-block length for long-sequence causal attention


def _sdpa_qchunked(q: Array, k: Array, v: Array, chunk: int = Q_CHUNK) -> Array:
    """Causal attention scanned over query blocks: O(chunk·S) live logits.

    Full-S² logits at 32k seq are ~34 GB/device f32 — this bounds them to one
    (B, H, chunk, S) block at a time. Pure-XLA fallback for the TPU flash
    kernel; attention FLOPs remain full-S² masked (2× the causal-useful work —
    noted in the roofline methodology).
    """
    b, s, h, dh = q.shape
    if s % chunk:
        return _sdpa(q, k, v, causal_mask(s, s))
    nc = s // chunk
    qc = jnp.moveaxis(q.reshape(b, nc, chunk, h, dh), 1, 0)  # (nc, b, chunk, h, dh)

    kpos = jnp.arange(s)

    def body(_, inp):
        qblk, i = inp
        qpos = i * chunk + jnp.arange(chunk)
        mask = (kpos[None, :] <= qpos[:, None])[None, None]  # (1,1,chunk,s)
        out = _sdpa(qblk, k, v, mask)
        return None, out

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dh)


LONG_SEQ_THRESHOLD = 8192


def causal_mask(sq: int, sk: int, window: int = 0) -> Array:
    """(1,1,sq,sk) boolean; window>0 restricts to a local band."""
    qi = jnp.arange(sq)[:, None] + (sk - sq)
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window:
        m &= ki > qi - window
    return m[None, None]


# ---------------------------------------------------------------------------
# attention block (self / local / cross)
# ---------------------------------------------------------------------------


def attention(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    positions: Array,
    *,
    cache: Optional[dict] = None,
    pos: Optional[Array] = None,
    window: int = 0,
    kv_override: Optional[Tuple[Array, Array]] = None,
    chunked: bool = False,
) -> Tuple[Array, Optional[dict]]:
    """GQA attention. Returns (out, new_cache).

    Modes
    -----
    train            cache=None                 full / chunked-local causal attn
    prefill          cache=empty, pos=0         as train, but also fills the cache
    decode           cache=filled, pos=cur_len  x is (B, 1, D), attends cache
    chunked decode   chunked=True, cache=filled x is (B, s, D) *mid-sequence*:
                     the s new tokens attend the whole cache + themselves
                     (speculative verify — DESIGN.md §5); `pos` may be a
                     scalar or a per-row (B,) array
    cross            kv_override=(k_mem, v_mem) attends provided memory, no cache
    """
    b, s, _ = x.shape
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if "wqkv" in p:
        # decode fast path: output-fused QKV weights (models.fuse) — one kernel
        # pass / one activation read for all three projections (DESIGN.md §2.3)
        q, k, v = linear_fused(x, p["wqkv"], (cfg.q_dim, cfg.kv_dim, cfg.kv_dim))
    else:
        q = linear(x, p["wq"])
        k = v = None
    q = rope(q.reshape(b, s, cfg.n_heads, cfg.d_head), positions, cfg.rope_theta)

    if kv_override is not None:
        k_mem, v_mem = kv_override
        out = _sdpa(q, k_mem, v_mem, None)
        # wo is row-parallel under TP: local heads contract to a partial (B,S,D)
        return psum_partial(linear(out.reshape(b, s, cfg.q_dim), p["wo"])), cache

    if k is None:
        k = linear(x, p["wk"])
        v = linear(x, p["wv"])
    k = rope(k.reshape(b, s, cfg.n_kv_heads, cfg.d_head), positions, cfg.rope_theta)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)

    def _causal(qq, kk, vv):
        qq, kk, vv = constrain_qkv(qq, kk, vv)
        if s >= LONG_SEQ_THRESHOLD:
            return _sdpa_qchunked(qq, kk, vv)
        return _sdpa(qq, kk, vv, causal_mask(s, s))

    new_cache = None
    if cache is None:
        # train: no cache
        if window:
            out = _local_attention_chunked(q, k, v, window)
        else:
            out = _causal(q, k, v)
    elif s > 1 and not chunked:
        # prefill: compute attention over the fresh sequence, then write cache
        if window:
            out = _local_attention_chunked(q, k, v, window)
        else:
            out = _causal(q, k, v)
        new_cache = _cache_write(cache, k, v, pos, window)
    elif s > 1:
        # chunked decode (speculative verify): s fresh tokens at absolute
        # positions pos..pos+s-1 against a *filled* cache. All s rows are
        # written first, then every token attends the cache under a per-token
        # positional mask — the same slot layout a step-by-step decode reads,
        # so the unwrapped case is compute-identical to s single-token steps.
        # Ring ring-buffers additionally re-expose the entries the chunk's own
        # writes clobbered (live positions p - s_max once wrapped) as appended
        # snapshot keys with their original validity band.
        s_max_c = cache["k"].shape[1]
        pvec = pos if jnp.ndim(pos) == 1 else jnp.full((b,), pos, jnp.int32)
        snap = None
        if window:
            idx = (pvec[:, None] + jnp.arange(s)) % s_max_c  # (B, s) written slots
            snap_k = _gather_rows(cache["k"], idx)
            snap_v = _gather_rows(cache["v"], idx)
            if "k_scale" in cache:
                snap_k = _kv_dequantize(snap_k, _gather_rows(cache["k_scale"], idx), x.dtype)
                snap_v = _kv_dequantize(snap_v, _gather_rows(cache["v_scale"], idx), x.dtype)
            snap = (snap_k.astype(x.dtype), snap_v.astype(x.dtype))
        new_cache = _cache_write(cache, k, v, pos, window)
        ck, cv = new_cache["k"], new_cache["v"]
        if "k_scale" in new_cache:
            ck = _kv_dequantize(ck, new_cache["k_scale"], x.dtype)
            cv = _kv_dequantize(cv, new_cache["v_scale"], x.dtype)
        q = constrain_decode_q(q)
        qpos = pvec[:, None] + jnp.arange(s)  # (B, s) per-token absolute pos
        slot = jnp.arange(s_max_c)
        if window:
            stored = _ring_positions(slot[None, None, :], (pvec + s)[:, None, None], s_max_c)
            valid = (
                (stored >= 0)
                & (stored <= qpos[..., None])
                & (stored > qpos[..., None] - window)
            )  # (B, s, s_max)
            # clobbered entries: written slot j previously held position
            # qpos_j - s_max (if the ring had wrapped); in-band for earlier
            # tokens of this same chunk
            op = qpos - s_max_c  # (B, s) original position of snapshot row j
            valid_snap = (op[:, None, :] >= 0) & (
                op[:, None, :] > qpos[..., None] - window
            )  # (B, s_q, s_snap)
            valid = jnp.concatenate([valid, valid_snap], axis=-1)
            ck = jnp.concatenate([ck, snap[0]], axis=1)
            cv = jnp.concatenate([cv, snap[1]], axis=1)
        else:
            valid = slot[None, None, :] <= qpos[..., None]  # (B, s, s_max)
        out = _sdpa(q, ck, cv, valid[:, None])  # mask (B, 1, s, n_keys)
    else:
        # decode: single new token against the cache. The cache is Dh-sharded
        # on `model`; constrain q to match so the score einsum is a local
        # partial followed by a tiny all-reduce of (B,1,D) partials — NOT a
        # whole-cache all-gather (was 64 GB/step).
        #
        # `pos` may be a scalar (whole batch at one position — Engine.generate)
        # or a (B,) array (slot-batched serving: each batch row is an
        # independent request at its own position — infer/scheduler). The
        # per-row mask values are identical to the scalar case, so a slotted
        # decode reproduces solo decodes bit-for-bit per row.
        new_cache = _cache_write(cache, k, v, pos, window)
        ck, cv = new_cache["k"], new_cache["v"]
        if "k_scale" in new_cache:
            ck = _kv_dequantize(ck, new_cache["k_scale"], x.dtype)
            cv = _kv_dequantize(cv, new_cache["v_scale"], x.dtype)
        q = constrain_decode_q(q)
        s_max = ck.shape[1]
        slot = jnp.arange(s_max)
        if jnp.ndim(pos) == 0:
            if window:
                stored = _ring_positions(slot, pos + 1, s_max)
                valid = (stored >= 0) & (stored <= pos) & (stored > pos - window)
            else:
                valid = slot <= pos
            mask = valid[None, None, None, :]
        else:
            pb = pos[:, None]  # (B, 1)
            if window:
                stored = _ring_positions(slot[None, :], pb + 1, s_max)
                valid = (stored >= 0) & (stored <= pb) & (stored > pb - window)
            else:
                valid = slot[None, :] <= pb
            mask = valid[:, None, None, :]
        out = _sdpa(q, ck, cv, mask)
    # wo is row-parallel under TP (heads → q_dim local shards): psum the
    # partial sums; no-op outside a TP shard_map region
    out = psum_partial(linear(out.reshape(b, s, cfg.q_dim), p["wo"]))
    return out, new_cache


def _gather_rows(buf: Array, idx: Array) -> Array:
    """Per-row gather of cache rows: buf (B, s_max, ...), idx (B, n) → (B, n, ...)."""
    ix = idx.reshape(idx.shape + (1,) * (buf.ndim - 2))
    return jnp.take_along_axis(buf, ix, axis=1)


def _kv_quantize(x: Array):
    """(B, s, Hkv, Dh) → int8 codes + per-(token, head) scale (beyond-paper
    int8 KV cache; vLLM-style dynamic per-vector scaling)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)  # (B, s, Hkv)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, 1e-8)[..., None] * 127.0),
        -127, 127,
    ).astype(jnp.int8)
    return q, scale


def _kv_dequantize(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * (scale[..., None] / 127.0)).astype(dtype)


def _cache_write(cache: dict, k: Array, v: Array, pos: Array, window: int) -> dict:
    """Write s new K/V rows at absolute position `pos` (ring buffer if local).

    Scalar-start ``dynamic_update_slice`` wherever possible: gather-index
    scatters lower to whole-cache select/convert chains (measured 24 GB/step of
    cache round-trips on llama3.2-3b decode_32k — §Perf cell A). A cache-as-
    scan-carry variant with 5-D DUS was tried and REJECTED: XLA's copy
    insertion duplicates the whole carry whenever the loop body also READS a
    slice of it (measured 105 GB/step vs 15 GB for the xs/ys form).

    ``pos`` may also be a (B,) array (slot-batched serving decode and
    speculative verify chunks): each batch row writes its ``s`` fresh rows at
    its own position via a per-row DUS / ring scatter under vmap. That lowers
    to a batched scatter — costlier than the scalar-start form, accepted on
    the serving path where rows are independent requests by design.
    """
    ck, cv = cache["k"], cache["v"]
    s_max = ck.shape[1]
    s = k.shape[1]
    quantized = "k_scale" in cache
    if quantized:
        k, k_scale = _kv_quantize(k)
        v, v_scale = _kv_quantize(v)

    if jnp.ndim(pos) == 1:
        # per-row writes (slot-batched serving / speculative chunks): each
        # batch row writes its s fresh rows at its own position
        if s >= s_max:
            raise ValueError(
                f"per-row cache writes need s({s}) < s_max({s_max}) "
                "(whole-window overwrite is a lockstep-prefill-only path)"
            )
        if window and s > 1:
            # per-row partial ring fill (speculative verify on a ring buffer)
            idx = (pos[:, None] + jnp.arange(s)) % s_max  # (B, s)

            def set_rows(buf, new, ix):
                return buf.at[ix].set(new.astype(buf.dtype))

            write_b = jax.vmap(set_rows, in_axes=(0, 0, 0))
            out = {"k": write_b(ck, k, idx), "v": write_b(cv, v, idx)}
            if quantized:
                out["k_scale"] = write_b(cache["k_scale"], k_scale, idx)
                out["v_scale"] = write_b(cache["v_scale"], v_scale, idx)
            return out
        start_b = (pos % s_max if window else pos).astype(jnp.int32)

        def dus_row(buf, new, st):
            idxs = (st,) + (jnp.int32(0),) * (buf.ndim - 1)
            return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), idxs)

        write_b = jax.vmap(dus_row, in_axes=(0, 0, 0))
        out = {"k": write_b(ck, k, start_b), "v": write_b(cv, v, start_b)}
        if quantized:
            out["k_scale"] = write_b(cache["k_scale"], k_scale, start_b)
            out["v_scale"] = write_b(cache["v_scale"], v_scale, start_b)
        return out

    def dus(buf, new, start, rank4=True):
        new = new.astype(buf.dtype)
        st = start.astype(jnp.int32) if hasattr(start, "astype") else jnp.int32(start)
        zero = jnp.int32(0)
        idxs = (zero, st, zero, zero) if rank4 else (zero, st, zero)
        return jax.lax.dynamic_update_slice(buf, new, idxs)

    def write(start):
        out = {"k": dus(ck, k, start), "v": dus(cv, v, start)}
        if quantized:
            out["k_scale"] = dus(cache["k_scale"], k_scale, start, rank4=False)
            out["v_scale"] = dus(cache["v_scale"], v_scale, start, rank4=False)
        return out

    if s >= s_max:
        # keep only the last s_max tokens (local-attn prefill over a window)
        keep = slice(s - s_max, None)
        k, v = k[:, keep], v[:, keep]
        if quantized:
            k_scale, v_scale = k_scale[:, keep], v_scale[:, keep]
        if window:
            # ring phase: slot = abs_pos % s_max → roll the linear order
            base = (pos + s - s_max) % s_max
            k = jnp.roll(k, base, axis=1)
            v = jnp.roll(v, base, axis=1)
            if quantized:
                k_scale = jnp.roll(k_scale, base, axis=1)
                v_scale = jnp.roll(v_scale, base, axis=1)
        return write(jnp.int32(0))
    if window and s > 1:
        # partial ring fill that may wrap — not used by any assigned shape
        idx = (pos + jnp.arange(s)) % s_max
        out = {
            "k": ck.at[:, idx].set(k.astype(ck.dtype)),
            "v": cv.at[:, idx].set(v.astype(cv.dtype)),
        }
        if quantized:
            out["k_scale"] = cache["k_scale"].at[:, idx].set(k_scale)
            out["v_scale"] = cache["v_scale"].at[:, idx].set(v_scale)
        return out
    start = (pos % s_max) if window else pos
    return write(start)


def _ring_positions(slot: Array, total: Array, s_max: int) -> Array:
    """Absolute position held by each ring slot after `total` writes."""
    r = total % s_max
    base = total - r
    return jnp.where(slot < r, base + slot, base - s_max + slot)


def mlp_swiglu(p: dict, x: Array) -> Array:
    if "w_gate_up" in p:
        # decode fast path: output-fused gate/up weights (models.fuse)
        w = p["w_gate_up"]
        d_ff = (w.o if isinstance(w, QuantizedTensor) else w.shape[-1]) // 2
        gate, up = linear_fused(x, w, (d_ff, d_ff))
    else:
        gate = linear(x, p["w_gate"])
        up = linear(x, p["w_up"])
    # w_down is row-parallel under TP (d_ff shards): psum the partials
    return psum_partial(linear(jax.nn.silu(gate) * up, p["w_down"]))
