"""Recurrent temporal-mixing blocks: RG-LRU (Griffin/RecurrentGemma) and
xLSTM cells (mLSTM matrix memory, sLSTM scalar memory).

All three expose a *parallel* form for train/prefill (scan over time for the
strictly-recurrent cells, quadratic gated form for mLSTM) and an O(1)-state
*step* form for decode — which is what makes the ``long_500k`` shape lowerable
for these families (DESIGN.md §6).

References: Griffin [arXiv:2402.19427] eqs. (1)-(4); xLSTM [arXiv:2405.04517]
§2 (sLSTM) and §3 (mLSTM), with exponential-gating log-space stabilisation.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ops import linear
from repro.models.config import ModelConfig
from repro.models.layers import _dense_init

Array = jax.Array

_C_RGLRU = 8.0  # Griffin's fixed recurrence-sharpness constant


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block: conv1d + RG-LRU, gated)
# ---------------------------------------------------------------------------


def init_rglru(key, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    # Lambda init so a = sigma(L)^(c*r) starts with decay in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9**_C_RGLRU, 0.999**_C_RGLRU)
    lam = jnp.log(u ** (1.0 / _C_RGLRU) / (1 - u ** (1.0 / _C_RGLRU)))
    return {
        "w_x": _dense_init(ks[0], d, w, cfg.pdtype),  # recurrent branch in
        "w_y": _dense_init(ks[1], d, w, cfg.pdtype),  # gate branch in
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32) * 0.1).astype(cfg.pdtype),
        "w_a": _dense_init(ks[3], w, w, cfg.pdtype),  # recurrence gate
        "w_i": _dense_init(ks[4], w, w, cfg.pdtype),  # input gate
        "lam": lam,  # (w,) f32 learnable recurrence parameter
        "w_out": _dense_init(ks[6], w, d, cfg.pdtype),
    }


def _causal_conv1d(x: Array, w: Array, state: Optional[Array]) -> Tuple[Array, Array]:
    """Depthwise causal conv. x: (B,S,W); w: (K,W); state: (B,K-1,W) or None."""
    kw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, W)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None].astype(x.dtype) for i in range(kw)
    )
    return out, xp[:, -(kw - 1) :]


def rglru_scan(p: dict, x: Array, h0: Optional[Array]) -> Tuple[Array, Array, Array]:
    """RG-LRU over a sequence. x: (B,S,W) post-conv. Returns (y, h_last, hs)
    where ``hs`` is the full f32 state trajectory (S, B, W) — ``hs[t]`` is the
    state after consuming token ``t`` (the per-step snapshot stack the
    speculative cache-rewind contract selects from; DESIGN.md §5).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t),
    a_t = exp(c * r_t * log_sigmoid(Λ)), r_t = σ(x_t W_a), i_t = σ(x_t W_i).
    """
    b, s, w = x.shape
    r = jax.nn.sigmoid(linear(x, p["w_a"], out_dtype=jnp.float32))
    i = jax.nn.sigmoid(linear(x, p["w_i"], out_dtype=jnp.float32))
    log_a = _C_RGLRU * r * jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))[None, None]
    a = jnp.exp(log_a)  # (B,S,W) in (0,1)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * x.astype(jnp.float32)
    )
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    h_last, ys = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2), gated.transpose(1, 0, 2))
    )
    return ys.transpose(1, 0, 2).astype(x.dtype), h_last, ys


def rglru_block(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    state: Optional[dict] = None,
    collect: bool = False,
) -> Tuple[Array, Optional[dict]]:
    """Griffin recurrent block. x: (B,S,D). state: {"h": (B,W), "conv": (B,K-1,W)}.

    ``collect=True`` (speculative verify) returns the state stacked over the
    chunk's time axis instead of the final state — {"h": (S,B,W), "conv":
    (S,B,K-1,W)} with entry ``t`` the state after consuming token ``t`` — so
    rollback can select the snapshot at the commit index (DESIGN.md §5).
    """
    gate = jax.nn.gelu(linear(x, p["w_y"], out_dtype=jnp.float32))
    u = linear(x, p["w_x"])
    conv_state = state["conv"] if state is not None else None
    if collect and state is None:
        raise ValueError("collect=True requires a decoding state")
    conv_in, s_len, kw = u, u.shape[1], p["conv_w"].shape[0]
    u, new_conv = _causal_conv1d(u, p["conv_w"], conv_state)
    if collect:
        # conv state after token t = the K-1 inputs ending at t
        xp = jnp.concatenate([conv_state, conv_in], axis=1)  # (B, S+K-1, W)
        widx = jnp.arange(s_len)[:, None] + 1 + jnp.arange(kw - 1)[None]
        conv_stack = xp[:, widx].transpose(1, 0, 2, 3)  # (S, B, K-1, W)
    h0 = state["h"] if state is not None else None
    y, h_last, hs = rglru_scan(p, u, h0)
    out = linear((y.astype(jnp.float32) * gate).astype(x.dtype), p["w_out"])
    if collect:
        new_state = {"h": hs, "conv": conv_stack}
    else:
        new_state = {"h": h_last, "conv": new_conv} if state is not None else None
    return out, new_state


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM §3) — matrix memory, exponential gating
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    inner = int(d * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": _dense_init(ks[0], d, inner, cfg.pdtype),
        "w_z": _dense_init(ks[1], d, inner, cfg.pdtype),  # output-gate branch
        "wq": _dense_init(ks[2], inner, inner, cfg.pdtype),
        "wk": _dense_init(ks[3], inner, inner, cfg.pdtype),
        "wv": _dense_init(ks[4], inner, inner, cfg.pdtype),
        "w_i": _dense_init(ks[5], inner, nh, jnp.float32),  # input gate (per head)
        "w_f": _dense_init(ks[6], inner, nh, jnp.float32),  # forget gate (per head)
        "w_down": _dense_init(ks[7], inner, d, cfg.pdtype),
        "skip_scale": jnp.ones((inner,), jnp.float32),
    }


def _mlstm_parallel(q, k, v, i_gate, f_gate):
    """Stabilised quadratic parallel form (train/prefill).

    q,k,v: (B,NH,S,Dh); i_gate,f_gate: (B,NH,S) raw logits.
    D_ts = exp(i_s + Σ_{u=s+1..t} log σ(f_u) − m_t), causal; h = (D ⊙ qkᵀ) v / norm.
    """
    b, nh, s, dh = q.shape
    logf = jax.nn.log_sigmoid(f_gate)  # (B,NH,S)
    cf = jnp.cumsum(logf, axis=-1)  # inclusive cumsum
    # log decay matrix: cf[t] - cf[s] + i[s]  for s<=t
    dmat = cf[..., :, None] - cf[..., None, :] + i_gate[..., None, :]
    tri = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(tri[None, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=-1, keepdims=True)  # stabiliser
    dmat = jnp.exp(dmat - m)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(jnp.float32(dh))
    weights = scores * dmat
    norm = jnp.maximum(jnp.abs(weights.sum(-1, keepdims=True)), jnp.exp(-m))
    h = jnp.einsum("bhts,bhsd->bhtd", weights / norm, v)
    return h


MLSTM_CHUNK = 128  # chunkwise-parallel block length (train/prefill)


def _mlstm_chunkwise(q, k, v, i_gate, f_gate, chunk: int = MLSTM_CHUNK):
    """Chunkwise-parallel mLSTM: O(S·chunk) memory instead of O(S²).

    The quadratic form materialises a (B,NH,S,S) decay matrix — 69 TB for the
    train_4k shape (measured 109 GB/device temp in the dry-run → would OOM a
    v5e). Standard linear-attention chunking: intra-chunk quadratic (C×C) +
    inter-chunk recurrent (C_state, n_state, m_state) carried by a scan, with
    log-space stabilisation throughout. Exactly equal to the quadratic form
    (validated in tests/test_recurrent.py).

    q,k,v: (B,NH,S,Dh); i_gate,f_gate: (B,NH,S) raw logits → h (B,NH,S,Dh).
    """
    b, nh, s, dh = q.shape
    c = min(chunk, s)
    if s % c:
        pad = c - s % c
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 3))
        out = _mlstm_chunkwise(
            zpad(q), zpad(k), zpad(v), zpad(i_gate),
            jnp.pad(f_gate, ((0, 0), (0, 0), (0, pad)), constant_values=30.0),  # σ≈1
            chunk,
        )
        return out[:, :, :s]
    nc = s // c
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    qc = q.reshape(b, nh, nc, c, dh) * scale
    kc = k.reshape(b, nh, nc, c, dh)
    vc = v.reshape(b, nh, nc, c, dh)
    ic = i_gate.reshape(b, nh, nc, c)
    lf = jax.nn.log_sigmoid(f_gate).reshape(b, nh, nc, c)
    bcum = jnp.cumsum(lf, axis=-1)  # within-chunk inclusive logf cumsum
    a = ic - bcum  # a_s = i_s - b_s

    # put the chunk axis first for the scan
    qs, ks, vs, is_, bs2, as_ = (
        jnp.moveaxis(t, 2, 0) for t in (qc, kc, vc, ic, bcum, a)
    )

    tri = jnp.tril(jnp.ones((c, c), bool))

    def step(carry, inp):
        c_st, n_st, m_st = carry  # (B,NH,Dh,Dh), (B,NH,Dh), (B,NH)
        qt, kt, vt, it_, bt, at = inp
        # stabiliser per position t: M_t = max(m_st + b_t, b_t + max_{s<=t} a_s)
        a_run = jax.lax.cummax(at, axis=at.ndim - 1)  # (B,NH,C)
        m_t = jnp.maximum(m_st[..., None] + bt, bt + a_run)
        # inter-chunk: decay factor exp(b_t + m_st - M_t)
        inter_w = jnp.exp(bt + m_st[..., None] - m_t)  # (B,NH,C)
        h_inter = jnp.einsum("bhtd,bhde->bhte", qt, c_st) * inter_w[..., None]
        n_inter = jnp.einsum("bhtd,bhd->bht", qt, n_st) * inter_w
        # intra-chunk: D_ts = exp(b_t - b_s + i_s - M_t), s<=t
        dlog = bt[..., :, None] - bt[..., None, :] + it_[..., None, :]
        dmat = jnp.where(tri, jnp.exp(dlog - m_t[..., :, None]), 0.0)  # (B,NH,C,C)
        scores = jnp.einsum("bhtd,bhsd->bhts", qt, kt)
        w = scores * dmat
        h_intra = jnp.einsum("bhts,bhsd->bhtd", w, vt)
        n_intra = w.sum(-1)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t))
        h = (h_inter + h_intra) / denom[..., None]
        # state update to the chunk end
        bC = bt[..., -1:]
        m_new = jnp.maximum(m_st + bC[..., 0], (bC + a_run[..., -1:])[..., 0])
        decay_st = jnp.exp(m_st + bC[..., 0] - m_new)  # (B,NH)
        kw = jnp.exp(bC - bt + it_ - m_new[..., None])  # (B,NH,C)
        c_new = decay_st[..., None, None] * c_st + jnp.einsum(
            "bhs,bhsd,bhse->bhde", kw, kt, vt
        )
        n_new = decay_st[..., None] * n_st + jnp.einsum("bhs,bhsd->bhd", kw, kt)
        return (c_new, n_new, m_new), h

    c0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    m0 = jnp.full((b, nh), -1e30, jnp.float32)
    _, hs = jax.lax.scan(step, (c0, n0, m0), (qs, ks, vs, is_, bs2, as_))
    return jnp.moveaxis(hs, 0, 2).reshape(b, nh, s, dh)


def mlstm_block(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    state: Optional[dict] = None,
    collect: bool = False,
) -> Tuple[Array, Optional[dict]]:
    """x: (B,S,D). state: {"c": (B,NH,Dh,Dh), "n": (B,NH,Dh), "m": (B,NH)}.

    ``collect=True`` (speculative verify; requires a state) stacks the state
    over the chunk's time axis — entry ``t`` = state after token ``t`` — for
    rollback selection at the commit index (DESIGN.md §5)."""
    b, s, d = x.shape
    nh = cfg.n_heads
    inner = int(d * cfg.mlstm_proj_factor)
    dh = inner // nh

    u = linear(x, p["w_up"])
    z = linear(x, p["w_z"], out_dtype=jnp.float32)
    q = linear(u, p["wq"], out_dtype=jnp.float32).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    k = linear(u, p["wk"], out_dtype=jnp.float32).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    v = linear(u, p["wv"], out_dtype=jnp.float32).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    i_gate = linear(u, p["w_i"], out_dtype=jnp.float32).transpose(0, 2, 1)  # (B,NH,S)
    f_gate = linear(u, p["w_f"], out_dtype=jnp.float32).transpose(0, 2, 1)

    if collect and state is None:
        raise ValueError("collect=True requires a decoding state")
    if state is None and s > 1:
        if s <= MLSTM_CHUNK:
            h = _mlstm_parallel(q, k, v, i_gate, f_gate)  # (B,NH,S,Dh)
        else:
            h = _mlstm_chunkwise(q, k, v, i_gate, f_gate)
        new_state = None
    else:
        c = state["c"] if state is not None else jnp.zeros((b, nh, dh, dh), jnp.float32)
        n = state["n"] if state is not None else jnp.zeros((b, nh, dh), jnp.float32)
        m = state["m"] if state is not None else jnp.full((b, nh), -jnp.inf, jnp.float32)

        def step(carry, inp):
            c, n, m = carry
            q_t, k_t, v_t, i_t, f_t = inp  # (B,NH,Dh) x3, (B,NH) x2
            logf = jax.nn.log_sigmoid(f_t)
            m_new = jnp.maximum(logf + m, i_t)
            fg = jnp.exp(logf + m - m_new)  # (B, NH)
            ig = jnp.exp(i_t - m_new)  # (B, NH)
            scale = 1.0 / jnp.sqrt(jnp.float32(dh))
            kv = (ig[..., None] * k_t * scale)[..., :, None] * v_t[..., None, :]
            c = fg[..., None, None] * c + kv  # (B, NH, Dh, Dh)
            n = fg[..., None] * n + ig[..., None] * k_t * scale
            num = jnp.einsum("bhd,bhde->bhe", q_t, c)
            den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q_t, n)), jnp.exp(-m_new))
            h_t = num / den[..., None]
            out_t = (h_t, (c, n, m_new)) if collect else h_t
            return (c, n, m_new), out_t

        seq = (
            q.transpose(2, 0, 1, 3),
            k.transpose(2, 0, 1, 3),
            v.transpose(2, 0, 1, 3),
            i_gate.transpose(2, 0, 1),
            f_gate.transpose(2, 0, 1),
        )
        (c, n, m), ys = jax.lax.scan(step, (c, n, m), seq)
        if collect:
            hs, (cs, ns, ms) = ys
            new_state = {"c": cs, "n": ns, "m": ms}  # (S, B, NH, ...) stacks
        else:
            hs = ys
            new_state = {"c": c, "n": n, "m": m}
        h = hs.transpose(1, 2, 0, 3)  # (B,NH,S,Dh)

    h = h.transpose(0, 2, 1, 3).reshape(b, s, inner)
    h = h + p["skip_scale"][None, None] * u.astype(jnp.float32)
    out = linear((h * jax.nn.silu(z)).astype(x.dtype), p["w_down"])
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM §2) — scalar memory with hidden-to-hidden recurrence
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 9)
    rinit = lambda kk: (jax.random.normal(kk, (nh, dh, dh), jnp.float32) / jnp.sqrt(dh)).astype(cfg.pdtype)
    return {
        "w_z": _dense_init(ks[0], d, d, cfg.pdtype),
        "w_i": _dense_init(ks[1], d, d, cfg.pdtype),
        "w_f": _dense_init(ks[2], d, d, cfg.pdtype),
        "w_o": _dense_init(ks[3], d, d, cfg.pdtype),
        "r_z": rinit(ks[4]),
        "r_i": rinit(ks[5]),
        "r_f": rinit(ks[6]),
        "r_o": rinit(ks[7]),
        "w_out": _dense_init(ks[8], d, d, cfg.pdtype),
    }


def slstm_block(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    state: Optional[dict] = None,
    collect: bool = False,
) -> Tuple[Array, Optional[dict]]:
    """x: (B,S,D). state: {"h","c","n","m": (B,NH,Dh)}. Strictly sequential.

    ``collect=True`` (speculative verify; requires a state) stacks the state
    over the chunk's time axis for rollback selection (DESIGN.md §5)."""
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = d // nh

    pre = {
        g: linear(x, p["w_" + g], out_dtype=jnp.float32).reshape(b, s, nh, dh)
        for g in ("z", "i", "f", "o")
    }
    if state is None:
        zeros = jnp.zeros((b, nh, dh), jnp.float32)
        st = {"h": zeros, "c": zeros, "n": zeros + 1e-6, "m": zeros - jnp.inf}
    else:
        st = state

    r = {g: p["r_" + g].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    def step(carry, inp):
        h, c, n, m = carry
        pz, pi, pf, po = inp  # (B,NH,Dh) each
        rec = lambda rm: jnp.einsum("bhd,hde->bhe", h, rm)
        z = jnp.tanh(pz + rec(r["z"]))
        i_log = pi + rec(r["i"])
        f_log = jax.nn.log_sigmoid(pf + rec(r["f"]))
        o = jax.nn.sigmoid(po + rec(r["o"]))
        m_new = jnp.maximum(f_log + m, i_log)
        ig = jnp.exp(i_log - m_new)
        fg = jnp.exp(f_log + m - m_new)
        c = fg * c + ig * z
        n = fg * n + ig
        h = o * c / jnp.maximum(n, 1e-6)
        out_t = (h, (h, c, n, m_new)) if collect else h
        return (h, c, n, m_new), out_t

    if collect and state is None:
        raise ValueError("collect=True requires a decoding state")
    seq = tuple(pre[g].transpose(1, 0, 2, 3) for g in ("z", "i", "f", "o"))
    (h, c, n, m), ys = jax.lax.scan(step, (st["h"], st["c"], st["n"], st["m"]), seq)
    if collect:
        hs, (hh, cs, ns, ms) = ys
        new_state = {"h": hh, "c": cs, "n": ns, "m": ms}  # (S, B, NH, Dh) stacks
    else:
        hs = ys
        new_state = {"h": h, "c": c, "n": n, "m": m} if state is not None else None
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    out = linear(y, p["w_out"])
    return out, new_state
