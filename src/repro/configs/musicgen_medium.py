"""MusicGen-medium — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284; hf]

Backbone only (assignment spec): the EnCodec frontend is a stub —
``input_specs()`` supplies precomputed frame embeddings, the LM head predicts
the 2048-entry codebook vocabulary.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    rope_theta=10_000.0,
    input_kind="embeddings",
)
