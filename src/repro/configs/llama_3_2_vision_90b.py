"""Llama-3.2-Vision-90B — text decoder with interleaved cross-attention image
layers (every 5th layer). The vision tower is a stub: ``input_specs()``
supplies precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=500_000.0,
    cross_attn_period=5,
    n_image_tokens=1601,
)
