"""Llama-4-Maverick 400B-A17B — 128-expert top-1 MoE with shared expert,
early-fusion multimodal (modality frontend stubbed per assignment).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202_048,
    rope_theta=500_000.0,
    n_experts=128,
    top_k=1,
    shared_expert=True,
    moe_period=2,  # Maverick interleaves dense and MoE layers (→ ~400B total)
)
