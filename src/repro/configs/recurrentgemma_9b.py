"""RecurrentGemma-9B — Griffin: RG-LRU recurrent blocks + local attention, 1:2.
[arXiv:2402.19427; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256_000,
    rope_theta=10_000.0,
    window=2048,
    lru_width=4096,
)
