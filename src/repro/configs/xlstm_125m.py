"""xLSTM-125M — alternating mLSTM (matrix memory) and sLSTM (scalar memory)
blocks; d_ff=0 (no separate FFN — the cells carry their own projections).
[arXiv:2405.04517; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    rope_theta=10_000.0,
)
