"""GPT-3 175B — the paper's §IV estimation target (m = d_model = 12288, 96L).
Used by benchmarks/table5_gpt3.py; not part of the assigned dry-run cells.
[paper Table I]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt3-175b",
    family="dense",
    n_layers=96,
    d_model=12288,
    n_heads=96,
    n_kv_heads=96,
    d_ff=49152,
    vocab=50257,
    rope_theta=10_000.0,
)
