"""Assigned architecture configs (public-literature) + the paper's own GPT-3 config.

``get_config(arch_id)`` resolves the ``--arch`` flag. Each module defines
``CONFIG`` (exact published shape) — reduced smoke variants come from
``repro.models.config.reduced``.
"""

from __future__ import annotations

import importlib

_ARCHS = {
    "llama3.2-3b": "llama3_2_3b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "minitron-4b": "minitron_4b",
    "starcoder2-7b": "starcoder2_7b",
    "musicgen-medium": "musicgen_medium",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "xlstm-125m": "xlstm_125m",
    "gpt3-175b": "gpt3_175b",  # the paper's own estimation target (§IV)
}

ARCH_IDS = tuple(k for k in _ARCHS if k != "gpt3-175b")


def get_config(arch_id: str):
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch_id]}")
    return mod.CONFIG
