"""Self-speculative decoding from nested BCQ precisions (DESIGN.md §5).

BCQ is *nested by construction*: the first ``q'`` binary-code planes of a
``q``-bit weight (``packed[:q']``, ``scales[:q']``) are themselves a valid
``q'``-bit approximation — the greedy solver builds them as successive
residual refinements (paper §III.A). Every quantized model therefore carries
a free family of cheaper draft models, and the paper's own latency model
(fewer ``q`` planes → proportionally less LUT work and HBM traffic) makes a
1–2-bit draft decode substantially cheaper than the 4-bit target.

This module turns that into end-to-end decode throughput with *exactly* the
target model's output distribution:

- **draft**: γ+1 scanned single-token decode steps of the truncated-precision
  view (:func:`repro.quant.truncate_params`) propose tokens ``d_1..d_γ``;
- **verify**: ONE batched forward of the full-``q`` model over
  ``[t_pending, d_1..d_γ]`` (the chunked-decode attention mode of
  ``models/layers.py``) scores every proposal;
- **accept**: exact prefix-match for greedy rows, standard rejection sampling
  (Leviathan et al., 2023) for ``temperature>0`` rows — accepted prefix plus
  one correction/bonus token is committed, so every chunk emits ≥ 1 token and
  greedy output is token-identical to plain greedy decode;
- **rollback**: rejected tokens are erased from both models' caches under the
  cache-rewind contract (``models/layers.py``): positional KV rows are
  restored from a pre-chunk snapshot (ring buffers *require* this — a wrapped
  write clobbers the live entry ``s_max`` positions back; for dense caches it
  additionally makes the cache bit-identical to never having decoded the
  chunk), and recurrent state — which folds tokens irreversibly and cannot be
  re-masked — is rewound by selecting the per-step snapshot at the commit
  index (``collect_states=True`` verify, scan-carried snapshots on the draft
  side).

Everything per-row: ``pos``, PRNG streams, acceptance counts and budgets are
(B,) vectors, so the same chunk body serves one-shot ``Engine.generate`` (a
``lax.while_loop`` until every row has its budget) and the continuous-batching
scheduler (a fixed number of chunks per dispatch with active masks, rows
opting in per request).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.model import forward

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculation knobs: draft precision (BCQ planes) and draft length.

    ``q_draft`` planes of the target's own quantized weights form the draft
    (dense leaves are shared — an unquantized model drafts with itself and
    accepts everything, which is the degenerate-but-correct case).
    ``gamma`` tokens are proposed per chunk; each chunk commits between 1 and
    ``gamma + 1`` tokens.
    """

    q_draft: int = 2
    gamma: int = 4

    def __post_init__(self):
        if self.q_draft < 1:
            raise ValueError(f"q_draft must be >= 1, got {self.q_draft}")
        if self.gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {self.gamma}")

    @classmethod
    def parse(cls, text: str) -> "SpecConfig":
        """Parse the CLI form ``q_draft:gamma`` (e.g. ``2:4``).

        Every failure mode — wrong separator, non-integer parts, out-of-range
        values — raises a ``ValueError`` that names the expected ``QD:GAMMA``
        syntax, so CLI surfaces (``launch/serve.py --speculate``) can forward
        the message verbatim instead of a bare traceback.
        """
        syntax = (
            "expected 'QD:GAMMA' — two ':'-separated integers, QD = draft "
            "bit-planes >= 1, GAMMA = proposals per chunk >= 1 (e.g. '2:4')"
        )
        try:
            q_draft, gamma = (int(t) for t in text.split(":"))
        except ValueError as e:
            raise ValueError(f"{syntax}; got {text!r}") from e
        try:
            return cls(q_draft=q_draft, gamma=gamma)
        except ValueError as e:
            raise ValueError(f"{syntax}; got {text!r} ({e})") from e


def has_recurrent_state(cfg: ModelConfig) -> bool:
    """True if any block carries non-positional (recurrent) decode state."""
    return any(
        bt in ("rglru", "mlstm", "slstm")
        for pattern, _ in cfg.stages
        for bt in pattern
    )


def has_ring_buffer(cfg: ModelConfig) -> bool:
    """True if any block's KV cache is a ring buffer (local attention)."""
    return any(bt == "local_attn" for pattern, _ in cfg.stages for bt in pattern)


# ---------------------------------------------------------------------------
# cache rewind primitives (the contract constants live in models/layers.py)
# ---------------------------------------------------------------------------


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", last)))


def snapshot_rows(cache: dict, pos: Array, n: int) -> dict:
    """Pre-write snapshot of the ``n`` cache rows a chunk will write.

    ``pos`` is the per-row (B,) start position; rows ``pos..pos+n-1`` (mod the
    ring length for windowed buffers) of every POSITIONAL leaf are gathered to
    ``(repeat, B, n, ...)``. Non-positional leaves become empty placeholders
    so the snapshot remains a fixed-shape pytree (it rides a while_loop/scan
    carry).
    """

    def visit(path, leaf):
        if _leaf_name(path) not in L.POSITIONAL_CACHE_LEAVES:
            return jnp.zeros((0,), jnp.int8)
        s_eff = leaf.shape[2]
        idx = (pos[:, None] + jnp.arange(n)) % s_eff  # (B, n)
        ix = idx.reshape((1,) + idx.shape + (1,) * (leaf.ndim - 3))
        return jnp.take_along_axis(leaf, ix, axis=2)

    return jax.tree_util.tree_map_with_path(visit, cache)


def restore_rows(cache: dict, snap: dict, pos: Array, n: int, keep: Array) -> dict:
    """Roll rejected rows back: row ``pos+j`` keeps its fresh write iff
    ``j < keep`` (per-row), otherwise its pre-chunk snapshot content returns.

    For ring buffers this un-clobbers the live entries the rejected writes
    destroyed; for linear caches it leaves the buffer bit-identical to never
    having decoded the rejected suffix.
    """

    def visit(path, leaf, sn):
        if _leaf_name(path) not in L.POSITIONAL_CACHE_LEAVES:
            return leaf
        s_eff = leaf.shape[2]
        b = leaf.shape[1]
        idx = (pos[:, None] + jnp.arange(n)) % s_eff  # (B, n)
        ix = idx.reshape((1,) + idx.shape + (1,) * (leaf.ndim - 3))
        cur = jnp.take_along_axis(leaf, ix, axis=2)  # (repeat, B, n, ...)
        m = (jnp.arange(n)[None, :] < keep[:, None]).reshape(
            (1, b, n) + (1,) * (leaf.ndim - 3)
        )
        rows = jnp.where(m, cur, sn)
        return leaf.at[:, jnp.arange(b)[:, None], idx].set(rows)

    return jax.tree_util.tree_map_with_path(visit, cache, snap)


def select_recurrent_target(verify_cache: dict, idx: Array) -> dict:
    """Pick the per-step recurrent snapshots at the per-row commit index.

    ``verify_cache`` came from a ``collect_states=True`` forward: recurrent
    leaves are ``(repeat, S, B, ...)`` stacks (entry ``t`` = state after
    consuming chunk token ``t``); positional leaves are untouched. Returns a
    normal-structure cache with recurrent leaves ``(repeat, B, ...)``.
    """

    def visit(path, leaf):
        if _leaf_name(path) not in L.RECURRENT_CACHE_LEAVES:
            return leaf
        b = leaf.shape[2]
        ix = idx.reshape((1, 1, b) + (1,) * (leaf.ndim - 3))
        return jnp.take_along_axis(leaf, ix, axis=1)[:, 0]

    return jax.tree_util.tree_map_with_path(visit, verify_cache)


def select_recurrent_draft(cache: dict, stacks: dict, idx: Array) -> dict:
    """Same selection for the draft side, whose snapshots were emitted by the
    draft scan: recurrent leaves of ``stacks`` are ``(S, repeat, B, ...)``
    (scan-stacked, step axis leading); positional leaves come from ``cache``.
    """

    def visit(path, leaf, st):
        if _leaf_name(path) not in L.RECURRENT_CACHE_LEAVES:
            return leaf
        b = leaf.shape[1]
        ix = idx.reshape((1, 1, b) + (1,) * (leaf.ndim - 2))
        return jnp.take_along_axis(st, ix, axis=0)[0]

    return jax.tree_util.tree_map_with_path(visit, cache, stacks)


def _recurrent_only(cache: dict):
    """Recurrent leaves verbatim, positional leaves as empty placeholders —
    the per-step snapshot payload the draft scan emits."""

    def visit(path, leaf):
        if _leaf_name(path) in L.RECURRENT_CACHE_LEAVES:
            return leaf
        return jnp.zeros((0,), jnp.int8)

    return jax.tree_util.tree_map_with_path(visit, cache)


# ---------------------------------------------------------------------------
# the draft-verify-accept-rollback chunk
# ---------------------------------------------------------------------------


def freeze_inactive(new_state: dict, old_state: dict, active: Array) -> dict:
    """Freeze inactive rows' per-row chunk carries (pending token, position,
    PRNG streams) at their pre-chunk values. Caches are deliberately NOT
    frozen: an inactive row's garbage writes land beyond its frozen position
    and are never attended (the same write-before-read argument as the plain
    slot batch, DESIGN.md §4)."""
    return dict(
        new_state,
        t_pend=jnp.where(active, new_state["t_pend"], old_state["t_pend"]),
        pos=jnp.where(active, new_state["pos"], old_state["pos"]),
        keys=jnp.where(active[:, None], new_state["keys"], old_state["keys"]),
        draft_keys=jnp.where(
            active[:, None], new_state["draft_keys"], old_state["draft_keys"]
        ),
    )


def _row_categorical(keys: Array, logits: Array) -> Array:
    """Per-row seeded categorical, bit-identical to a standalone batch-1 call
    (the slot-batched sampling idiom of Engine._scan_decode_slots)."""
    return jax.vmap(lambda kk, lg: jax.random.categorical(kk, lg[None])[0])(
        keys, logits
    )


def spec_chunk(
    cfg: ModelConfig,
    params,
    draft_params,
    state: dict,
    *,
    gamma: int,
    greedy: Array,  # (B,) bool
    temperature: Array,  # (B,) f32 (ignored where greedy)
    spec_enabled: Array,  # (B,) bool — False rows force n_acc=0 (plain decode)
    fwd=None,  # forward with cfg bound; TP engines pass their shard_map'd one
) -> Tuple[Array, Array, dict]:
    """One speculative chunk over the whole batch.

    ``state``: {"t_pend" (B,) int32, "pos" (B,) int32, "keys" (B,2) uint32,
    "draft_keys" (B,2) uint32, "cache", "draft_cache"}.

    ``fwd(params, **kw)`` defaults to ``models.forward`` with ``cfg`` bound;
    a tensor-parallel engine passes its shard_map wrapper instead so draft
    scan and batched verify both consume sharded params/caches.

    Returns ``(commit (B, gamma+1) int32, n_keep (B,) int32, new_state)``:
    row ``b`` committed ``commit[b, :n_keep[b]]`` — the accepted draft prefix
    plus one correction/bonus token — and the caches/counters in ``new_state``
    are rewound to exactly that prefix.
    """
    if fwd is None:
        fwd = functools.partial(forward, cfg)
    t_pend, pos = state["t_pend"], state["pos"]
    cache, dcache = state["cache"], state["draft_cache"]
    b = t_pend.shape[0]
    n_tok = gamma + 1
    collect = has_recurrent_state(cfg)
    # Linear (non-ring) caches need no row restore: rejected rows sit beyond
    # the rewound position counter, are never attended (masked reads), and are
    # overwritten before re-entering the valid range. Only wrapped ring
    # buffers lose live entries to rejected writes (DESIGN.md §5).
    ring = has_ring_buffer(cfg)

    # -- PRNG: one split per row per chunk for the commit token (non-spec
    # sampled rows thereby consume exactly one split per emitted token — the
    # plain decode stream), plus an independent draft-proposal stream.
    splits = jax.vmap(jax.random.split)(state["keys"])  # (B, 2, 2)
    new_keys, commit_sub = splits[:, 0], splits[:, 1]
    dsplits = jax.vmap(lambda k: jax.random.split(k, gamma + 3))(
        state["draft_keys"]
    )  # (B, gamma+3, 2): carry, accept-uniforms, gamma+1 proposal steps
    new_draft_keys = dsplits[:, 0]
    uniform_sub = dsplits[:, 1]
    prop_subs = dsplits[:, 2:]  # (B, gamma+1, 2) one per draft step

    # -- draft: gamma+1 scanned decode steps of the truncated model ---------
    if ring:
        dsnap = snapshot_rows(dcache, pos, n_tok)  # pre-write rows for rollback
    def draft_step(carry, step_keys):
        tok, dc, j = carry
        kw = {"tokens": tok[:, None]}
        if cfg.family == "vlm":
            kw["image_emb"] = None
        logits, dc, _ = fwd(
            draft_params, **kw, cache=dc, pos=pos + j, logits_mode="last"
        )
        lg = logits[:, -1]  # (B, V) draft dist for position pos+j+1
        sampled = _row_categorical(step_keys, lg / temperature[:, None])
        prop = jnp.where(greedy, jnp.argmax(lg, axis=-1), sampled).astype(jnp.int32)
        return (prop, dc, j + 1), (prop, lg, _recurrent_only(dc))

    (_, dcache, _), (props, q_logits, dstacks) = jax.lax.scan(
        draft_step, (t_pend, dcache, jnp.int32(0)), prop_subs.swapaxes(0, 1)
    )
    drafts = props.swapaxes(0, 1)[:, :gamma]  # (B, gamma): d_1..d_gamma
    q_logits = q_logits.swapaxes(0, 1)  # (B, gamma+1, V); [:, i] ~ d_{i+1}

    # -- verify: ONE chunked forward of the target over the proposals -------
    if ring:
        snap = snapshot_rows(cache, pos, n_tok)
    verify_toks = jnp.concatenate([t_pend[:, None], drafts], axis=1)  # (B, γ+1)
    kw = {"tokens": verify_toks}
    if cfg.family == "vlm":
        kw["image_emb"] = None
    p_logits, vcache, _ = fwd(
        params, **kw, cache=cache, pos=pos, logits_mode="all",
        chunked_decode=True, collect_states=collect,
    )  # p_logits (B, gamma+1, V); [:, i] = target dist for position pos+i+1

    # -- accept: greedy prefix-match / rejection sampling per row -----------
    tgt_argmax = jnp.argmax(p_logits[:, :gamma], axis=-1)  # (B, gamma)
    acc_greedy = drafts == tgt_argmax

    temp = temperature[:, None, None]
    p_probs = jax.nn.softmax(p_logits[:, :gamma] / temp, axis=-1)
    q_probs = jax.nn.softmax(q_logits[:, :gamma] / temp, axis=-1)
    pick = lambda pr: jnp.take_along_axis(pr, drafts[..., None], axis=-1)[..., 0]
    ratio = pick(p_probs) / jnp.maximum(pick(q_probs), 1e-30)  # (B, gamma)
    uniforms = jax.vmap(lambda kk: jax.random.uniform(kk, (gamma,)))(uniform_sub)
    acc_sample = uniforms < ratio

    accepted = jnp.where(greedy[:, None], acc_greedy, acc_sample)
    accepted &= spec_enabled[:, None]
    n_acc = jnp.sum(jnp.cumprod(accepted.astype(jnp.int32), axis=1), axis=1)  # (B,)

    # -- commit token: correction at the reject position / bonus at the end -
    sel = lambda arr, i: jnp.take_along_axis(
        arr, i.reshape(b, 1, 1), axis=1
    )[:, 0]
    p_at = sel(p_logits, n_acc)  # (B, V) target logits at the commit position
    greedy_next = jnp.argmax(p_at, axis=-1).astype(jnp.int32)
    # residual max(p-q, 0): q := 0 beyond the proposal range (bonus position)
    # and for non-speculating rows, which degrades to sampling p directly
    q_at = jax.nn.softmax(sel(q_logits, jnp.minimum(n_acc, gamma)) / temperature[:, None], axis=-1)
    q_at = jnp.where(((n_acc >= gamma) | ~spec_enabled)[:, None], 0.0, q_at)
    resid = jnp.maximum(jax.nn.softmax(p_at / temperature[:, None], axis=-1) - q_at, 0.0)
    resid = resid / jnp.maximum(resid.sum(-1, keepdims=True), 1e-30)
    spec_next = _row_categorical(commit_sub, jnp.log(jnp.maximum(resid, 1e-38)))
    # non-spec rows sample the RAW logits row — bit-identical to plain decode
    plain_next = _row_categorical(commit_sub, p_at / temperature[:, None])
    sampled_next = jnp.where(spec_enabled, spec_next, plain_next).astype(jnp.int32)
    t_next = jnp.where(greedy, greedy_next, sampled_next)

    n_keep = n_acc + 1  # committed tokens fed this chunk (t_pend..d_n_acc)
    commit = jnp.concatenate([drafts, jnp.zeros((b, 1), jnp.int32)], axis=1)
    commit = jnp.where(
        jnp.arange(n_tok)[None, :] == n_acc[:, None], t_next[:, None], commit
    )  # (B, gamma+1): [d_1..d_n_acc, t_next, junk...]

    # -- rollback: positional restore (ring only) + recurrent per-step select
    if collect:
        vcache = select_recurrent_target(vcache, n_acc)
        dcache = select_recurrent_draft(dcache, dstacks, n_acc)
    if ring:
        new_cache = restore_rows(vcache, snap, pos, n_tok, n_keep)
        new_dcache = restore_rows(dcache, dsnap, pos, n_tok, n_keep)
    else:
        new_cache, new_dcache = vcache, dcache

    new_state = dict(
        state,
        t_pend=t_next,
        pos=pos + n_keep,
        keys=new_keys,
        draft_keys=new_draft_keys,
        cache=new_cache,
        draft_cache=new_dcache,
    )
    return commit, n_keep, new_state
