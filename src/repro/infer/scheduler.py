"""Continuous-batching scheduler over the slot-batched decode path.

The paper's headline number is decode-phase throughput on a *serving*
workload (§V: OPT-175B token generation): the LUT/BCQ kernels only pay off
end-to-end if the decode batch stays fed. One-shot ``Engine.generate`` runs a
fixed batch in lockstep — every request waits for the longest one, and the
batch drains as requests finish. This module keeps a fixed-width decode batch
full instead (Orca-style continuous batching):

- requests wait in a **bounded admission queue**;
- the decode batch has ``n_slots`` **slots**; a free slot is filled by
  prefilling the next queued request (batch-1) and scatter-installing its KV
  rows, position counter, PRNG key and sampling params into the slot
  (``Engine.admit_slot``);
- decode runs in **chunks** of ``chunk`` scanned steps over the whole batch
  (``Engine.decode_slots``); per-slot active masks let requests finish
  mid-chunk without stalling neighbours;
- a finished slot is freed and refilled at the next chunk boundary.

Correctness contract (tests/test_scheduler.py): the interleaving is
*invisible* — each request's tokens are identical to running it alone through
``Engine.generate(prompt, max_new_tokens, temperature=..., seed=...)``. This
holds because batch rows are fully independent in the model forward (per-slot
positions, per-slot cache rows, per-slot PRNG streams) and the batched
per-row compute is bitwise equal to the batch-1 compute. MoE families are the
documented exception: expert-capacity dropping couples batch rows, so
continuous batching there is throughput-correct but not token-identical.

**Request lifecycle** (DESIGN.md §9, ``infer/lifecycle.py``): every request
runs an explicit validated state machine — QUEUED → PREFILLING → DECODING →
{FINISHED, CANCELLED, TIMED_OUT, FAILED}, with SHED for deadline-aware queue
shedding and a loud :class:`~repro.infer.lifecycle.QueueFullError` when the
bounded admission queue rejects a submit. The hardening invariant
(tests/test_lifecycle.py) extends the §4 contract to the unhappy path:
**whatever happens to any subset of requests — cancellation, deadline
expiry, injected dispatch failures, NaN-poisoned rows — every surviving
request's tokens stay bit-identical to an undisturbed run.** The mechanisms:

- **cancellation** (:meth:`Scheduler.cancel`, thread-safe to *flag*): the
  slot is reclaimed at the next chunk boundary (``Engine.release_slot`` — the
  row goes inactive, the next admission overwrites its whole state row);
- **deadlines**: per-request TTFT and total wall-clock deadlines enforced at
  chunk boundaries against the scheduler's injectable ``clock``; queued
  requests whose deadline already expired are SHED before wasting a prefill;
- **NaN/inf logit guard**: a per-chunk (B,)-bool device check; a non-finite
  row is FAILED and quarantined (slot scrubbed + refilled) while neighbours
  decode on untouched;
- **bounded retry with backoff** around every engine dispatch; a prefill
  failure quarantines only the admitting request, exhausted decode-chunk
  retries fail the *active* tenants and rebuild the slot state so queued
  requests still complete;
- **fault injection** (``infer/faults.py``): all of the above is
  deterministically testable by threading a :class:`FaultPlan` through the
  dispatch points.

**Stop tokens**: per-request ``Request.stop_tokens`` finish a row early —
host-side truncation at the chunk boundary (the stop token is the last one
kept), the slot frees immediately, and the completion is token-identical to
a solo ``generate`` truncated at the same position.

**Tensor-parallel serving** (``Scheduler(Engine(cfg, params, mesh=...))``,
DESIGN.md §7): the scheduler is sharding-agnostic — slots, admission and
budgets live on the host exactly as below, while every engine dispatch it
drives (admission prefill, decode chunks, speculative chunks) consumes
TP-sharded weights and KV caches under ``shard_map``. Greedy completions
stay bit-identical to the single-device engine; sampled completions remain
solo-identical *within* the same sharded engine (tests/test_tp_serve.py).

**Speculative serving** (``Scheduler(engine, speculate=SpecConfig(...))``,
DESIGN.md §5): decode dispatches become draft-verify-accept chunks — each
chunk commits 1..gamma+1 tokens per row instead of exactly one. Requests opt
in per row (``Request.speculate``); opted-out rows run one plain target step
per chunk with their solo-identical PRNG stream. Greedy speculative rows are
token-identical to solo plain ``generate``; sampled speculative rows follow
the exact target distribution but a different stream for the same seed
(rejection sampling consumes randomness differently). The request's first
token is sampled at admission (it comes from the target's own prefill
logits), so a chunk always has a pending token to verify behind.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.infer.engine import Engine
from repro.infer.faults import FaultPlan
from repro.infer.lifecycle import (
    QueueFullError,
    RequestLifecycle,
    RequestState,
    latency_summary,
)
from repro.infer.speculative import SpecConfig


class DispatchError(RuntimeError):
    """An engine dispatch kept failing after bounded retries."""


@dataclasses.dataclass
class Request:
    """One generation request. `seed`/`temperature` are per-request: mixed
    greedy and sampled requests share a batch. ``speculate`` opts this request
    in/out of speculative decoding when the scheduler runs a speculative slot
    batch (None → the scheduler's default: in); it is ignored otherwise.

    ``stop_tokens`` ends the generation early at the first matching token
    (kept, then the slot frees at the next chunk boundary).
    ``ttft_deadline_s`` / ``deadline_s`` are wall-clock budgets measured from
    submit: miss the first-token deadline or the total deadline and the
    request is TIMED_OUT (or SHED while still queued) at the next chunk
    boundary instead of occupying a slot forever."""

    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    speculate: Optional[bool] = None
    stop_tokens: Optional[Sequence[int]] = None
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None
    rid: Optional[int] = None  # assigned at submit() if None

    def __post_init__(self):
        arr = np.asarray(self.prompt)  # staticcheck: host-sync(request validation on host input)
        if arr.dtype.kind not in "iu":
            # silent float->int32 casting would truncate values the caller
            # never meant as token ids
            raise ValueError(
                f"prompt must be integer token ids, got dtype {arr.dtype}"
            )
        self.prompt = arr.astype(np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # temperature feeds `logits / temperature` on device: NaN/inf would
        # poison sampling silently (NaN fails every `<= 0` greedy check and
        # then divides the logits), negative values would invert the
        # distribution. Exactly 0.0 means greedy by convention.
        if not np.isfinite(self.temperature):
            raise ValueError(
                f"temperature must be finite, got {self.temperature!r}"
            )
        if self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0 (0 = greedy), got {self.temperature!r}"
            )
        self.temperature = float(self.temperature)
        # seed feeds jax.random.PRNGKey, whose C-long conversion overflows
        # outside int64 — catch it here with the limit named instead of
        # letting an OverflowError surface mid-admission (and reject
        # non-integral seeds before they'd be silently truncated)
        if isinstance(self.seed, bool) or not isinstance(
            self.seed, (int, np.integer)
        ):
            raise ValueError(f"seed must be an integer, got {self.seed!r}")
        if not (-(2**63) <= int(self.seed) < 2**63):
            raise ValueError(
                f"seed must fit in int64 (PRNGKey range "
                f"[-2**63, 2**63)), got {self.seed}"
            )
        self.seed = int(self.seed)
        if self.stop_tokens is not None:
            toks = tuple(int(t) for t in self.stop_tokens)
            if any(
                isinstance(t, bool) or not isinstance(t, (int, np.integer))
                for t in self.stop_tokens
            ):
                raise ValueError(
                    f"stop_tokens must be integer token ids, got "
                    f"{self.stop_tokens!r}"
                )
            self.stop_tokens = toks
        for name in ("ttft_deadline_s", "deadline_s"):
            v = getattr(self, name)
            if v is not None and not (np.isfinite(v) and v > 0):
                raise ValueError(f"{name} must be a positive number, got {v!r}")


@dataclasses.dataclass
class Completion:
    rid: int
    prompt: np.ndarray  # (prompt_len,)
    new_tokens: np.ndarray  # (<= max_new_tokens,) — shorter iff stopped early
    admitted_at_step: int  # scheduler decode-step counter at admission
    finished_at_step: int
    stopped: bool = False  # True iff ended on a stop token before the budget

    @property
    def tokens(self) -> np.ndarray:
        """prompt + generation, the same layout GenerationResult.tokens uses."""
        return np.concatenate([self.prompt, self.new_tokens])


class _Tenant:
    __slots__ = ("req", "emitted", "admitted_at_step", "stop")

    def __init__(self, req: Request, admitted_at_step: int):
        self.req = req
        self.emitted: List[int] = []
        self.admitted_at_step = admitted_at_step
        self.stop = frozenset(req.stop_tokens or ())


class Scheduler:
    """Continuous-batching front-end for one :class:`Engine`.

    >>> sched = Scheduler(engine, n_slots=4)
    >>> sched.submit(Request(prompt, max_new_tokens=16))
    >>> done = sched.run()   # or: sched.step() in a serving loop

    Lifecycle/robustness knobs (all have serving-sane defaults):

    - ``prefill_chunk`` (tokens) switches admission to *chunked prefill*
      (DESIGN.md §12): a free slot claims a queued request immediately, but
      its prompt prefills at most ``prefill_chunk`` tokens per scheduler
      step, interleaved with the decode chunks of active streams — a long
      prompt admission never stalls running requests for its whole prefill.
      Chunk dispatches are padded to power-of-two buckets so they compile
      once per bucket, not once per prompt length. Requires
      ``engine.supports_chunked_prefill``; ``None`` (default) keeps the
      synchronous whole-prompt admission.
    - ``max_queue`` bounds the admission queue; a full queue rejects at
      ``submit`` with :class:`QueueFullError` (None = unbounded, for trusted
      batch drivers only).
    - ``retries``/``backoff_s``: bounded exponential-backoff retry around
      every engine dispatch.
    - ``nan_guard``: per-chunk non-finite-logit check; poisoned rows are
      FAILED and their slot scrubbed, neighbours untouched.
    - ``faults``: a :class:`FaultPlan` threaded through the dispatch points
      (deterministic fault injection; None in production).
    - ``clock``/``sleep``: injectable time sources — deadlines and backoff
      are wall-clock quantities, tests drive them with ``faults.StepClock``.
    - ``on_tokens(rid, tokens)``: streaming callback, fired at every chunk
      boundary with the request's newly visible (post-truncation) tokens.
    - ``on_event(record)``: fired at every terminal transition with the
      request's :class:`RequestLifecycle` (partial tokens attached).
    - ``tracer``: an optional :class:`repro.obs.trace.Tracer`. Lifecycle
      spans (queued/prefill/decode per request) are replayed from the
      record's *stored* timestamps at the terminal transition — tracing
      consumes zero extra scheduler-clock readings, so StepClock-driven
      deadline behaviour is untouched. Per-chunk spans read the tracer's
      own clock (``time.monotonic`` unless injected), a separate timebase
      by design (DESIGN.md §11).
    - ``metrics``: an optional :class:`repro.obs.metrics.MetricsRegistry`.
      Every ``counters`` increment goes through one helper that also bumps
      the registry's ``serve_<key>_total`` series, so the exported metrics
      agree with :meth:`summary` by construction, plus queue-depth /
      slot-occupancy gauges and TTFT/TPOT/e2e histograms.

    Both hooks observe strictly *between* engine dispatches; instrumented
    serving is bit-identical to uninstrumented (tests/test_obs.py).

    Threading: the scheduler itself is single-threaded — drive ``submit``/
    ``step``/``run`` from one thread (the async server pumps it from a
    dedicated thread). :meth:`cancel` only *flags*; the flag is applied at
    the next chunk boundary, which makes it safe to call from notification
    contexts as long as submits/steps stay on the pump thread.
    """

    def __init__(
        self,
        engine: Engine,
        n_slots: int = 4,
        chunk: int = 8,
        speculate: Optional[SpecConfig] = None,
        *,
        prefill_chunk: Optional[int] = None,
        max_queue: Optional[int] = 64,
        retries: int = 2,
        backoff_s: float = 0.05,
        nan_guard: bool = True,
        faults: Optional[FaultPlan] = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        on_tokens: Optional[Callable[[int, List[int]], None]] = None,
        on_event: Optional[Callable[[RequestLifecycle], None]] = None,
        tracer=None,
        metrics=None,
    ):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        if retries < 0:
            raise ValueError("retries must be >= 0")
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(
                    "prefill_chunk must be >= 1 (or None for synchronous "
                    "whole-prompt admission)"
                )
            if not engine.supports_chunked_prefill:
                raise ValueError(
                    "chunked prefill is unsupported for this engine's "
                    "architecture (ring-buffer/recurrent models pad-clobber "
                    "— see Engine.supports_chunked_prefill); use "
                    "prefill_chunk=None"
                )
        self.prefill_chunk = prefill_chunk
        self.engine = engine
        self.n_slots = n_slots
        self.chunk = chunk
        self.speculate = speculate
        self.max_queue = max_queue
        self.retries = retries
        self.backoff_s = backoff_s
        self.nan_guard = nan_guard
        self.faults = faults
        self.on_tokens = on_tokens
        self.on_event = on_event
        self._clock = clock
        self._sleep = sleep
        self.slots = engine.init_slots(n_slots, speculate=speculate)
        self.queue: Deque[Request] = deque()
        self._tenants: List[Optional[_Tenant]] = [None] * n_slots
        # chunked-prefill admissions in flight: slot -> (Request, pending)
        self._pending: Dict[int, tuple] = {}
        self.outcomes: Dict[int, RequestLifecycle] = {}
        self._pending_cancel: Dict[int, str] = {}
        self.decode_steps = 0  # total chunked decode steps executed
        self.steps_active = 0  # sum over steps of active slots (utilisation)
        self.chunk_rows = 0  # spec mode: row-chunks dispatched (accept-rate est.)
        self.counters: Dict[str, int] = {
            "rejected_queue_full": 0,
            "shed": 0,
            "cancelled": 0,
            "timed_out": 0,
            "failed": 0,
            "nan_quarantined": 0,
            "retries": 0,
            "decode_dispatch_failures": 0,
            "stopped_early": 0,
        }
        self._chunk_ordinal = 0  # decode dispatches over the lifetime
        self._rid_counter = itertools.count()
        self._used_rids = set()  # rids ever seen by THIS scheduler
        self.tracer = tracer
        self.metrics = metrics
        pc = getattr(engine, "prefix_cache", None)
        if pc is not None:
            # prefix metrics/trace instants share the serve exporter unless
            # the cache already has its own
            pc.attach(metrics=metrics, tracer=tracer)
        if metrics is not None:
            metrics.gauge(
                "serve_slot_capacity", "configured decode-batch slots"
            ).set(n_slots)
            # pre-register the zero-valued series so a scrape before traffic
            # (and the counters_agree check) sees every family
            for key in self.counters:
                metrics.counter(
                    f"serve_{key}_total", f"requests/events: {key}"
                )
            metrics.counter("serve_submitted_total",
                            "submit() calls incl. queue-full rejections")
            metrics.counter("serve_finished_total",
                            "requests that reached FINISHED")
            metrics.counter("serve_tokens_total", "committed output tokens")
            metrics.counter("serve_decode_chunks_total",
                            "decode/speculative chunk dispatches")

    def _count(self, key: str, n: int = 1) -> None:
        """The one place ``counters`` increments happen: keeps the host-side
        dict and the exported ``serve_<key>_total`` series in lockstep."""
        self.counters[key] += n
        if self.metrics is not None:
            self.metrics.counter(f"serve_{key}_total").inc(n)

    def _observe_gauges(self) -> None:
        if self.metrics is None:
            return
        active = self.n_active
        self.metrics.gauge(
            "serve_queue_depth", "requests waiting for admission"
        ).set(len(self.queue))
        self.metrics.gauge(
            "serve_active_slots", "slots with a live tenant"
        ).set(active)
        self.metrics.gauge(
            "serve_batch_efficiency",
            "active slots / capacity at the last chunk boundary",
        ).set(active / self.n_slots)
        if self.speculate is not None and self.chunk_rows:
            self.metrics.gauge(
                "serve_spec_accept_rate",
                "estimated draft-token acceptance rate",
            ).set(self.spec_accept_rate)

    def _trace_lifecycle(self, rec: RequestLifecycle) -> None:
        """Replay one finished record as spans on its ``req:<rid>`` lane —
        all timestamps come from the record (taken by the scheduler clock as
        part of normal lifecycle bookkeeping), so tracing adds no readings."""
        lane = f"req:{rec.rid}"
        # phase spans: QUEUED from submit to the first transition, then each
        # history entry runs to the next (the terminal entry has zero width
        # and is emitted as an instant with the reason attached)
        t_prev, name_prev = rec.submitted_at, "queued"
        for state, at in rec.history:
            self.tracer.complete(name_prev, t_prev, at, cat="lifecycle",
                                 lane=lane, args={"rid": rec.rid})
            t_prev, name_prev = at, state.value
        self.tracer.instant(
            rec.state.value, ts=rec.finished_at, cat="lifecycle", lane=lane,
            args={"rid": rec.rid, "reason": rec.reason,
                  "n_tokens": rec.n_tokens},
        )

    def _observe_latency(self, rec: RequestLifecycle) -> None:
        """Terminal-time latency histograms (each record reaches a terminal
        state exactly once — terminal states are terminal — so these observe
        once per request)."""
        if rec.ttft is not None:
            self.metrics.histogram(
                "serve_ttft_seconds", "submit -> first token"
            ).observe(rec.ttft)
        if rec.tpot is not None:
            self.metrics.histogram(
                "serve_tpot_seconds", "mean time per token after the first"
            ).observe(rec.tpot)
        if rec.finished_at is not None:
            self.metrics.histogram(
                "serve_e2e_seconds", "submit -> terminal state"
            ).observe(rec.finished_at - rec.submitted_at)

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request) -> int:
        plen = int(req.prompt.size)
        headroom = 0 if self.speculate is None else self.speculate.gamma + 1
        if plen + req.max_new_tokens + headroom > self.engine.max_seq:
            raise ValueError(
                f"request needs {plen + req.max_new_tokens + headroom} cache "
                f"rows (incl. {headroom} speculation headroom), engine "
                f"max_seq={self.engine.max_seq}"
            )
        vocab = self.engine.cfg.vocab
        if req.prompt.min() < 0 or req.prompt.max() >= vocab:
            raise ValueError(
                f"prompt token ids must lie in [0, vocab={vocab}); got range "
                f"[{req.prompt.min()}, {req.prompt.max()}] — out-of-range ids "
                f"index garbage embedding rows device-side"
            )
        if self.metrics is not None:
            # counted after validation, before the queue-full check: the
            # accounting invariant is finished + cancelled + timed_out + shed
            # + failed + rejected_queue_full == submitted, with rejections on
            # both sides (a ValueError above is a malformed call, not a
            # request)
            self.metrics.counter("serve_submitted_total").inc()
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            # loud reject-with-reason backpressure: the caller (or the async
            # server, which turns this into a per-client rejection) decides
            # whether to retry — the queue never grows without bound
            self._count("rejected_queue_full")
            if self.tracer is not None:
                self.tracer.instant(
                    "reject_queue_full", cat="admission", lane="scheduler",
                    args={"queued": len(self.queue)},
                )
            raise QueueFullError(
                f"admission queue full ({self.max_queue} waiting): request "
                f"rejected — resubmit later, shrink the burst, or raise "
                f"max_queue"
            )
        if req.rid is None:
            # skip values a caller-supplied rid already claimed: rids must be
            # unique per scheduler or `{c.rid: c for c in run()}` drops results
            req.rid = next(
                r for r in self._rid_counter if r not in self._used_rids
            )
        elif req.rid in self._used_rids:
            raise ValueError(
                f"rid {req.rid!r} already used in this scheduler (a Request "
                "submitted elsewhere keeps its assigned rid — pass a fresh "
                "Request or an explicit unique rid)"
            )
        self._used_rids.add(req.rid)
        rec = RequestLifecycle(rid=req.rid, submitted_at=self._clock())
        self.outcomes[req.rid] = rec
        self.queue.append(req)
        if self.tracer is not None:
            self.tracer.instant(
                "submit", ts=rec.submitted_at, cat="lifecycle",
                lane=f"req:{req.rid}",
                args={"rid": req.rid, "prompt_len": plen,
                      "max_new_tokens": req.max_new_tokens},
            )
        if self.metrics is not None:
            self._observe_gauges()
        return req.rid

    def cancel(self, rid: int, reason: str = "cancelled by client") -> bool:
        """Flag a request for cancellation; applied at the next chunk
        boundary (queued → removed before prefill, decoding → slot reclaimed
        with zero trace on surviving rows). Returns False if the rid is
        unknown or already terminal."""
        rec = self.outcomes.get(rid)
        if rec is None or rec.state.terminal:
            return False
        self._pending_cancel[rid] = reason
        return True

    @property
    def n_active(self) -> int:
        return sum(t is not None for t in self._tenants)

    @property
    def idle(self) -> bool:
        return not self.queue and self.n_active == 0 and not self._pending

    @property
    def spec_accept_rate(self) -> float:
        """Estimated draft-acceptance rate over all speculative dispatches:
        tokens per row-chunk is 1 + gamma * accept_rate (slight underestimate
        when rows finish mid-dispatch). 0.0 until a spec chunk has run."""
        if self.speculate is None or self.chunk_rows == 0:
            return 0.0
        tokens_per_row_chunk = self.steps_active / self.chunk_rows
        return max(0.0, (tokens_per_row_chunk - 1.0) / self.speculate.gamma)

    def summary(self) -> dict:
        """Lifecycle + latency summary: TTFT/TPOT percentiles over finished
        requests, terminal-state counts, and the robustness counters."""
        out = latency_summary(self.outcomes.values())
        out["counters"] = dict(self.counters)
        out["decode_steps"] = self.decode_steps
        return out

    # -- lifecycle internals -------------------------------------------------

    def _terminal(
        self,
        rec: RequestLifecycle,
        state: RequestState,
        reason: str,
        tokens: Optional[List[int]] = None,
    ) -> None:
        rec.transition(state, self._clock(), reason)
        rec.new_tokens = np.asarray(tokens or [], np.int32)  # staticcheck: host-sync(tokens already host-side)
        rec.n_tokens = int(rec.new_tokens.size)
        if self.tracer is not None:
            self._trace_lifecycle(rec)
        if self.metrics is not None:
            self._observe_latency(rec)
        if self.on_event is not None:
            self.on_event(rec)

    def _evict(self, slot: int, state: RequestState, reason: str) -> None:
        """Reclaim a slot mid-flight (cancel/timeout/quarantine): terminal
        transition with the partial tokens attached, then deactivate the row
        so it stops consuming decode steps. The next admission overwrites the
        row's entire state (slot-reset contract, DESIGN.md §4) — zero trace
        on surviving rows."""
        tenant = self._tenants[slot]
        assert tenant is not None
        self._terminal(
            self.outcomes[tenant.req.rid], state, reason, tokens=tenant.emitted
        )
        self._tenants[slot] = None
        self.slots = self.engine.release_slot(self.slots, slot)

    def _apply_cancels(self) -> None:
        if not self._pending_cancel:
            return
        keep: Deque[Request] = deque()
        for req in self.queue:
            reason = self._pending_cancel.pop(req.rid, None)
            if reason is None:
                keep.append(req)
            else:
                self._count("cancelled")
                self._terminal(
                    self.outcomes[req.rid], RequestState.CANCELLED, reason
                )
        self.queue = keep
        # chunked-prefill admissions cancel between their chunks: unpin the
        # prefix handle and drop the pending state — the slot row was never
        # written, so it is simply free again
        for slot, (req, pending) in list(self._pending.items()):
            reason = self._pending_cancel.pop(req.rid, None)
            if reason is not None:
                self._count("cancelled")
                self.engine.abort_admission(pending)
                del self._pending[slot]
                self._terminal(
                    self.outcomes[req.rid], RequestState.CANCELLED, reason
                )
        for slot, tenant in enumerate(self._tenants):
            if tenant is None:
                continue
            reason = self._pending_cancel.pop(tenant.req.rid, None)
            if reason is not None:
                self._count("cancelled")
                self._evict(slot, RequestState.CANCELLED, reason)
        self._pending_cancel.clear()  # unknown/raced rids: nothing to do

    def _enforce_deadlines(self) -> None:
        now = self._clock()
        # queued requests whose deadline already expired are shed before they
        # waste a prefill — deadline-aware queue shedding
        keep: Deque[Request] = deque()
        for req in self.queue:
            rec = self.outcomes[req.rid]
            waited = now - rec.submitted_at
            expired = None
            if req.ttft_deadline_s is not None and waited > req.ttft_deadline_s:
                expired = (
                    f"shed in queue: TTFT deadline {req.ttft_deadline_s}s "
                    f"expired after {waited:.3f}s waiting"
                )
            elif req.deadline_s is not None and waited > req.deadline_s:
                expired = (
                    f"shed in queue: deadline {req.deadline_s}s expired "
                    f"after {waited:.3f}s waiting"
                )
            if expired is None:
                keep.append(req)
            else:
                self._count("shed")
                self._terminal(rec, RequestState.SHED, expired)
        self.queue = keep
        # mid-prefill deadlines (chunked admissions span many steps): the
        # TTFT deadline always applies — no first token yet by definition
        for slot, (req, pending) in list(self._pending.items()):
            rec = self.outcomes[req.rid]
            age = now - rec.submitted_at
            expired = None
            if req.deadline_s is not None and age > req.deadline_s:
                expired = (
                    f"deadline {req.deadline_s}s exceeded mid-prefill "
                    f"({pending.pos}/{pending.plen} prompt tokens)"
                )
            elif req.ttft_deadline_s is not None and age > req.ttft_deadline_s:
                expired = (
                    f"TTFT deadline {req.ttft_deadline_s}s exceeded "
                    f"mid-prefill ({pending.pos}/{pending.plen} prompt tokens)"
                )
            if expired is not None:
                self._count("timed_out")
                self.engine.abort_admission(pending)
                del self._pending[slot]
                self._terminal(rec, RequestState.TIMED_OUT, expired)
        for slot, tenant in enumerate(self._tenants):
            if tenant is None:
                continue
            req = tenant.req
            rec = self.outcomes[req.rid]
            age = now - rec.submitted_at
            if req.deadline_s is not None and age > req.deadline_s:
                self._count("timed_out")
                self._evict(
                    slot,
                    RequestState.TIMED_OUT,
                    f"deadline {req.deadline_s}s exceeded after "
                    f"{len(tenant.emitted)} tokens",
                )
            elif (
                req.ttft_deadline_s is not None
                and rec.first_token_at is None
                and age > req.ttft_deadline_s
            ):
                self._count("timed_out")
                self._evict(
                    slot,
                    RequestState.TIMED_OUT,
                    f"TTFT deadline {req.ttft_deadline_s}s exceeded before "
                    f"first token",
                )

    def _with_retry(self, fn, what: str):
        """Bounded exponential-backoff retry around one engine dispatch.

        Sound for failures raised *before* the dispatch consumes its (donated)
        inputs — which is where FaultPlan injects and where argument/shape
        validation fails. A failure that killed the donated slot state anyway
        is caught one level up: exhausted decode retries rebuild the slot
        state from scratch."""
        delay = self.backoff_s
        last: Optional[Exception] = None
        for attempt in range(self.retries + 1):
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 — retry then re-raise below
                last = e
                if attempt < self.retries:
                    self._count("retries")
                    if self.tracer is not None:
                        self.tracer.instant(
                            "retry", cat="fault", lane="scheduler",
                            args={"what": what, "attempt": attempt + 1,
                                  "error": repr(e)},
                        )
                    self._sleep(delay)
                    delay *= 2
        raise DispatchError(
            f"{what} failed after {self.retries + 1} attempt(s): {last!r}"
        ) from last

    # -- scheduling ----------------------------------------------------------

    def _record_tokens(self, tenant: _Tenant, new: List[int]) -> bool:
        """Append a chunk's newly emitted tokens to the tenant, honouring its
        stop set (truncation keeps the stop token). Fires the streaming
        callback with exactly the visible tokens. Returns True if a stop
        token ended the request."""
        rec = self.outcomes[tenant.req.rid]
        stopped = False
        if tenant.stop:
            for i, t in enumerate(new):
                if t in tenant.stop:
                    new = new[: i + 1]
                    stopped = True
                    break
        if new:
            if rec.first_token_at is None:
                rec.first_token_at = self._clock()
            tenant.emitted.extend(new)
            rec.n_tokens = len(tenant.emitted)
            if self.metrics is not None:
                self.metrics.counter("serve_tokens_total").inc(len(new))
            if self.on_tokens is not None:
                self.on_tokens(tenant.req.rid, list(new))
        return stopped

    def _finish(self, slot: int, *, stopped: bool) -> Completion:
        """FINISH a tenant: budget exhausted or stop token hit. Early stops
        release the slot (the device row is still active); budget exhaustion
        already deactivated the row on device."""
        tenant = self._tenants[slot]
        assert tenant is not None
        if stopped:
            self._count("stopped_early")
            self.slots = self.engine.release_slot(self.slots, slot)
        if self.metrics is not None:
            self.metrics.counter("serve_finished_total").inc()
        self._terminal(
            self.outcomes[tenant.req.rid],
            RequestState.FINISHED,
            "stop token" if stopped else "budget exhausted",
            tokens=tenant.emitted,
        )
        self._tenants[slot] = None  # freed; refilled next chunk boundary
        return Completion(
            rid=tenant.req.rid,
            prompt=tenant.req.prompt,
            new_tokens=np.asarray(tenant.emitted, np.int32),  # staticcheck: host-sync(emitted list is host-side)
            admitted_at_step=tenant.admitted_at_step,
            finished_at_step=self.decode_steps,
            stopped=stopped,
        )

    def _note_prefix(self, rec: RequestLifecycle, prompt_len: int) -> None:
        """Stamp prefix-cache hit stats onto the lifecycle record and emit
        the ``cache_hit`` trace instant (DESIGN.md §12 observability)."""
        h = self.engine.take_prefix_handle()
        if h is None:
            return
        rec.prefix_hit_tokens = h.length
        if h.length and self.tracer is not None:
            self.tracer.instant(
                "cache_hit", cat="prefix", lane="scheduler",
                args={"rid": rec.rid, "hit_tokens": h.length,
                      "prompt_len": prompt_len},
            )

    def _install_tenant(self, slot: int, req: Request) -> Optional[Completion]:
        """Post-admission bookkeeping shared by both admission modes: the
        DECODING transition, tenant install, and (spec mode) emitting the
        first token sampled at admission — which can complete a budget-1
        request right here."""
        rec = self.outcomes[req.rid]
        rec.transition(RequestState.DECODING, self._clock())
        self._note_prefix(rec, int(req.prompt.size))
        tenant = _Tenant(req, self.decode_steps)
        self._tenants[slot] = tenant
        if self.speculate is not None:
            t0 = int(np.asarray(self.slots["t_pend"][slot]))  # staticcheck: host-sync(per-admission fetch of the pre-sampled first token)
            stopped = self._record_tokens(tenant, [t0])
            if stopped or len(tenant.emitted) >= req.max_new_tokens:
                return self._finish(slot, stopped=stopped)
        return None

    def _admit_free_slots(self) -> List[Completion]:
        """Fill free slots from the queue. In speculative mode admission also
        emits the request's first token (sampled from its own prefill logits
        on device), so a budget-1 request can complete right here — returned
        so its slot frees up for the same admission round. A prefill dispatch
        that keeps failing quarantines only the admitting request; the slot
        stays free for the next queued request in the same round.

        With ``prefill_chunk`` set, admission is *chunked* instead
        (DESIGN.md §12): free slots claim queued requests, but each pending
        admission prefills at most ``prefill_chunk`` prompt tokens per step,
        so one long prompt never stalls the decode chunks of active streams.
        """
        if self.prefill_chunk is not None:
            return self._admit_chunked()
        done: List[Completion] = []
        for slot in range(self.n_slots):
            while self.queue and self._tenants[slot] is None:
                req = self.queue.popleft()
                rec = self.outcomes[req.rid]
                rec.transition(RequestState.PREFILLING, self._clock())

                def dispatch(req=req, slot=slot):
                    if self.faults is not None:
                        self.faults.on_prefill(req.rid)
                    return self.engine.admit_slot(
                        self.slots,
                        slot,
                        req.prompt,
                        max_new_tokens=req.max_new_tokens,
                        temperature=req.temperature,
                        seed=req.seed,
                        speculate=req.speculate is not False,
                    )

                try:
                    self.slots = self._with_retry(
                        dispatch, what=f"admission prefill (request {req.rid})"
                    )
                except DispatchError as e:
                    self._count("failed")
                    self._terminal(rec, RequestState.FAILED, str(e))
                    continue  # slot still free: try the next queued request
                rec.prefill_chunks = 1
                c = self._install_tenant(slot, req)
                if c is not None:
                    done.append(c)
        return done

    def _admit_chunked(self) -> List[Completion]:
        """Chunked admission: claim free slots (prefix lookup + install —
        host trie walk plus at most one row-install dispatch), then advance
        every pending admission by ``prefill_chunk`` prompt tokens. An
        admission that completes installs its tenant this same step; one
        that keeps failing is FAILED alone, its prefix pins released."""
        done: List[Completion] = []
        for slot in range(self.n_slots):
            if self._tenants[slot] is not None or slot in self._pending:
                continue
            if not self.queue:
                break
            req = self.queue.popleft()
            rec = self.outcomes[req.rid]
            rec.transition(RequestState.PREFILLING, self._clock())

            def begin(req=req):
                if self.faults is not None:
                    self.faults.on_prefill(req.rid)
                return self.engine.begin_admission(
                    req.prompt,
                    max_new_tokens=req.max_new_tokens,
                    temperature=req.temperature,
                    seed=req.seed,
                    speculate=req.speculate is not False,
                    chunked=True,
                )

            try:
                pending = self._with_retry(
                    begin, what=f"admission begin (request {req.rid})"
                )
            except DispatchError as e:
                self._count("failed")
                self._terminal(rec, RequestState.FAILED, str(e))
                continue
            self._pending[slot] = (req, pending)
        for slot, (req, pending) in sorted(self._pending.items()):
            rec = self.outcomes[req.rid]

            def advance(pending=pending):
                return self.engine.advance_admission(
                    pending, self.prefill_chunk
                )

            def fail(e: DispatchError) -> None:
                self.engine.abort_admission(pending)
                del self._pending[slot]
                self._count("failed")
                self._terminal(rec, RequestState.FAILED, str(e))

            try:
                self._with_retry(
                    advance, what=f"prefill chunk (request {req.rid})"
                )
            except DispatchError as e:
                fail(e)
                continue
            if not pending.done:
                continue

            def install(pending=pending, slot=slot):
                return self.engine.finish_admission(self.slots, slot, pending)

            try:
                self.slots = self._with_retry(
                    install, what=f"admission install (request {req.rid})"
                )
            except DispatchError as e:
                fail(e)
                continue
            del self._pending[slot]
            rec.prefill_chunks = pending.prefill_chunks
            c = self._install_tenant(slot, req)
            if c is not None:
                done.append(c)
        return done

    def _harvest(self, slot: int) -> Optional[Completion]:
        tenant = self._tenants[slot]
        if tenant is None or len(tenant.emitted) < tenant.req.max_new_tokens:
            return None
        assert len(tenant.emitted) == tenant.req.max_new_tokens, (
            "device active-mask emitted past the request budget"
        )
        return self._finish(slot, stopped=False)

    def _dispatch_decode(self):
        """One decode (or speculative) chunk with fault injection + bounded
        retry. Returns the (tokens, valid, slots) triple, or None after
        exhausted retries — in which case every *active* tenant is FAILED
        (they are the affected requests; their device state may be
        unrecoverable) and the slot state is rebuilt so queued requests still
        serve."""
        ordinal = self._chunk_ordinal
        self._chunk_ordinal += 1

        def dispatch():
            if self.faults is not None:
                self.faults.on_chunk(ordinal)
            if self.speculate is None:
                return self.engine.decode_slots(self.slots, self.chunk)
            return self.engine.spec_decode_slots(self.slots, self.chunk)

        try:
            return self._with_retry(dispatch, what=f"decode chunk {ordinal}")
        except DispatchError as e:
            self._count("decode_dispatch_failures")
            for slot, tenant in enumerate(self._tenants):
                if tenant is None:
                    continue
                self._count("failed")
                tenant_rec = self.outcomes[tenant.req.rid]
                self._terminal(
                    tenant_rec, RequestState.FAILED, str(e), tokens=tenant.emitted
                )
                self._tenants[slot] = None
            # the failed dispatch may have consumed (donated) the old slot
            # buffers — rebuild from scratch rather than risk dead buffers
            self.slots = self.engine.init_slots(
                self.n_slots, speculate=self.speculate
            )
            return None

    def _inject_and_guard_nan(self) -> None:
        """Post-chunk NaN handling: (a) FaultPlan poisons due rows (exactly
        what an upstream numerical fault leaves behind); (b) the guard fails
        and quarantines every non-finite row — slot scrubbed and refilled at
        the next boundary, neighbours untouched."""
        if self.faults is not None:
            for slot, tenant in enumerate(self._tenants):
                if tenant is not None and self.faults.poison_due(
                    tenant.req.rid, len(tenant.emitted)
                ):
                    self.slots = self.engine.poison_logit_row(self.slots, slot)
        if not self.nan_guard:
            return
        occupied = [s for s, t in enumerate(self._tenants) if t is not None]
        if not occupied:
            return
        finite = self.engine.finite_logit_rows(self.slots)
        for slot in occupied:
            if not finite[slot]:
                self._count("nan_quarantined")
                self._count("failed")
                if self.tracer is not None:
                    tenant = self._tenants[slot]
                    self.tracer.instant(
                        "nan_quarantine", cat="fault", lane="scheduler",
                        args={"slot": slot, "rid": tenant.req.rid},
                    )
                self._evict(
                    slot,
                    RequestState.FAILED,
                    "non-finite logits: row quarantined (slot scrubbed; "
                    "neighbours unaffected)",
                )

    def step(self) -> List[Completion]:
        """One chunk boundary: apply cancels, enforce deadlines, admit into
        free slots, run one decode chunk, harvest completions, guard NaNs."""
        done: List[Completion] = []
        self._apply_cancels()
        self._enforce_deadlines()
        done.extend(self._admit_free_slots())
        if self.n_active == 0:
            self._observe_gauges()
            return done
        # the chunk span reads the *tracer's* clock (never the scheduler's:
        # tracing must not perturb StepClock-driven deadlines); a no-op
        # handle when tracer is None/disabled
        # the span covers dispatch AND the chunk-boundary host fetch — the
        # fetch is the sync point, so this is the chunk's true wall time; it
        # reads the *tracer's* clock (never the scheduler's: tracing must not
        # perturb StepClock-driven deadlines)
        span = (
            self.tracer.span(
                "decode_chunk", cat="scheduler", lane="scheduler",
                ordinal=self._chunk_ordinal, active=self.n_active,
                chunk=self.chunk, spec=self.speculate is not None,
            )
            if self.tracer is not None
            else None
        )
        if span is not None:
            span.__enter__()
        try:
            res = self._dispatch_decode()
            if self.metrics is not None:
                self.metrics.counter("serve_decode_chunks_total").inc()
            if res is None:
                self._observe_gauges()
                return done
            toks, valid, self.slots = res
            self.decode_steps += self.chunk
            if self.speculate is not None:
                self.chunk_rows += self.n_active * self.chunk
            toks = np.asarray(toks)  # (B, chunk) / (B, chunk*(gamma+1))  # staticcheck: host-sync(the one documented per-chunk fetch)
            valid = np.asarray(valid)  # staticcheck: host-sync(the one documented per-chunk fetch)
            committed = int(valid.sum())  # staticcheck: host-sync(valid already fetched above)
            self.steps_active += committed
            if span is not None:
                span.annotate(tokens=committed)
        finally:
            if span is not None:
                span.__exit__(None, None, None)
        if self.speculate is not None and self.tracer is not None:
            self._trace_spec_subchunks(valid)
        if self.metrics is not None:
            self.metrics.histogram(
                "serve_chunk_commit_tokens",
                "tokens committed per decode chunk",
                buckets=tuple(float(2**i) for i in range(10)),
            ).observe(committed)

        for slot, tenant in enumerate(self._tenants):
            if tenant is None:
                continue
            stopped = self._record_tokens(
                tenant, [int(t) for t in toks[slot][valid[slot]]]
            )
            if stopped:
                done.append(self._finish(slot, stopped=True))
            else:
                c = self._harvest(slot)
                if c is not None:
                    done.append(c)
        self._inject_and_guard_nan()
        self._observe_gauges()
        return done

    def _trace_spec_subchunks(self, valid: np.ndarray) -> None:
        """Speculative draft/verify/rollback annotation. Draft + verify are
        fused into the one device dispatch the chunk span already covers, so
        per-sub-chunk *timing* needs ``jax.profiler`` (DESIGN.md §11); what
        the host does know exactly — per row and sub-chunk, how many drafted
        tokens the verifier kept and how many rolled back — is emitted as
        ``spec_verify`` instants derived from the fetched valid mask."""
        gamma = self.speculate.gamma
        ordinal = self._chunk_ordinal - 1
        for slot, tenant in enumerate(self._tenants):
            if tenant is None:
                continue
            if tenant.req.speculate is False:
                continue  # plain rows have no draft to account for
            # valid row layout: chunk sub-chunks of (gamma accepted-draft
            # slots + 1 bonus/target token)
            sub = valid[slot].reshape(self.chunk, gamma + 1)
            for j in range(self.chunk):
                committed = int(sub[j].sum())  # staticcheck: host-sync(valid mask already fetched at the chunk boundary)
                if committed == 0:
                    continue  # row went inactive before this sub-chunk
                accepted = max(0, committed - 1)
                self.tracer.instant(
                    "spec_verify", cat="speculative",
                    lane=f"req:{tenant.req.rid}",
                    args={"chunk": ordinal, "sub": j, "drafted": gamma,
                          "accepted": accepted,
                          "rolled_back": gamma - accepted},
                )

    def run(self, max_chunks: int = 100_000) -> List[Completion]:
        """Drain the queue completely; returns completions in finish order.
        Requests that end CANCELLED/TIMED_OUT/FAILED/SHED do not produce a
        Completion — read their terminal records from ``outcomes`` (or stream
        them via ``on_event``)."""
        out: List[Completion] = []
        for _ in range(max_chunks):
            if self.idle:
                return out
            out.extend(self.step())
        raise RuntimeError(f"scheduler did not drain within {max_chunks} chunks")
