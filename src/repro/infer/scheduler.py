"""Continuous-batching scheduler over the slot-batched decode path.

The paper's headline number is decode-phase throughput on a *serving*
workload (§V: OPT-175B token generation): the LUT/BCQ kernels only pay off
end-to-end if the decode batch stays fed. One-shot ``Engine.generate`` runs a
fixed batch in lockstep — every request waits for the longest one, and the
batch drains as requests finish. This module keeps a fixed-width decode batch
full instead (Orca-style continuous batching):

- requests wait in an **admission queue**;
- the decode batch has ``n_slots`` **slots**; a free slot is filled by
  prefilling the next queued request (batch-1) and scatter-installing its KV
  rows, position counter, PRNG key and sampling params into the slot
  (``Engine.admit_slot``);
- decode runs in **chunks** of ``chunk`` scanned steps over the whole batch
  (``Engine.decode_slots``); per-slot active masks let requests finish
  mid-chunk without stalling neighbours;
- a finished slot is freed and refilled at the next chunk boundary.

Correctness contract (tests/test_scheduler.py): the interleaving is
*invisible* — each request's tokens are identical to running it alone through
``Engine.generate(prompt, max_new_tokens, temperature=..., seed=...)``. This
holds because batch rows are fully independent in the model forward (per-slot
positions, per-slot cache rows, per-slot PRNG streams) and the batched
per-row compute is bitwise equal to the batch-1 compute. MoE families are the
documented exception: expert-capacity dropping couples batch rows, so
continuous batching there is throughput-correct but not token-identical.

Admission happens at chunk boundaries only: ``chunk=1`` gives per-token
admission (lowest queue latency), larger chunks amortise dispatch overhead
across more decode steps (highest host throughput). Completion detection is
host-side (the per-request budget is known), deactivation is device-side (the
active mask inside the scan), so a mid-chunk finish never emits extra tokens.

**Tensor-parallel serving** (``Scheduler(Engine(cfg, params, mesh=...))``,
DESIGN.md §7): the scheduler is sharding-agnostic — slots, admission and
budgets live on the host exactly as below, while every engine dispatch it
drives (admission prefill, decode chunks, speculative chunks) consumes
TP-sharded weights and KV caches under ``shard_map``. Greedy completions
stay bit-identical to the single-device engine; sampled completions remain
solo-identical *within* the same sharded engine (tests/test_tp_serve.py).

**Speculative serving** (``Scheduler(engine, speculate=SpecConfig(...))``,
DESIGN.md §5): decode dispatches become draft-verify-accept chunks — each
chunk commits 1..gamma+1 tokens per row instead of exactly one. Requests opt
in per row (``Request.speculate``); opted-out rows run one plain target step
per chunk with their solo-identical PRNG stream. Greedy speculative rows are
token-identical to solo plain ``generate``; sampled speculative rows follow
the exact target distribution but a different stream for the same seed
(rejection sampling consumes randomness differently). The request's first
token is sampled at admission (it comes from the target's own prefill
logits), so a chunk always has a pending token to verify behind.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.infer.engine import Engine
from repro.infer.speculative import SpecConfig


@dataclasses.dataclass
class Request:
    """One generation request. `seed`/`temperature` are per-request: mixed
    greedy and sampled requests share a batch. ``speculate`` opts this request
    in/out of speculative decoding when the scheduler runs a speculative slot
    batch (None → the scheduler's default: in); it is ignored otherwise."""

    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    speculate: Optional[bool] = None
    rid: Optional[int] = None  # assigned at submit() if None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # temperature feeds `logits / temperature` on device: NaN/inf would
        # poison sampling silently (NaN fails every `<= 0` greedy check and
        # then divides the logits), negative values would invert the
        # distribution. Exactly 0.0 means greedy by convention.
        if not np.isfinite(self.temperature):
            raise ValueError(
                f"temperature must be finite, got {self.temperature!r}"
            )
        if self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0 (0 = greedy), got {self.temperature!r}"
            )
        self.temperature = float(self.temperature)


@dataclasses.dataclass
class Completion:
    rid: int
    prompt: np.ndarray  # (prompt_len,)
    new_tokens: np.ndarray  # (max_new_tokens,)
    admitted_at_step: int  # scheduler decode-step counter at admission
    finished_at_step: int

    @property
    def tokens(self) -> np.ndarray:
        """prompt + generation, the same layout GenerationResult.tokens uses."""
        return np.concatenate([self.prompt, self.new_tokens])


class _Tenant:
    __slots__ = ("req", "emitted", "admitted_at_step")

    def __init__(self, req: Request, admitted_at_step: int):
        self.req = req
        self.emitted: List[int] = []
        self.admitted_at_step = admitted_at_step


class Scheduler:
    """Continuous-batching front-end for one :class:`Engine`.

    >>> sched = Scheduler(engine, n_slots=4)
    >>> sched.submit(Request(prompt, max_new_tokens=16))
    >>> done = sched.run()   # or: sched.step() in a serving loop
    """

    def __init__(
        self,
        engine: Engine,
        n_slots: int = 4,
        chunk: int = 8,
        speculate: Optional[SpecConfig] = None,
    ):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.engine = engine
        self.n_slots = n_slots
        self.chunk = chunk
        self.speculate = speculate
        self.slots = engine.init_slots(n_slots, speculate=speculate)
        self.queue: Deque[Request] = deque()
        self._tenants: List[Optional[_Tenant]] = [None] * n_slots
        self.decode_steps = 0  # total chunked decode steps executed
        self.steps_active = 0  # sum over steps of active slots (utilisation)
        self.chunk_rows = 0  # spec mode: row-chunks dispatched (accept-rate est.)
        self._rid_counter = itertools.count()
        self._used_rids = set()  # rids ever seen by THIS scheduler

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request) -> int:
        plen = int(req.prompt.size)
        headroom = 0 if self.speculate is None else self.speculate.gamma + 1
        if plen + req.max_new_tokens + headroom > self.engine.max_seq:
            raise ValueError(
                f"request needs {plen + req.max_new_tokens + headroom} cache "
                f"rows (incl. {headroom} speculation headroom), engine "
                f"max_seq={self.engine.max_seq}"
            )
        if req.rid is None:
            # skip values a caller-supplied rid already claimed: rids must be
            # unique per scheduler or `{c.rid: c for c in run()}` drops results
            req.rid = next(
                r for r in self._rid_counter if r not in self._used_rids
            )
        elif req.rid in self._used_rids:
            raise ValueError(
                f"rid {req.rid!r} already used in this scheduler (a Request "
                "submitted elsewhere keeps its assigned rid — pass a fresh "
                "Request or an explicit unique rid)"
            )
        self._used_rids.add(req.rid)
        self.queue.append(req)
        return req.rid

    @property
    def n_active(self) -> int:
        return sum(t is not None for t in self._tenants)

    @property
    def idle(self) -> bool:
        return not self.queue and self.n_active == 0

    @property
    def spec_accept_rate(self) -> float:
        """Estimated draft-acceptance rate over all speculative dispatches:
        tokens per row-chunk is 1 + gamma * accept_rate (slight underestimate
        when rows finish mid-dispatch). 0.0 until a spec chunk has run."""
        if self.speculate is None or self.chunk_rows == 0:
            return 0.0
        tokens_per_row_chunk = self.steps_active / self.chunk_rows
        return max(0.0, (tokens_per_row_chunk - 1.0) / self.speculate.gamma)

    # -- scheduling ----------------------------------------------------------

    def _admit_free_slots(self) -> List[Completion]:
        """Fill free slots from the queue. In speculative mode admission also
        emits the request's first token (sampled from its own prefill logits
        on device), so a budget-1 request can complete right here — returned
        so its slot frees up for the same admission round."""
        done: List[Completion] = []
        for slot in range(self.n_slots):
            while self.queue and self._tenants[slot] is None:
                req = self.queue.popleft()
                self.slots = self.engine.admit_slot(
                    self.slots,
                    slot,
                    req.prompt,
                    max_new_tokens=req.max_new_tokens,
                    temperature=req.temperature,
                    seed=req.seed,
                    speculate=req.speculate is not False,
                )
                tenant = _Tenant(req, self.decode_steps)
                self._tenants[slot] = tenant
                if self.speculate is not None:
                    tenant.emitted.append(int(np.asarray(self.slots["t_pend"][slot])))
                    c = self._harvest(slot)
                    if c is not None:
                        done.append(c)  # budget-1: finished at admission
        return done

    def _harvest(self, slot: int) -> Optional[Completion]:
        tenant = self._tenants[slot]
        if tenant is None or len(tenant.emitted) < tenant.req.max_new_tokens:
            return None
        assert len(tenant.emitted) == tenant.req.max_new_tokens, (
            "device active-mask emitted past the request budget"
        )
        self._tenants[slot] = None  # freed; refilled next chunk boundary
        return Completion(
            rid=tenant.req.rid,
            prompt=tenant.req.prompt,
            new_tokens=np.asarray(tenant.emitted, np.int32),
            admitted_at_step=tenant.admitted_at_step,
            finished_at_step=self.decode_steps,
        )

    def step(self) -> List[Completion]:
        """Admit into free slots, run one decode chunk, harvest completions."""
        done = self._admit_free_slots()
        if self.n_active == 0:
            return done
        if self.speculate is None:
            toks, valid, self.slots = self.engine.decode_slots(self.slots, self.chunk)
            self.decode_steps += self.chunk
        else:
            toks, valid, self.slots = self.engine.spec_decode_slots(
                self.slots, self.chunk
            )
            self.decode_steps += self.chunk
            self.chunk_rows += self.n_active * self.chunk
        toks = np.asarray(toks)  # (B, chunk) / (B, chunk*(gamma+1))
        valid = np.asarray(valid)
        self.steps_active += int(valid.sum())

        for slot, tenant in enumerate(self._tenants):
            if tenant is None:
                continue
            tenant.emitted.extend(int(t) for t in toks[slot][valid[slot]])
            c = self._harvest(slot)
            if c is not None:
                done.append(c)
        return done

    def run(self, max_chunks: int = 100_000) -> List[Completion]:
        """Drain the queue completely; returns completions in finish order."""
        out: List[Completion] = []
        for _ in range(max_chunks):
            if self.idle:
                return out
            out.extend(self.step())
        raise RuntimeError(f"scheduler did not drain within {max_chunks} chunks")
