"""Continuous-batching scheduler over the slot-batched decode path.

The paper's headline number is decode-phase throughput on a *serving*
workload (§V: OPT-175B token generation): the LUT/BCQ kernels only pay off
end-to-end if the decode batch stays fed. One-shot ``Engine.generate`` runs a
fixed batch in lockstep — every request waits for the longest one, and the
batch drains as requests finish. This module keeps a fixed-width decode batch
full instead (Orca-style continuous batching):

- requests wait in an **admission queue**;
- the decode batch has ``n_slots`` **slots**; a free slot is filled by
  prefilling the next queued request (batch-1) and scatter-installing its KV
  rows, position counter, PRNG key and sampling params into the slot
  (``Engine.admit_slot``);
- decode runs in **chunks** of ``chunk`` scanned steps over the whole batch
  (``Engine.decode_slots``); per-slot active masks let requests finish
  mid-chunk without stalling neighbours;
- a finished slot is freed and refilled at the next chunk boundary.

Correctness contract (tests/test_scheduler.py): the interleaving is
*invisible* — each request's tokens are identical to running it alone through
``Engine.generate(prompt, max_new_tokens, temperature=..., seed=...)``. This
holds because batch rows are fully independent in the model forward (per-slot
positions, per-slot cache rows, per-slot PRNG streams) and the batched
per-row compute is bitwise equal to the batch-1 compute. MoE families are the
documented exception: expert-capacity dropping couples batch rows, so
continuous batching there is throughput-correct but not token-identical.

Admission happens at chunk boundaries only: ``chunk=1`` gives per-token
admission (lowest queue latency), larger chunks amortise dispatch overhead
across more decode steps (highest host throughput). Completion detection is
host-side (the per-request budget is known), deactivation is device-side (the
active mask inside the scan), so a mid-chunk finish never emits extra tokens.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.infer.engine import Engine


@dataclasses.dataclass
class Request:
    """One generation request. `seed`/`temperature` are per-request: mixed
    greedy and sampled requests share a batch."""

    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    rid: Optional[int] = None  # assigned at submit() if None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass
class Completion:
    rid: int
    prompt: np.ndarray  # (prompt_len,)
    new_tokens: np.ndarray  # (max_new_tokens,)
    admitted_at_step: int  # scheduler decode-step counter at admission
    finished_at_step: int

    @property
    def tokens(self) -> np.ndarray:
        """prompt + generation, the same layout GenerationResult.tokens uses."""
        return np.concatenate([self.prompt, self.new_tokens])


class _Tenant:
    __slots__ = ("req", "emitted", "admitted_at_step")

    def __init__(self, req: Request, admitted_at_step: int):
        self.req = req
        self.emitted: List[int] = []
        self.admitted_at_step = admitted_at_step


class Scheduler:
    """Continuous-batching front-end for one :class:`Engine`.

    >>> sched = Scheduler(engine, n_slots=4)
    >>> sched.submit(Request(prompt, max_new_tokens=16))
    >>> done = sched.run()   # or: sched.step() in a serving loop
    """

    def __init__(self, engine: Engine, n_slots: int = 4, chunk: int = 8):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.engine = engine
        self.n_slots = n_slots
        self.chunk = chunk
        self.slots = engine.init_slots(n_slots)
        self.queue: Deque[Request] = deque()
        self._tenants: List[Optional[_Tenant]] = [None] * n_slots
        self.decode_steps = 0  # total chunked decode steps executed
        self.steps_active = 0  # sum over steps of active slots (utilisation)
        self._rid_counter = itertools.count()
        self._used_rids = set()  # rids ever seen by THIS scheduler

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request) -> int:
        plen = int(req.prompt.size)
        if plen + req.max_new_tokens > self.engine.max_seq:
            raise ValueError(
                f"request needs {plen + req.max_new_tokens} cache rows, "
                f"engine max_seq={self.engine.max_seq}"
            )
        if req.rid is None:
            # skip values a caller-supplied rid already claimed: rids must be
            # unique per scheduler or `{c.rid: c for c in run()}` drops results
            req.rid = next(
                r for r in self._rid_counter if r not in self._used_rids
            )
        elif req.rid in self._used_rids:
            raise ValueError(
                f"rid {req.rid!r} already used in this scheduler (a Request "
                "submitted elsewhere keeps its assigned rid — pass a fresh "
                "Request or an explicit unique rid)"
            )
        self._used_rids.add(req.rid)
        self.queue.append(req)
        return req.rid

    @property
    def n_active(self) -> int:
        return sum(t is not None for t in self._tenants)

    @property
    def idle(self) -> bool:
        return not self.queue and self.n_active == 0

    # -- scheduling ----------------------------------------------------------

    def _admit_free_slots(self) -> None:
        for slot in range(self.n_slots):
            if not self.queue:
                return
            if self._tenants[slot] is None:
                req = self.queue.popleft()
                self.slots = self.engine.admit_slot(
                    self.slots,
                    slot,
                    req.prompt,
                    max_new_tokens=req.max_new_tokens,
                    temperature=req.temperature,
                    seed=req.seed,
                )
                self._tenants[slot] = _Tenant(req, self.decode_steps)

    def step(self) -> List[Completion]:
        """Admit into free slots, run one decode chunk, harvest completions."""
        self._admit_free_slots()
        if self.n_active == 0:
            return []
        toks, actives, self.slots = self.engine.decode_slots(self.slots, self.chunk)
        toks = np.asarray(toks)  # (B, chunk)
        actives = np.asarray(actives)
        self.decode_steps += self.chunk
        self.steps_active += int(actives.sum())

        done: List[Completion] = []
        for slot, tenant in enumerate(self._tenants):
            if tenant is None:
                continue
            tenant.emitted.extend(int(t) for t in toks[slot][actives[slot]])
            if len(tenant.emitted) >= tenant.req.max_new_tokens:
                assert len(tenant.emitted) == tenant.req.max_new_tokens, (
                    "device active-mask emitted past the request budget"
                )
                done.append(
                    Completion(
                        rid=tenant.req.rid,
                        prompt=tenant.req.prompt,
                        new_tokens=np.asarray(tenant.emitted, np.int32),
                        admitted_at_step=tenant.admitted_at_step,
                        finished_at_step=self.decode_steps,
                    )
                )
                self._tenants[slot] = None  # freed; refilled next chunk boundary
        return done

    def run(self, max_chunks: int = 100_000) -> List[Completion]:
        """Drain the queue completely; returns completions in finish order."""
        out: List[Completion] = []
        for _ in range(max_chunks):
            if self.idle:
                return out
            out.extend(self.step())
        raise RuntimeError(f"scheduler did not drain within {max_chunks} chunks")
