"""Batched generation engine implementing the paper's Fig. 13 strategy.

Weights live in memory **once**, in packed BCQ format. The two stages consume
them differently:

- **summarization / context (prefill)** — compute-bound, large effective batch:
  weights are dequantized and fed to dense matmuls (on TPU: the fused
  dequant-in-VMEM ``bcq_mm`` tile loop; the dequantized matrix never re-enters
  HBM). Rationale (paper §V.B): dequant cost is amortised over many tokens.
- **generation (decode)** — memory-bound single-token steps: LUT-GEMM consumes
  the packed format directly.

The engine also serves *dense* models (pass unquantized params) so the
cuBLAS-analogue baseline uses the identical code path.

Decode fast path (DESIGN.md §2.3): at construction the params are run through
:func:`repro.models.fuse_decode_projections` (``fuse=True`` default) so QKV and
gate/up issue one fused projection kernel each, and ``generate`` runs all N
decode steps as a single jitted ``jax.lax.scan`` (``scan=True`` default) —
sampling happens on device inside the scan body, the KV cache is threaded
through the carry, and the host syncs once for the whole sequence instead of
once per token. Embedding-input (modality-stub) models fall back to the
per-token step loop because ``embed_fn`` runs host-side.

Caveat (TPU): the scan threads the KV cache through the carry — the body
reads the whole cache and dynamic-update-slices one slot per step. This is
the standard JAX decode idiom (XLA's while-loop lowering updates loop-carried
buffers in place), but it is a *different* access pattern from the
layer-stacked cache-as-carry variant that ``models/layers.py::_cache_write``
measured and rejected (dynamic per-layer slice reads triggered copy-insertion
duplication of the carry). CPU-host timings (BENCH_decode.json: 1.44x over
the step loop) cannot rule that pathology out on TPU — profile HBM traffic
there before relying on the scan path at large ``max_seq``; ``scan=False``
is the escape hatch.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward, fuse_decode_projections, init_cache
from repro.models.config import ModelConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, prompt+generated)
    prompt_len: int
    steps: int


def _sample(logits: jax.Array, key: jax.Array, temperature, greedy: bool) -> jax.Array:
    """(B, V) f32 logits → (B,) int32 tokens, on device."""
    if greedy:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature)


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_seq: int = 2048,
        embed_fn=None,
        fuse: bool = True,
    ):
        """``embed_fn(tokens (B,1) int32) → (B,1,D)`` is required for
        embedding-input (modality-stub) models to feed sampled codes back in —
        it stands in for the stubbed frontend (e.g. EnCodec codebook embed).

        ``fuse=False`` keeps the unfused per-projection weight layout
        (debugging / layouts the fuser declines are left unfused anyway)."""
        self.cfg = cfg
        self.params = fuse_decode_projections(cfg, params) if fuse else params
        self.max_seq = max_seq
        self.embed_fn = embed_fn

        def _prefill(params, tokens, image_emb, cache):
            kw = (
                {"tokens": tokens}
                if cfg.input_kind == "tokens"
                else {"embeddings": tokens}
            )
            if cfg.family == "vlm":
                kw["image_emb"] = image_emb
            logits, cache, _ = forward(
                cfg, params, **kw, cache=cache, pos=jnp.int32(0), logits_mode="last"
            )
            return logits, cache

        def _decode(params, tok, cache, pos):
            kw = {"tokens": tok} if cfg.input_kind == "tokens" else {"embeddings": tok}
            if cfg.family == "vlm":
                kw["image_emb"] = None
            logits, cache, _ = forward(
                cfg, params, **kw, cache=cache, pos=pos, logits_mode="last"
            )
            return logits, cache

        def _scan_decode(params, logits0, cache, pos0, key, temperature, *, n_steps, greedy):
            """N decode steps as ONE dispatch: sample → step, all on device.

            The carry holds (last logits, cache, position, PRNG key); the
            stacked scan output is the sampled token matrix. The key-split /
            sample order matches the step loop exactly, so scanned and
            step-loop generations are bit-identical (test_engine_scan).
            """

            def body(carry, _):
                logits, cache, pos, key = carry
                key, sub = jax.random.split(key)
                tok = _sample(logits, sub, temperature, greedy)
                logits2, cache = _decode(params, tok[:, None], cache, pos)
                return (logits2[:, -1], cache, pos + 1, key), tok

            (_, cache, _, _), toks = jax.lax.scan(
                body, (logits0, cache, pos0, key), None, length=n_steps
            )
            return toks.T, cache  # (B, n_steps)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._scan_decode = jax.jit(
            _scan_decode, static_argnames=("n_steps", "greedy")
        )

    def generate(
        self,
        prompt_tokens: np.ndarray,
        n_steps: int,
        *,
        image_emb: Optional[np.ndarray] = None,
        temperature: float = 0.0,
        seed: int = 0,
        scan: bool = True,
    ) -> GenerationResult:
        """Greedy (temperature=0) or sampled autoregressive generation.

        ``scan=True`` (default) runs the whole decode as one on-device
        ``lax.scan`` for tokens-input models; ``scan=False`` forces the
        per-token step loop (always used for embedding-input models, whose
        host-side ``embed_fn`` cannot run inside the scan).

        ``n_steps`` is a static scan length: each *distinct* value compiles
        its own scan graph once (then cached for the engine's lifetime).
        Serving highly variable lengths? Bucket them, or use ``scan=False``
        whose single ``_decode`` compilation covers every length."""
        cfg = self.cfg
        b, s = prompt_tokens.shape[:2]
        cache = init_cache(cfg, b, self.max_seq)
        logits, cache = self._prefill(
            self.params, jnp.asarray(prompt_tokens), image_emb, cache
        )
        key = jax.random.PRNGKey(seed)
        greedy = temperature <= 0

        if scan and cfg.input_kind == "tokens":
            toks, _ = self._scan_decode(
                self.params,
                logits[:, -1],
                cache,
                jnp.int32(s),
                key,
                jnp.float32(temperature if not greedy else 1.0),
                n_steps=n_steps,
                greedy=greedy,
            )
            tokens = np.concatenate([np.asarray(prompt_tokens), np.asarray(toks)], axis=1)
            return GenerationResult(tokens=tokens, prompt_len=s, steps=n_steps)

        out = [np.asarray(prompt_tokens)] if cfg.input_kind == "tokens" else []
        for step in range(n_steps):
            if not greedy:
                key, sub = jax.random.split(key)
                tok = _sample(logits[:, -1], sub, temperature, greedy=False)[:, None]
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(np.asarray(tok))
            if cfg.input_kind != "tokens":
                if self.embed_fn is None:
                    raise ValueError(
                        "embedding-input model: pass embed_fn to Engine to map "
                        "sampled codes back to frame embeddings"
                    )
                tok = jnp.asarray(self.embed_fn(np.asarray(tok))).astype(cfg.cdtype)
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(s + step)
            )
        tokens = np.concatenate(out, axis=1)
        return GenerationResult(tokens=tokens, prompt_len=s, steps=n_steps)
