"""Batched generation engine implementing the paper's Fig. 13 strategy.

Weights live in memory **once**, in packed BCQ format. The two stages consume
them differently:

- **summarization / context (prefill)** — compute-bound, large effective batch:
  weights are dequantized and fed to dense matmuls (on TPU: the fused
  dequant-in-VMEM ``bcq_mm`` tile loop; the dequantized matrix never re-enters
  HBM). Rationale (paper §V.B): dequant cost is amortised over many tokens.
- **generation (decode)** — memory-bound single-token steps: LUT-GEMM consumes
  the packed format directly.

The engine also serves *dense* models (pass unquantized params) so the
cuBLAS-analogue baseline uses the identical code path.

Decode fast path (DESIGN.md §2.3): at construction the params are run through
:func:`repro.models.fuse_decode_projections` (``fuse=True`` default) so QKV and
gate/up issue one fused projection kernel each, and ``generate`` runs all N
decode steps as a single jitted ``jax.lax.scan`` (``scan=True`` default) —
sampling happens on device inside the scan body, the KV cache is threaded
through the carry, and the host syncs once for the whole sequence instead of
once per token. Embedding-input (modality-stub) models fall back to the
per-token step loop because ``embed_fn`` runs host-side.

Caveat (TPU): the scan threads the KV cache through the carry — the body
reads the whole cache and dynamic-update-slices one slot per step. This is
the standard JAX decode idiom (XLA's while-loop lowering updates loop-carried
buffers in place), but it is a *different* access pattern from the
layer-stacked cache-as-carry variant that ``models/layers.py::_cache_write``
measured and rejected (dynamic per-layer slice reads triggered copy-insertion
duplication of the carry). CPU-host timings (BENCH_decode.json: 1.44x over
the step loop) cannot rule that pathology out on TPU — profile HBM traffic
there before relying on the scan path at large ``max_seq``; ``scan=False``
is the escape hatch.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.infer.prefix_cache import (
    PrefixHandle,
    concat_rows,
    model_identity,
    pad_rows,
)
from repro.infer.speculative import (
    SpecConfig,
    freeze_inactive,
    has_recurrent_state,
    has_ring_buffer,
    select_recurrent_target,
    spec_chunk,
)
from repro.models import forward, fuse_decode_projections, init_cache
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.quant import truncate_params


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, prompt+generated)
    prompt_len: int
    steps: int
    spec_stats: Optional[dict] = None  # accept_rate/chunks when speculating
    # per-row index into the generated tokens of the first stop token
    # (-1 = none); set when generate(stop_tokens=...) was given
    stop_positions: Optional[np.ndarray] = None

    def generated(self, b: int = 0) -> np.ndarray:
        """Row ``b``'s generation, truncated at its stop token (inclusive).

        ``tokens`` stays rectangular — decode always runs the full budget and
        truncation is host-side — so this is the accessor that honours
        ``stop_tokens``: everything after the first stop token is cut, and
        the stop token itself is the last element (matching the scheduler's
        early-exit serving contract)."""
        new = self.tokens[b, self.prompt_len :]
        if self.stop_positions is not None and self.stop_positions[b] >= 0:
            return new[: int(self.stop_positions[b]) + 1]
        return new


def stop_positions_for(new_tokens: np.ndarray, stop_tokens) -> np.ndarray:
    """(B, N) generated tokens -> (B,) index of each row's first stop token
    (-1 if the row never emits one)."""
    new_tokens = np.asarray(new_tokens)  # staticcheck: host-sync(host-side stop-token scan on emitted tokens)
    hits = np.isin(new_tokens, np.asarray(list(stop_tokens), np.int32))  # staticcheck: host-sync(stop-token ids are host ints)
    first = np.argmax(hits, axis=1)
    return np.where(hits.any(axis=1), first, -1).astype(np.int32)


@dataclasses.dataclass
class PendingAdmission:
    """Multi-step admission state (chunked prefill, DESIGN.md §12).

    ``Engine.begin_admission`` creates one (consulting the prefix cache and
    installing any matched prefix), ``advance_admission`` runs prefill
    forward by a token budget per call — which is what lets the scheduler
    interleave long-prompt admissions with decode chunks — and
    ``finish_admission`` captures the commit payload and installs the slot.
    ``admit_slot`` is the synchronous composition of the three.
    """

    prompt: jax.Array            # (1, plen) int32, device
    plen: int
    max_new_tokens: int
    temperature: float
    seed: int
    speculate: bool              # per-request opt-in (spec slot batches)
    needs_draft: bool            # the slot batch is speculative
    chunked: bool                # bucket-padded chunk dispatches
    whole: bool                  # single whole-prompt prefill dispatch
    collect: bool                # capture recurrent stacks for prefix commit
    handle: Optional[PrefixHandle] = None
    pos: int = 0                 # target prompt tokens consumed so far
    cache1: object = None        # evolving batch-1 target cache
    logits1: object = None       # (1, V) last-token logits once target done
    # (start_pos, collect_states cache) per collect dispatch — recurrent
    # boundary snapshots for prefix commit are selected out of these
    stack_segments: list = dataclasses.field(default_factory=list)
    dcache1: object = None       # batch-1 draft cache (spec mode)
    prefill_chunks: int = 0      # dispatches so far (lifecycle stamp)

    @property
    def target_done(self) -> bool:
        return self.logits1 is not None

    @property
    def done(self) -> bool:
        return self.target_done and (
            not self.needs_draft or self.dcache1 is not None
        )


def _sample(logits: jax.Array, key: jax.Array, temperature, greedy: bool) -> jax.Array:
    """(B, V) f32 logits → (B,) int32 tokens, on device.

    ``temperature <= 0`` with ``greedy=False`` falls back to argmax instead of
    dividing by zero (``logits / 0`` → ±inf → NaN probabilities in the
    categorical). ``temperature`` may be a traced scalar, so the guard is a
    ``jnp.where`` on the *result*, and the division clamps its denominator —
    bit-identical to the unguarded path for any real temperature > 1e-6.
    """
    if greedy:
        return jnp.argmax(logits, axis=-1)
    sampled = jax.random.categorical(key, logits / jnp.maximum(temperature, 1e-6))
    return jnp.where(temperature > 0, sampled, jnp.argmax(logits, axis=-1))


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_seq: int = 2048,
        embed_fn=None,
        fuse: bool = True,
        mesh=None,
        tracer=None,
        prefix_cache=None,
    ):
        """``embed_fn(tokens (B,1) int32) → (B,1,D)`` is required for
        embedding-input (modality-stub) models to feed sampled codes back in —
        it stands in for the stubbed frontend (e.g. EnCodec codebook embed).

        ``fuse=False`` keeps the unfused per-projection weight layout
        (debugging / layouts the fuser declines are left unfused anyway).

        ``mesh`` (a 1-D mesh with a ``model`` axis — ``parallel.tp.
        make_tp_mesh``) runs every forward tensor-parallel under ``shard_map``
        (DESIGN.md §7): weights are placed column/row-parallel, KV caches
        shard their kv-head dim, and all decode/serve/speculative paths
        consume the shards; tokens are identical to the single-device engine
        for greedy decoding, logits equal up to psum reassociation.

        ``tracer`` (a :class:`repro.obs.trace.Tracer`) records host-side
        spans around every engine dispatch on the ``engine`` lane. Spans
        wrap the *host* call — dispatch plus any blocking fetch the caller's
        path performs inside them — never code inside a jitted function, so
        instrumentation changes neither the traced programs (§3 trace-once)
        nor the tokens (tests/test_obs.py). Per-op device timing needs a
        ``jax.profiler.trace`` capture (``launch/serve.py --profile-dir``);
        the :func:`jax.profiler.TraceAnnotation` scopes emitted here label
        those captures.

        ``prefix_cache`` (a :class:`repro.infer.prefix_cache.PrefixCache`)
        turns on prompt-prefix KV reuse for the slot-batched admission path
        (DESIGN.md §12): ``admit_slot`` consults it, installs matched prefix
        rows instead of recomputing them, and commits the prompt's prefix
        back on success. Tokens are bit-identical to cold-cache admission.
        Requires a tokens-input, non-VLM, non-MoE model (the same gate as
        slot-batched serving)."""
        self.cfg = cfg
        self.tracer = tracer
        self.params = fuse_decode_projections(cfg, params) if fuse else params
        self.max_seq = max_seq
        self.embed_fn = embed_fn
        self._unit_cache = None  # lazy batch-1 prefill template (admit_slot)
        self.mesh = mesh
        if mesh is not None:
            from repro.parallel.tp import shard_model

            self.params, self._tp = shard_model(cfg, self.params, mesh)
            fwd = self._tp.forward
        else:
            self._tp = None
            fwd = functools.partial(forward, cfg)

        def _prefill(params, tokens, image_emb, cache):
            kw = (
                {"tokens": tokens}
                if cfg.input_kind == "tokens"
                else {"embeddings": tokens}
            )
            if cfg.family == "vlm":
                kw["image_emb"] = image_emb
            logits, cache, _ = fwd(
                params, **kw, cache=cache, pos=jnp.int32(0), logits_mode="last"
            )
            return logits, cache

        def _decode(params, tok, cache, pos):
            kw = {"tokens": tok} if cfg.input_kind == "tokens" else {"embeddings": tok}
            if cfg.family == "vlm":
                kw["image_emb"] = None
            logits, cache, _ = fwd(
                params, **kw, cache=cache, pos=pos, logits_mode="last"
            )
            return logits, cache

        def _scan_decode(params, logits0, cache, pos0, key, temperature, *, n_steps, greedy):
            """N decode steps as ONE dispatch: sample → step, all on device.

            The carry holds (last logits, cache, position, PRNG key); the
            stacked scan output is the sampled token matrix. The key-split /
            sample order matches the step loop exactly, so scanned and
            step-loop generations are bit-identical (test_engine_scan).
            """

            def body(carry, _):
                logits, cache, pos, key = carry
                key, sub = jax.random.split(key)
                tok = _sample(logits, sub, temperature, greedy)
                logits2, cache = _decode(params, tok[:, None], cache, pos)
                return (logits2[:, -1], cache, pos + 1, key), tok

            (_, cache, _, _), toks = jax.lax.scan(
                body, (logits0, cache, pos0, key), None, length=n_steps
            )
            return toks.T, cache  # (B, n_steps)

        def _admit(slots, slot, cache1, logits1, key, plen, max_new, temperature, greedy):
            """Install a freshly prefilled request into batch row `slot`.

            `cache1` is the batch-1 prefilled cache; every cache leaf is
            (repeat, batch, ...) so the row write is one dynamic-update-slice
            per leaf along axis 1. The slot's whole state row (KV rows,
            recurrent state, position counter, PRNG key, sampling params) is
            overwritten — nothing from the previous tenant survives, which is
            the slot-reset contract (DESIGN.md §4).
            """
            cache = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1
                ),
                slots["cache"],
                cache1,
            )
            return dict(
                slots,
                cache=cache,
                logits=slots["logits"].at[slot].set(logits1[0]),
                pos=slots["pos"].at[slot].set(plen),
                keys=slots["keys"].at[slot].set(key),
                active=slots["active"].at[slot].set(True),
                remaining=slots["remaining"].at[slot].set(max_new),
                temperature=slots["temperature"].at[slot].set(temperature),
                greedy=slots["greedy"].at[slot].set(greedy),
            )

        def _scan_decode_slots(params, slots, *, n_steps):
            """`n_steps` slot-batched decode steps as ONE dispatch.

            Like `_scan_decode`, but each batch row is an independent request
            with its own position counter, PRNG key and sampling params, plus
            an active mask: inactive rows keep their key/logits/position
            frozen so a row's (key-split, sample) sequence advances exactly
            once per emitted token — the same sequence a solo batch-1
            `generate` of that request produces. Inactive rows still flow
            through the batched forward (they decode garbage into their own
            cache rows at a frozen position, which is harmless: a row's cache
            beyond its position is never attended, and admission rewrites the
            slot's state from scratch).

            Per-row sampling matches batch-1 `_sample` bit-for-bit: the
            categorical is taken over a (1, V) row under vmap, which JAX's
            counter-based PRNG evaluates identically to a standalone call.
            """
            temperature, greedy = slots["temperature"], slots["greedy"]

            def body(carry, _):
                logits, cache, pos, keys, active, remaining = carry
                splits = jax.vmap(jax.random.split)(keys)  # (B, 2, 2)
                new_keys = jnp.where(active[:, None], splits[:, 0], keys)
                sub = splits[:, 1]
                sampled = jax.vmap(
                    lambda lg, kk, t: jax.random.categorical(kk, lg[None] / t)[0]
                )(logits, sub, temperature)
                tok = jnp.where(greedy, jnp.argmax(logits, axis=-1), sampled)
                tok = tok.astype(jnp.int32)
                logits2, cache2 = _decode(params, tok[:, None], cache, pos)
                new_logits = jnp.where(active[:, None], logits2[:, -1], logits)
                new_pos = jnp.where(active, pos + 1, pos)
                new_rem = jnp.where(active, remaining - 1, remaining)
                new_active = active & (new_rem > 0)
                emitted = jnp.where(active, tok, -1)
                return (
                    (new_logits, cache2, new_pos, new_keys, new_active, new_rem),
                    (emitted, active),
                )

            carry = (
                slots["logits"], slots["cache"], slots["pos"],
                slots["keys"], slots["active"], slots["remaining"],
            )
            carry, (toks, actives) = jax.lax.scan(body, carry, None, length=n_steps)
            logits, cache, pos, keys, active, remaining = carry
            out = dict(
                slots,
                logits=logits, cache=cache, pos=pos, keys=keys,
                active=active, remaining=remaining,
            )
            return toks.T, actives.T, out  # (B, n_steps) each

        def _admit_spec(
            slots, slot, cache1, dcache1, logits1, key, dkey, plen, max_new,
            temperature, greedy, spec_on,
        ):
            """Spec-mode admission: the plain install plus the draft-cache row,
            the per-row draft PRNG stream, and the request's FIRST token —
            sampled here exactly as the plain path's first decode step would
            (one key split, same categorical shape), recorded in `t_pend` and
            already counted against the budget."""
            slots = _admit(
                slots, slot, cache1, logits1, key, plen, max_new, temperature, greedy
            )
            dcache = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1
                ),
                slots["draft_cache"],
                dcache1,
            )
            key2, sub = jax.random.split(key)
            lg = logits1[0]
            tok = jnp.where(
                greedy,
                jnp.argmax(lg),
                jax.random.categorical(sub, lg[None] / temperature)[0],
            ).astype(jnp.int32)
            return dict(
                slots,
                draft_cache=dcache,
                t_pend=slots["t_pend"].at[slot].set(tok),
                spec=slots["spec"].at[slot].set(spec_on),
                keys=slots["keys"].at[slot].set(key2),
                draft_keys=slots["draft_keys"].at[slot].set(dkey),
                remaining=slots["remaining"].at[slot].set(max_new - 1),
                active=slots["active"].at[slot].set(max_new > 1),
            )

        def _scan_spec_slots(params, draft_params, slots, *, n_chunks, gamma):
            """`n_chunks` speculative chunks over the slot batch, ONE dispatch.

            Each chunk commits 1..gamma+1 tokens per row (per-row budgets clip
            the tail); rows with `spec=False` are forced to n_acc=0 inside the
            chunk and so emit exactly one plain-decode token per chunk, with
            a PRNG stream bit-identical to the non-speculative path."""
            temperature, greedy, spec_on = (
                slots["temperature"], slots["greedy"], slots["spec"],
            )

            def body(carry, _):
                state, active, remaining = carry
                commit, n_keep, ns = spec_chunk(
                    cfg, params, draft_params, state, gamma=gamma,
                    greedy=greedy, temperature=temperature, spec_enabled=spec_on,
                    fwd=fwd,
                )
                emit_n = jnp.where(active, jnp.minimum(n_keep, remaining), 0)
                valid = jnp.arange(gamma + 1)[None, :] < emit_n[:, None]
                toks = jnp.where(valid, commit, -1)
                new_remaining = remaining - emit_n
                new_active = active & (new_remaining > 0)
                frozen = freeze_inactive(ns, state, active)
                return (frozen, new_active, new_remaining), (toks, valid)

            state0 = {
                k: slots[k]
                for k in ("t_pend", "pos", "keys", "draft_keys", "cache", "draft_cache")
            }
            (state, active, remaining), (toks, valid) = jax.lax.scan(
                body, (state0, slots["active"], slots["remaining"]), None,
                length=n_chunks,
            )
            b = toks.shape[1]
            toks = toks.transpose(1, 0, 2).reshape(b, -1)  # (B, n_chunks*(γ+1))
            valid = valid.transpose(1, 0, 2).reshape(b, -1)
            out = dict(slots, active=active, remaining=remaining, **state)
            return toks, valid, out

        def _spec_generate(
            params, draft_params, logits0, cache, dcache, pos0, key, dkey,
            temperature, *, n_steps, gamma, greedy,
        ):
            """One-shot speculative generation: chunks under a while_loop until
            every row has emitted `n_steps` tokens (host syncs once)."""
            b = logits0.shape[0]
            cap = n_steps + gamma + 1
            row_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(b))
            draft_keys = jax.vmap(lambda i: jax.random.fold_in(dkey, i))(jnp.arange(b))
            greedy_vec = jnp.full((b,), greedy)
            temp_vec = jnp.full((b,), temperature)
            spec_on = jnp.ones((b,), bool)

            # first token = the target's own next token from the prefill logits
            splits = jax.vmap(jax.random.split)(row_keys)
            row_keys, sub = splits[:, 0], splits[:, 1]
            sampled = jax.vmap(
                lambda kk, lg: jax.random.categorical(kk, lg[None] / temperature)[0]
            )(sub, logits0)
            t0 = jnp.where(greedy_vec, jnp.argmax(logits0, -1), sampled).astype(
                jnp.int32
            )
            buf0 = jnp.zeros((b, cap), jnp.int32).at[:, 0].set(t0)

            state0 = dict(
                t_pend=t0, pos=pos0, keys=row_keys, draft_keys=draft_keys,
                cache=cache, draft_cache=dcache,
            )
            emitted0 = jnp.ones((b,), jnp.int32)
            stats0 = (jnp.int32(0), jnp.int32(0), jnp.int32(0))

            def cond(carry):
                return jnp.any(carry[1] < n_steps)

            def body(carry):
                state, emitted, buf, (acc, prop, chunks) = carry
                active = emitted < n_steps
                commit, n_keep, ns = spec_chunk(
                    cfg, params, draft_params, state, gamma=gamma,
                    greedy=greedy_vec, temperature=temp_vec, spec_enabled=spec_on,
                    fwd=fwd,
                )

                def wrow(bufrow, vec, start, act):
                    # inactive rows park their junk write beyond n_steps
                    start = jnp.where(act, start, jnp.int32(cap - gamma - 1))
                    return jax.lax.dynamic_update_slice(bufrow, vec, (start,))

                buf = jax.vmap(wrow)(buf, commit, emitted, active)
                frozen = freeze_inactive(ns, state, active)
                # count only acceptances whose commits survive the n_steps
                # slice — the final chunk's clipped tail is not a real win
                counted = jnp.minimum(n_keep - 1, n_steps - emitted)
                emitted = jnp.where(active, emitted + n_keep, emitted)
                stats = (
                    acc + jnp.sum(jnp.where(active, counted, 0)),
                    prop + jnp.sum(active) * gamma,
                    chunks + 1,
                )
                return (frozen, emitted, buf, stats)

            _, _, buf, stats = jax.lax.while_loop(
                cond, body, (state0, emitted0, buf0, stats0)
            )
            return buf[:, :n_steps], stats

        # raw (unjitted) closures: repro.analysis.staticcheck traces these with
        # jax.make_jaxpr to prove the collective/transfer/dtype invariants of
        # the exact programs the jitted attributes below compile
        self.prefill_fn = _prefill
        self.decode_step_fn = _decode
        self.scan_decode_fn = _scan_decode

        # QuantizedTensor statics (g/k/o/fmt) travel in the pytree treedef, so
        # the param tree needs no static_argnums here
        self._prefill = jax.jit(_prefill)  # staticcheck: jit-ok(pytree statics; no donation — unit-cache template is reused)
        self._decode = jax.jit(_decode)  # staticcheck: jit-ok(pytree statics; cache threaded functionally by scan callers)
        self._scan_decode = jax.jit(
            _scan_decode, static_argnames=("n_steps", "greedy")
        )
        # donate the incoming slot state: both return a full replacement and
        # the scheduler drops the old dict, so the n_slots-wide KV cache can
        # be updated in place instead of copied per dispatch (the same hazard
        # launch/dryrun.py documents for the one-shot decode step)
        self._admit = jax.jit(_admit, donate_argnums=(0,))
        self._scan_decode_slots = jax.jit(
            _scan_decode_slots, static_argnames=("n_steps",), donate_argnums=(1,)
        )
        def _release(slots, slot):
            """Deactivate one slot row: the lifecycle layer's slot-reclaim
            primitive (cancel / timeout / quarantine). Only the row's active
            mask and budget change — its cache rows are left as-is, which is
            safe by the write-before-read contract (DESIGN.md §4: garbage
            beyond a row's position is never attended) and admission
            overwrites the entire row anyway."""
            return dict(
                slots,
                active=slots["active"].at[slot].set(False),
                remaining=slots["remaining"].at[slot].set(0),
            )

        self._release = jax.jit(_release, donate_argnums=(0,))
        # row-finiteness of the carried logits: the scheduler's NaN/inf guard
        # reads (B,) bools per chunk instead of hauling (B, vocab) to host
        self._finite_rows = jax.jit(lambda lg: jnp.isfinite(lg).all(axis=-1))  # staticcheck: jit-ok(single-array reduction, nothing to donate or mark static)
        self._admit_spec = jax.jit(_admit_spec, donate_argnums=(0,))
        self._scan_spec_slots = jax.jit(
            _scan_spec_slots, static_argnames=("n_chunks", "gamma"),
            donate_argnums=(2,),
        )
        self._spec_generate = jax.jit(
            _spec_generate, static_argnames=("n_steps", "gamma", "greedy")
        )
        self._draft_params: dict = {}  # q_draft -> truncated param tree
        self._slot_spec: Optional[SpecConfig] = None  # set by init_slots

        # -- prefix-cache KV reuse + chunked prefill (DESIGN.md §12) --------

        def _prefill_chunk(params, tokens, cache, pos, last_idx):
            """One suffix-prefill chunk: `s` fresh tokens mid-sequence against
            a filled cache — the speculative-verify mechanism (`chunked_decode`)
            reused for prefill, so every token attends the installed prefix
            rows plus its intra-chunk predecessors. Returns the (1, V) logits
            of the token at `last_idx`: the last REAL token when the chunk is
            bucket-padded (pad tokens sit at later positions, which causal
            masks make invisible to it)."""
            logits, cache, _ = fwd(
                params, tokens=tokens, cache=cache, pos=pos,
                logits_mode="all", chunked_decode=True,
            )
            last = jax.lax.dynamic_index_in_dim(
                logits, last_idx, axis=1, keepdims=False
            )
            return last, cache

        def _prefill_collect(params, tokens, cache):
            """Whole-prompt prefill that additionally returns recurrent state
            stacked over the time axis (``collect_states``) so prefix commit
            can snapshot the state at block boundaries. Logits and cache rows
            are bit-identical to `_prefill` — collect changes only what the
            recurrent blocks *return*, not what they compute."""
            logits, cache, _ = fwd(
                params, tokens=tokens, cache=cache, pos=jnp.int32(0),
                logits_mode="last", collect_states=True,
            )
            return logits[:, -1], cache

        def _suffix_collect(params, tokens, cache, pos, last_idx):
            """`_prefill_chunk` with recurrent-state collection (warm-hit
            suffix prefill on a recurrent architecture that also commits)."""
            logits, cache, _ = fwd(
                params, tokens=tokens, cache=cache, pos=pos,
                logits_mode="all", chunked_decode=True, collect_states=True,
            )
            last = jax.lax.dynamic_index_in_dim(
                logits, last_idx, axis=1, keepdims=False
            )
            return last, cache

        self.prefill_chunk_fn = _prefill_chunk  # staticcheck traces this raw
        self._prefill_chunk = jax.jit(_prefill_chunk)  # staticcheck: jit-ok(pytree statics; no donation — the evolving cache is also the commit-gather source)
        self._prefill_collect = jax.jit(_prefill_collect)  # staticcheck: jit-ok(pytree statics; same non-donation rationale as _prefill)
        self._suffix_collect = jax.jit(_suffix_collect)  # staticcheck: jit-ok(pytree statics; recurrent-only path, cold at serving scale)
        self._install_rows = jax.jit(L.install_prefix_rows)  # staticcheck: jit-ok(no donation — the batch-1 unit-cache template is reused across admissions)
        self._install_recurrent = jax.jit(L.install_recurrent)  # staticcheck: jit-ok(same template-reuse rationale as _install_rows)
        self._gather_block = jax.jit(
            L.gather_prefix_rows, static_argnums=(2,)
        )
        self._final_recurrent = jax.jit(select_recurrent_target)  # staticcheck: jit-ok(tiny per-leaf select; nothing to donate or mark static)
        self._boundary_snap = jax.jit(  # staticcheck: jit-ok(tiny select+snapshot; nothing to donate or mark static)
            lambda vc, idx: L.snapshot_recurrent(select_recurrent_target(vc, idx))
        )

        self.prefix_cache = prefix_cache
        self._prefix_ok = (
            cfg.input_kind == "tokens" and cfg.family != "vlm"
            and not cfg.n_experts
        )
        self._has_recurrent = has_recurrent_state(cfg)
        self._has_ring = has_ring_buffer(cfg)
        # Bucket-padded chunks need pad-token writes to be DEAD rows (the
        # write-before-read contract): ring buffers wrap pad writes onto live
        # rows and recurrent state folds pad tokens irreversibly, so those
        # architectures fall back to exact-length dispatches (correct, but
        # retraces per length — hence supports_chunked_prefill is False).
        self._chunkable = (
            self._prefix_ok and not self._has_recurrent and not self._has_ring
        )
        if prefix_cache is not None:
            if not self._prefix_ok:
                raise ValueError(
                    "prefix_cache requires a tokens-input, non-VLM, non-MoE "
                    "model (the slot-batched serving gate, DESIGN.md §4)"
                )
            prefix_cache.bind(model_identity(cfg, self.params, mesh))
        # pow-of-2 chunk/prefix buckets: one compile-cache entry per bucket
        # instead of one per prompt length (staticcheck trace-once proof)
        buckets, bkt = [], 8
        while bkt < max_seq:
            buckets.append(bkt)
            bkt *= 2
        buckets.append(max_seq)
        self.chunk_buckets = tuple(dict.fromkeys(buckets))
        self._last_prefix_handle: Optional[PrefixHandle] = None

    def _obs_scope(self, name: str, **args):
        """Host-side observability scope around one engine dispatch: a tracer
        span on the ``engine`` lane (when a tracer is attached and enabled)
        plus a ``jax.profiler.TraceAnnotation`` so the region is labelled in
        ``jax.profiler.trace`` captures. Entered strictly outside jitted
        code; a TraceAnnotation with no active profiler session is a cheap
        no-op, and a disabled/absent tracer never reads a clock."""
        ctx = contextlib.ExitStack()
        if self.tracer is not None and self.tracer.enabled:
            ctx.enter_context(
                self.tracer.span(name, cat="engine", lane="engine", **args)
            )
        ctx.enter_context(jax.profiler.TraceAnnotation(name))
        return ctx

    def _make_cache(self, batch: int):
        """A fresh decode cache, TP-sharded (kv-heads over `model`) when the
        engine runs on a mesh so the jitted paths see sharded inputs instead
        of paying a reshard on entry."""
        cache = init_cache(self.cfg, batch, self.max_seq)
        return cache if self._tp is None else self._tp.shard_cache(cache)

    # -- speculative decoding (infer/speculative.py) -------------------------

    def draft_params(self, q_draft: int):
        """The nested ``q_draft``-bit draft view of this engine's params
        (zero extra solve; norms/embeddings/dense leaves shared by reference).
        Cached per ``q_draft`` for the engine's lifetime."""
        if q_draft not in self._draft_params:
            draft = truncate_params(self.params, q_draft)
            if self._tp is not None:
                # plane truncation slices the q axis, never a sharded dim, so
                # the full tree's placement applies verbatim; re-commit so the
                # draft enters jit sharded even if the slice fell off-device
                draft = self._tp.place_params(draft)
            self._draft_params[q_draft] = draft
        return self._draft_params[q_draft]

    def _validate_spec(self, spec: SpecConfig) -> None:
        cfg = self.cfg
        # capability gate (DESIGN.md §2.4): drafts are nested low-bit views of
        # the target's own weights, which only residual-nested formats can
        # provide — refuse before tracing, naming the offending formats AND
        # the registered formats that would work (the capability flag, not a
        # hardcoded name list)
        from repro.core.formats import format_names, get_format
        from repro.core.qtensor import QuantizedTensor

        bad = sorted(
            {
                leaf.fmt
                for leaf in jax.tree.leaves(
                    self.params, is_leaf=lambda x: isinstance(x, QuantizedTensor)
                )
                if isinstance(leaf, QuantizedTensor)
                and not get_format(leaf.fmt).supports_truncate
            }
        )
        if bad:
            capable = [
                n for n in format_names() if get_format(n).supports_truncate
            ]
            raise ValueError(
                f"speculative decoding needs truncation-capable weight formats; "
                f"{bad} do not support nested draft truncation "
                f"(truncation-capable formats: {capable})"
            )
        if cfg.input_kind != "tokens":
            raise ValueError(
                "speculative decoding requires a tokens-input model (host-side "
                "embed_fn cannot run inside the jitted chunk)"
            )
        if cfg.n_experts:
            # verify batches γ+1 tokens through shared expert capacity, which
            # couples them — target logits would differ from step-by-step
            # decode, breaking the exactness contract (same exclusion as
            # slot-batched serving, DESIGN.md §4/§5)
            raise ValueError(
                "speculative decoding does not support MoE models: shared "
                "expert capacity couples the verified chunk's tokens, so the "
                "batched verify is not equivalent to step-by-step decode"
            )
        has_window = any(
            bt == "local_attn" for pattern, _ in cfg.stages for bt in pattern
        )
        if has_window and spec.gamma + 1 >= min(self.max_seq, cfg.window):
            raise ValueError(
                f"gamma={spec.gamma} too large for the ring-buffer window "
                f"{min(self.max_seq, cfg.window)} (need gamma+1 < window)"
            )

    # -- slot-batched serving API (infer/scheduler.py drives these) ---------

    def init_slots(self, n_slots: int, speculate: Optional[SpecConfig] = None) -> dict:
        """Fresh slot-batched decode state: a `n_slots`-wide KV cache plus
        per-slot counters/sampling params. All slots start inactive.

        ``speculate`` switches the slot batch to speculative chunks
        (DESIGN.md §5): the state grows a draft-model cache, per-row pending
        tokens, draft PRNG streams and a per-row opt-in flag; drive it with
        :meth:`spec_decode_slots` instead of :meth:`decode_slots`."""
        if self.cfg.input_kind != "tokens" or self.cfg.family == "vlm":
            raise ValueError(
                "slot-batched serving requires a tokens-input, non-VLM model "
                "(embed_fn/image inputs cannot run inside the slotted scan)"
            )
        if self.cfg.n_experts:
            # MoE expert capacity is shared across the batch: tokens from other
            # slots — including garbage from inactive rows — can evict an
            # active request's tokens from an expert buffer, so slot outputs
            # are neither solo-identical nor slot-history-independent. Reject
            # rather than silently break the scheduler's contract (DESIGN §4).
            raise ValueError(
                "slot-batched serving does not support MoE models: shared "
                "expert capacity couples batch rows, breaking per-request "
                "token-identity (use one-shot Engine.generate instead)"
            )
        slots = {
            "cache": self._make_cache(n_slots),
            "logits": jnp.zeros((n_slots, self.cfg.vocab), jnp.float32),
            "pos": jnp.zeros((n_slots,), jnp.int32),
            "keys": jnp.zeros((n_slots, 2), jnp.uint32),
            "active": jnp.zeros((n_slots,), bool),
            "remaining": jnp.zeros((n_slots,), jnp.int32),
            "temperature": jnp.ones((n_slots,), jnp.float32),
            "greedy": jnp.ones((n_slots,), bool),
        }
        self._slot_spec = speculate
        if speculate is not None:
            self._validate_spec(speculate)
            slots["draft_cache"] = self._make_cache(n_slots)
            slots["t_pend"] = jnp.zeros((n_slots,), jnp.int32)
            slots["spec"] = jnp.zeros((n_slots,), bool)
            slots["draft_keys"] = jnp.zeros((n_slots, 2), jnp.uint32)
        return slots

    # -- admission (whole-shot, prefix-cached, or chunked) -------------------

    @property
    def supports_chunked_prefill(self) -> bool:
        """True when bucket-padded chunked prefill is available: tokens-input,
        non-VLM/MoE, and neither ring-buffer (pad writes wrap onto live rows)
        nor recurrent (state folds pad tokens) architectures."""
        return self._chunkable

    def _bucket_for(self, pos: int, n: int) -> int:
        """Smallest chunk bucket holding ``n`` rows starting at ``pos``.
        Falls back to exact ``n`` near the cache end: a padded write there
        would make ``dynamic_update_slice`` CLAMP its start index and corrupt
        earlier rows (the §12 tail guard)."""
        for b in self.chunk_buckets:
            if b >= n and pos + b <= self.max_seq:
                return b
        return n

    def begin_admission(
        self,
        prompt_tokens,
        *,
        max_new_tokens: int,
        temperature: float = 0.0,
        seed: int = 0,
        speculate: bool = True,
        chunked: bool = False,
    ) -> PendingAdmission:
        """Start one slot admission: validate, consult the prefix cache, and
        install any matched prefix into a fresh batch-1 cache. Returns a
        :class:`PendingAdmission` to be driven by :meth:`advance_admission`
        and installed by :meth:`finish_admission` (or released by
        :meth:`abort_admission` on any failure/cancel in between).

        ``chunked=True`` makes :meth:`advance_admission` dispatch bucket-
        padded fixed-budget chunks (requires :attr:`supports_chunked_prefill`)
        so the scheduler can interleave long-prompt prefill with decode.
        """
        prompt = jnp.asarray(prompt_tokens, jnp.int32).reshape(1, -1)
        plen = int(prompt.shape[1])
        spec = self._slot_spec
        headroom = 0 if spec is None else spec.gamma + 1
        if plen + max_new_tokens + headroom > self.max_seq:
            raise ValueError(
                f"prompt_len({plen}) + max_new_tokens({max_new_tokens})"
                f"{f' + speculation headroom({headroom})' if headroom else ''} "
                f"exceeds max_seq={self.max_seq}"
            )
        if chunked and not self._chunkable:
            raise ValueError(
                "chunked prefill is unsupported for this architecture "
                "(ring-buffer/recurrent/MoE/VLM — see "
                "Engine.supports_chunked_prefill)"
            )
        if self._unit_cache is None:
            # one zeroed batch-1 cache per engine: _prefill is purely
            # functional (no donation), so the template is reusable and the
            # admission hot path skips a full max_seq cache alloc+zero
            self._unit_cache = self._make_cache(1)
        handle, pos, cache1, collect = None, 0, None, False
        if self.prefix_cache is not None:
            # at least the last prompt token must prefill (decode needs its
            # logits); ring caps both match and commit at the window — rows
            # past it wrapped during prefill and are not at their positions
            # ring guard: rows sit at their absolute positions only until
            # the buffer wraps (plen > window) — beyond that neither gather
            # nor install sees rows where the trie thinks they are, and a
            # warm suffix dispatch would wrap its own writes onto rows its
            # early tokens attend (the spec-gamma hazard). Prompts past the
            # window bypass the cache entirely (cold whole-shot prefill,
            # which handles the wrap natively).
            wrapped = self._has_ring and plen > min(self.max_seq, self.cfg.window)
            max_match = 0 if wrapped else plen - 1
            max_commit = 0 if wrapped else plen
            handle = self.prefix_cache.begin(
                prompt_tokens, max_match=max_match, max_commit=max_commit
            )
            try:
                if handle.length:
                    rows = concat_rows([nd.rows for nd in handle.matched])
                    total = (
                        self._bucket_for(0, handle.length)
                        if self._chunkable else handle.length
                    )
                    with self._obs_scope(
                        "engine/prefix_install", hit_tokens=handle.length,
                        padded=total,
                    ):
                        cache1 = self._install_rows(
                            self._unit_cache, pad_rows(rows, total)
                        )
                        if self._has_recurrent:
                            snap = handle.matched[-1].snap
                            assert snap is not None, (
                                "recurrent prefix block committed without a "
                                "boundary snapshot"
                            )
                            cache1 = self._install_recurrent(cache1, snap)
                    pos = handle.length
                collect = self._has_recurrent and bool(handle.new_spans)
            except Exception:
                self.prefix_cache.abort(handle)
                raise
        return PendingAdmission(
            prompt=prompt, plen=plen, max_new_tokens=max_new_tokens,
            temperature=temperature, seed=seed, speculate=speculate,
            needs_draft=spec is not None, chunked=chunked,
            whole=(not chunked) and pos == 0, collect=collect,
            handle=handle, pos=pos, cache1=cache1,
        )

    def _advance_once(self, p: PendingAdmission, left: Optional[int]) -> int:
        """One admission dispatch; returns prompt tokens consumed (the draft
        prefill counts its full prompt — it is always whole-shot, see
        :meth:`advance_admission`)."""
        if not p.target_done:
            if p.whole:
                with self._obs_scope("engine/prefill", prompt_len=p.plen):
                    if p.collect:
                        p.logits1, vc = self._prefill_collect(
                            self.params, p.prompt, self._unit_cache
                        )
                        p.stack_segments.append((0, vc))
                        p.cache1 = self._final_recurrent(
                            vc, jnp.full((1,), p.plen - 1, jnp.int32)
                        )
                    else:
                        logits, p.cache1 = self._prefill(
                            self.params, p.prompt, None, self._unit_cache
                        )
                        p.logits1 = logits[:, -1]
                p.pos = p.plen
                p.prefill_chunks += 1
                return p.plen
            n = p.plen - p.pos
            if left is not None:
                n = min(n, left)
            if p.chunked:
                n = min(n, self.chunk_buckets[-1])
            b = self._bucket_for(p.pos, n) if p.chunked else n
            chunk = p.prompt[:, p.pos : p.pos + n]
            if b > n:
                chunk = jnp.pad(chunk, ((0, 0), (0, b - n)))
            cache = p.cache1 if p.cache1 is not None else self._unit_cache
            with self._obs_scope(
                "engine/prefill_chunk", pos=p.pos, n_tokens=n, padded=b
            ):
                if p.collect:
                    last, vc = self._suffix_collect(
                        self.params, chunk, cache, jnp.int32(p.pos),
                        jnp.int32(n - 1),
                    )
                    p.stack_segments.append((p.pos, vc))
                    p.cache1 = self._final_recurrent(
                        vc, jnp.full((1,), n - 1, jnp.int32)
                    )
                else:
                    last, p.cache1 = self._prefill_chunk(
                        self.params, chunk, cache, jnp.int32(p.pos),
                        jnp.int32(n - 1),
                    )
            p.pos += n
            p.prefill_chunks += 1
            if p.pos >= p.plen:
                p.logits1 = last
            return n
        spec = self._slot_spec
        with self._obs_scope(
            "engine/prefill_draft", prompt_len=p.plen, q_draft=spec.q_draft
        ):
            _, p.dcache1 = self._prefill(
                self.draft_params(spec.q_draft), p.prompt, None,
                self._unit_cache,
            )
        return p.plen

    def advance_admission(
        self, pending: PendingAdmission, budget: Optional[int] = None
    ) -> int:
        """Run the pending prefill forward by up to ``budget`` prompt tokens
        (``None`` = to completion); returns tokens consumed. The scheduler
        calls this once per step with its chunk budget, interleaved with
        decode dispatches.

        The speculative draft prefill is always whole-shot (its prompt in one
        dispatch, charged entirely to the step it runs in): the draft cache
        has no prefix blocks to reuse, and splitting it would double the
        chunk machinery for a path whose forward is already the cheap
        ``q_draft``-bit truncation."""
        consumed = 0
        while not pending.done:
            left = None if budget is None else budget - consumed
            if left is not None and left <= 0:
                break
            consumed += self._advance_once(pending, left)
        return consumed

    def finish_admission(
        self, slots: dict, slot: int, pending: PendingAdmission
    ) -> dict:
        """Install a completed admission into ``slot`` and commit the
        prompt's prefix blocks back to the cache (gathered from the final
        batch-1 cache under ref-count; commit happens only after a
        successful install, so a failed install aborts instead)."""
        p = pending
        if not p.done:
            raise ValueError(
                "admission is not finished — drive advance_admission until "
                "pending.done before finish_admission"
            )
        h = p.handle
        if h is not None and h.new_spans and not h.closed and not h.rows:
            bt = self.prefix_cache.block_tokens
            for s, e in h.new_spans:
                h.rows.append(self._gather_block(p.cache1, jnp.int32(s), bt))
                if self._has_recurrent:
                    st, vc = next(
                        seg for seg in reversed(p.stack_segments)
                        if seg[0] < e
                    )
                    h.snaps.append(
                        self._boundary_snap(
                            vc, jnp.full((1,), e - 1 - st, jnp.int32)
                        )
                    )
                else:
                    h.snaps.append(None)
        greedy = p.temperature <= 0
        args = (
            jnp.int32(p.plen),
            jnp.int32(p.max_new_tokens),
            jnp.float32(p.temperature if not greedy else 1.0),
            jnp.bool_(greedy),
        )
        if self._slot_spec is None:
            with self._obs_scope("engine/admit", slot=slot):
                out = self._admit(
                    slots, jnp.int32(slot), p.cache1, p.logits1,
                    jax.random.PRNGKey(p.seed), *args,
                )
        else:
            with self._obs_scope("engine/admit", slot=slot, spec=True):
                out = self._admit_spec(
                    slots, jnp.int32(slot), p.cache1, p.dcache1, p.logits1,
                    jax.random.PRNGKey(p.seed),
                    jax.random.PRNGKey(p.seed ^ 0x5BEC),
                    *args, jnp.bool_(p.speculate),
                )
        if h is not None:
            self.prefix_cache.complete(h)
        self._last_prefix_handle = h
        return out

    def abort_admission(self, pending: Optional[PendingAdmission]) -> None:
        """Release a pending admission that will never finish (cancel,
        deadline, prefill fault): unpins its prefix handle without
        committing. Safe to call with ``None`` or repeatedly."""
        if pending is not None and pending.handle is not None:
            self.prefix_cache.abort(pending.handle)

    def take_prefix_handle(self) -> Optional[PrefixHandle]:
        """Pop the (already committed/closed) prefix handle of the most
        recent ``admit_slot``/``finish_admission`` — the scheduler reads hit
        stats off it for lifecycle stamps and trace instants."""
        h, self._last_prefix_handle = self._last_prefix_handle, None
        return h

    def admit_slot(
        self,
        slots: dict,
        slot: int,
        prompt_tokens,
        *,
        max_new_tokens: int,
        temperature: float = 0.0,
        seed: int = 0,
        speculate: bool = True,
    ) -> dict:
        """Prefill one request (batch-1) and install it into `slot`.

        The prefill compiles per distinct prompt length (same caveat as
        `generate`); the install itself compiles once. The slot then produces
        the exact token stream a solo `generate(prompt, max_new_tokens,
        temperature=..., seed=...)` would.

        With a ``prefix_cache`` attached, the longest committed prefix of the
        prompt is installed from cached rows and only the suffix prefills —
        tokens stay bit-identical to the cold path (DESIGN.md §12); the
        prompt's own prefix blocks are committed back on success. This is
        the synchronous composition of ``begin_admission`` →
        ``advance_admission`` → ``finish_admission``.

        In speculative slot batches (``init_slots(speculate=...)``) the draft
        model is prefilled too and the request's FIRST token is sampled at
        admission (recorded in ``slots["t_pend"][slot]`` and counted against
        the budget — the caller must emit it). ``speculate=False`` opts the
        request out per-row: it decodes one plain target token per chunk with
        its solo-identical PRNG stream.
        """
        pending = self.begin_admission(
            prompt_tokens, max_new_tokens=max_new_tokens,
            temperature=temperature, seed=seed, speculate=speculate,
        )
        try:
            self.advance_admission(pending)
            return self.finish_admission(slots, slot, pending)
        except Exception:
            self.abort_admission(pending)
            raise

    def decode_slots(self, slots: dict, n_steps: int):
        """Run `n_steps` decode steps over the whole slot batch.

        Returns `(tokens (B, n_steps) int32, active (B, n_steps) bool,
        new_slots)`; `tokens[b, t]` is a real emission iff `active[b, t]`.
        """
        with self._obs_scope("engine/scan_decode", n_steps=n_steps):
            return self._scan_decode_slots(self.params, slots, n_steps=n_steps)

    def spec_decode_slots(self, slots: dict, n_chunks: int):
        """Run `n_chunks` speculative chunks over the whole slot batch.

        Returns `(tokens (B, n_chunks*(gamma+1)) int32, valid (B, same) bool,
        new_slots)`; each chunk contributes between 1 and gamma+1 valid tokens
        per active row (1 exactly for rows admitted with speculate=False).
        """
        spec = self._slot_spec
        if spec is None or "draft_cache" not in slots:
            raise ValueError("slots were not initialised with speculate=...")
        with self._obs_scope(
            "engine/spec_chunks", n_chunks=n_chunks, gamma=spec.gamma
        ):
            return self._scan_spec_slots(
                self.params, self.draft_params(spec.q_draft), slots,
                n_chunks=n_chunks, gamma=spec.gamma,
            )

    def release_slot(self, slots: dict, slot: int) -> dict:
        """Reclaim one slot at a chunk boundary (cancel/timeout/quarantine):
        the row goes inactive with zero budget and stops emitting; neighbours
        are untouched (per-row masks) and the next admission overwrites the
        row's whole state. Zero trace on surviving rows — asserted by
        tests/test_lifecycle.py's survivor-invariance suite."""
        return self._release(slots, jnp.int32(slot))

    def finite_logit_rows(self, slots: dict) -> np.ndarray:
        """(B,) host bools: row b's carried next-token logits are all finite.
        The scheduler's NaN/inf guard polls this at chunk boundaries and
        quarantines exactly the poisoned rows."""
        return np.asarray(self._finite_rows(slots["logits"]))  # staticcheck: host-sync(the documented chunk-boundary guard poll — (B,) bools, not (B, vocab))

    def poison_logit_row(self, slots: dict, slot: int) -> dict:
        """Fault-injection hook (infer/faults.py): overwrite one row's
        carried logits with NaN, exactly what an upstream numerical fault
        would leave behind. Host-side, between dispatches — never inside a
        jitted computation."""
        return dict(slots, logits=slots["logits"].at[slot].set(jnp.nan))

    def generate(
        self,
        prompt_tokens: np.ndarray,
        n_steps: int,
        *,
        image_emb: Optional[np.ndarray] = None,
        temperature: float = 0.0,
        seed: int = 0,
        scan: bool = True,
        speculate: Optional[SpecConfig] = None,
        stop_tokens=None,
    ) -> GenerationResult:
        """Greedy (temperature=0) or sampled autoregressive generation.

        ``scan=True`` (default) runs the whole decode as one on-device
        ``lax.scan`` for tokens-input models; ``scan=False`` forces the
        per-token step loop (always used for embedding-input models, whose
        host-side ``embed_fn`` cannot run inside the scan).

        ``n_steps`` is a static scan length: each *distinct* value compiles
        its own scan graph once (then cached for the engine's lifetime).
        Serving highly variable lengths? Bucket them, or use ``scan=False``
        whose single ``_decode`` compilation covers every length.

        ``speculate=SpecConfig(q_draft, gamma)`` decodes self-speculatively
        (DESIGN.md §5): a ``q_draft``-bit truncation of the same params drafts
        ``gamma`` tokens per chunk and the full-precision model verifies them
        in one batched forward — greedy output is token-identical to plain
        greedy decode; ``temperature>0`` output follows the exact target
        distribution via rejection sampling (a *different* stream than the
        plain path's for the same seed — per-row PRNG streams). The result's
        ``spec_stats`` reports the draft acceptance rate.

        ``stop_tokens`` (iterable of token ids) marks per-row early stops:
        decode still runs the full ``n_steps`` budget (the scan length is
        static), but the result records each row's first stop position and
        ``GenerationResult.generated(b)`` returns the truncated completion —
        token-identical, up to the stop, to the untruncated run. The
        *serving* path (``Scheduler``) additionally frees the slot at the
        next chunk boundary, which is where the early exit buys throughput."""
        cfg = self.cfg
        b, s = prompt_tokens.shape[:2]
        if s + n_steps > self.max_seq:
            # the KV cache has exactly max_seq rows per slot; decoding past
            # them would wrap/garble device-side state with no error raised
            raise ValueError(
                f"prompt_len({s}) + n_steps({n_steps}) exceeds the engine's "
                f"cache length max_seq={self.max_seq} — decode past the cache "
                f"produces device-side garbage (build the Engine with a "
                f"larger max_seq or shorten the request)"
            )
        if cfg.input_kind == "tokens":
            pt = np.asarray(prompt_tokens)  # staticcheck: host-sync(prompt validation before any device work)
            if pt.size and (pt.min() < 0 or pt.max() >= cfg.vocab):
                raise ValueError(
                    f"prompt token ids must lie in [0, vocab={cfg.vocab}); got "
                    f"range [{pt.min()}, {pt.max()}] — out-of-range ids index "
                    f"garbage embedding rows device-side"
                )
        cache = self._make_cache(b)
        with self._obs_scope("engine/prefill", prompt_len=s, batch=b):
            logits, cache = self._prefill(
                self.params, jnp.asarray(prompt_tokens), image_emb, cache
            )
        key = jax.random.PRNGKey(seed)
        greedy = temperature <= 0

        def _result(tokens: np.ndarray, **kw) -> GenerationResult:
            sp = None
            if stop_tokens:
                sp = stop_positions_for(tokens[:, s:], stop_tokens)
            return GenerationResult(
                tokens=tokens, prompt_len=s, steps=n_steps,
                stop_positions=sp, **kw,
            )

        if speculate is not None:
            self._validate_spec(speculate)
            if s + n_steps + speculate.gamma > self.max_seq:
                raise ValueError(
                    f"prompt({s}) + n_steps({n_steps}) + gamma({speculate.gamma}) "
                    f"exceeds max_seq={self.max_seq}"
                )
            draft = self.draft_params(speculate.q_draft)
            dcache = self._make_cache(b)
            with self._obs_scope(
                "engine/prefill_draft", prompt_len=s, batch=b,
                q_draft=speculate.q_draft,
            ):
                _, dcache = self._prefill(
                    draft, jnp.asarray(prompt_tokens), image_emb, dcache
                )
            with self._obs_scope(
                "engine/spec_generate", n_steps=n_steps, gamma=speculate.gamma
            ):
                toks, (acc, prop, chunks) = self._spec_generate(
                    self.params, draft, logits[:, -1], cache, dcache,
                    jnp.full((b,), s, jnp.int32), key,
                    jax.random.PRNGKey(seed ^ 0x5BEC),
                    jnp.float32(temperature if not greedy else 1.0),
                    n_steps=n_steps, gamma=speculate.gamma, greedy=greedy,
                )
            tokens = np.concatenate(
                [np.asarray(prompt_tokens), np.asarray(toks)], axis=1  # staticcheck: host-sync(one fetch for the whole speculative generation)
            )
            acc, prop, chunks = int(acc), int(prop), int(chunks)
            return _result(
                tokens,
                spec_stats={
                    "accept_rate": acc / max(prop, 1),
                    "accepted": acc,
                    "proposed": prop,
                    "chunks": chunks,
                    "q_draft": speculate.q_draft,
                    "gamma": speculate.gamma,
                },
            )

        if scan and cfg.input_kind == "tokens":
            with self._obs_scope("engine/scan_decode", n_steps=n_steps, batch=b):
                toks, _ = self._scan_decode(
                    self.params,
                    logits[:, -1],
                    cache,
                    jnp.int32(s),
                    key,
                    jnp.float32(temperature if not greedy else 1.0),
                    n_steps=n_steps,
                    greedy=greedy,
                )
            tokens = np.concatenate([np.asarray(prompt_tokens), np.asarray(toks)], axis=1)  # staticcheck: host-sync(one fetch for the whole scanned decode)
            return _result(tokens)

        out = [np.asarray(prompt_tokens)] if cfg.input_kind == "tokens" else []  # staticcheck: host-sync(prompt is host input)
        for step in range(n_steps):
            if not greedy:
                key, sub = jax.random.split(key)
                tok = _sample(logits[:, -1], sub, temperature, greedy=False)[:, None]
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(np.asarray(tok))  # staticcheck: host-sync(per-token step loop — the scan path exists to avoid this)
            if cfg.input_kind != "tokens":
                if self.embed_fn is None:
                    raise ValueError(
                        "embedding-input model: pass embed_fn to Engine to map "
                        "sampled codes back to frame embeddings"
                    )
                tok = jnp.asarray(self.embed_fn(np.asarray(tok))).astype(cfg.cdtype)  # staticcheck: host-sync(embed_fn is host-side by contract)
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(s + step)
            )
        tokens = np.concatenate(out, axis=1)
        if cfg.input_kind != "tokens":
            if stop_tokens:
                raise ValueError(
                    "stop_tokens is only supported for tokens-input models "
                    "(modality-stub outputs are code streams, not vocab ids)"
                )
            return GenerationResult(tokens=tokens, prompt_len=s, steps=n_steps)
        return _result(tokens)
