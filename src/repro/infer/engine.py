"""Batched generation engine implementing the paper's Fig. 13 strategy.

Weights live in memory **once**, in packed BCQ format. The two stages consume
them differently:

- **summarization / context (prefill)** — compute-bound, large effective batch:
  weights are dequantized and fed to dense matmuls (on TPU: the fused
  dequant-in-VMEM ``bcq_mm`` tile loop; the dequantized matrix never re-enters
  HBM). Rationale (paper §V.B): dequant cost is amortised over many tokens.
- **generation (decode)** — memory-bound single-token steps: LUT-GEMM consumes
  the packed format directly.

The engine also serves *dense* models (pass unquantized params) so the
cuBLAS-analogue baseline uses the identical code path.

Decode fast path (DESIGN.md §2.3): at construction the params are run through
:func:`repro.models.fuse_decode_projections` (``fuse=True`` default) so QKV and
gate/up issue one fused projection kernel each, and ``generate`` runs all N
decode steps as a single jitted ``jax.lax.scan`` (``scan=True`` default) —
sampling happens on device inside the scan body, the KV cache is threaded
through the carry, and the host syncs once for the whole sequence instead of
once per token. Embedding-input (modality-stub) models fall back to the
per-token step loop because ``embed_fn`` runs host-side.

Caveat (TPU): the scan threads the KV cache through the carry — the body
reads the whole cache and dynamic-update-slices one slot per step. This is
the standard JAX decode idiom (XLA's while-loop lowering updates loop-carried
buffers in place), but it is a *different* access pattern from the
layer-stacked cache-as-carry variant that ``models/layers.py::_cache_write``
measured and rejected (dynamic per-layer slice reads triggered copy-insertion
duplication of the carry). CPU-host timings (BENCH_decode.json: 1.44x over
the step loop) cannot rule that pathology out on TPU — profile HBM traffic
there before relying on the scan path at large ``max_seq``; ``scan=False``
is the escape hatch.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward, fuse_decode_projections, init_cache
from repro.models.config import ModelConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, prompt+generated)
    prompt_len: int
    steps: int


def _sample(logits: jax.Array, key: jax.Array, temperature, greedy: bool) -> jax.Array:
    """(B, V) f32 logits → (B,) int32 tokens, on device."""
    if greedy:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature)


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_seq: int = 2048,
        embed_fn=None,
        fuse: bool = True,
    ):
        """``embed_fn(tokens (B,1) int32) → (B,1,D)`` is required for
        embedding-input (modality-stub) models to feed sampled codes back in —
        it stands in for the stubbed frontend (e.g. EnCodec codebook embed).

        ``fuse=False`` keeps the unfused per-projection weight layout
        (debugging / layouts the fuser declines are left unfused anyway)."""
        self.cfg = cfg
        self.params = fuse_decode_projections(cfg, params) if fuse else params
        self.max_seq = max_seq
        self.embed_fn = embed_fn
        self._unit_cache = None  # lazy batch-1 prefill template (admit_slot)

        def _prefill(params, tokens, image_emb, cache):
            kw = (
                {"tokens": tokens}
                if cfg.input_kind == "tokens"
                else {"embeddings": tokens}
            )
            if cfg.family == "vlm":
                kw["image_emb"] = image_emb
            logits, cache, _ = forward(
                cfg, params, **kw, cache=cache, pos=jnp.int32(0), logits_mode="last"
            )
            return logits, cache

        def _decode(params, tok, cache, pos):
            kw = {"tokens": tok} if cfg.input_kind == "tokens" else {"embeddings": tok}
            if cfg.family == "vlm":
                kw["image_emb"] = None
            logits, cache, _ = forward(
                cfg, params, **kw, cache=cache, pos=pos, logits_mode="last"
            )
            return logits, cache

        def _scan_decode(params, logits0, cache, pos0, key, temperature, *, n_steps, greedy):
            """N decode steps as ONE dispatch: sample → step, all on device.

            The carry holds (last logits, cache, position, PRNG key); the
            stacked scan output is the sampled token matrix. The key-split /
            sample order matches the step loop exactly, so scanned and
            step-loop generations are bit-identical (test_engine_scan).
            """

            def body(carry, _):
                logits, cache, pos, key = carry
                key, sub = jax.random.split(key)
                tok = _sample(logits, sub, temperature, greedy)
                logits2, cache = _decode(params, tok[:, None], cache, pos)
                return (logits2[:, -1], cache, pos + 1, key), tok

            (_, cache, _, _), toks = jax.lax.scan(
                body, (logits0, cache, pos0, key), None, length=n_steps
            )
            return toks.T, cache  # (B, n_steps)

        def _admit(slots, slot, cache1, logits1, key, plen, max_new, temperature, greedy):
            """Install a freshly prefilled request into batch row `slot`.

            `cache1` is the batch-1 prefilled cache; every cache leaf is
            (repeat, batch, ...) so the row write is one dynamic-update-slice
            per leaf along axis 1. The slot's whole state row (KV rows,
            recurrent state, position counter, PRNG key, sampling params) is
            overwritten — nothing from the previous tenant survives, which is
            the slot-reset contract (DESIGN.md §4).
            """
            cache = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1
                ),
                slots["cache"],
                cache1,
            )
            return {
                "cache": cache,
                "logits": slots["logits"].at[slot].set(logits1[0]),
                "pos": slots["pos"].at[slot].set(plen),
                "keys": slots["keys"].at[slot].set(key),
                "active": slots["active"].at[slot].set(True),
                "remaining": slots["remaining"].at[slot].set(max_new),
                "temperature": slots["temperature"].at[slot].set(temperature),
                "greedy": slots["greedy"].at[slot].set(greedy),
            }

        def _scan_decode_slots(params, slots, *, n_steps):
            """`n_steps` slot-batched decode steps as ONE dispatch.

            Like `_scan_decode`, but each batch row is an independent request
            with its own position counter, PRNG key and sampling params, plus
            an active mask: inactive rows keep their key/logits/position
            frozen so a row's (key-split, sample) sequence advances exactly
            once per emitted token — the same sequence a solo batch-1
            `generate` of that request produces. Inactive rows still flow
            through the batched forward (they decode garbage into their own
            cache rows at a frozen position, which is harmless: a row's cache
            beyond its position is never attended, and admission rewrites the
            slot's state from scratch).

            Per-row sampling matches batch-1 `_sample` bit-for-bit: the
            categorical is taken over a (1, V) row under vmap, which JAX's
            counter-based PRNG evaluates identically to a standalone call.
            """
            temperature, greedy = slots["temperature"], slots["greedy"]

            def body(carry, _):
                logits, cache, pos, keys, active, remaining = carry
                splits = jax.vmap(jax.random.split)(keys)  # (B, 2, 2)
                new_keys = jnp.where(active[:, None], splits[:, 0], keys)
                sub = splits[:, 1]
                sampled = jax.vmap(
                    lambda lg, kk, t: jax.random.categorical(kk, lg[None] / t)[0]
                )(logits, sub, temperature)
                tok = jnp.where(greedy, jnp.argmax(logits, axis=-1), sampled)
                tok = tok.astype(jnp.int32)
                logits2, cache2 = _decode(params, tok[:, None], cache, pos)
                new_logits = jnp.where(active[:, None], logits2[:, -1], logits)
                new_pos = jnp.where(active, pos + 1, pos)
                new_rem = jnp.where(active, remaining - 1, remaining)
                new_active = active & (new_rem > 0)
                emitted = jnp.where(active, tok, -1)
                return (
                    (new_logits, cache2, new_pos, new_keys, new_active, new_rem),
                    (emitted, active),
                )

            carry = (
                slots["logits"], slots["cache"], slots["pos"],
                slots["keys"], slots["active"], slots["remaining"],
            )
            carry, (toks, actives) = jax.lax.scan(body, carry, None, length=n_steps)
            logits, cache, pos, keys, active, remaining = carry
            out = dict(
                slots,
                logits=logits, cache=cache, pos=pos, keys=keys,
                active=active, remaining=remaining,
            )
            return toks.T, actives.T, out  # (B, n_steps) each

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._scan_decode = jax.jit(
            _scan_decode, static_argnames=("n_steps", "greedy")
        )
        # donate the incoming slot state: both return a full replacement and
        # the scheduler drops the old dict, so the n_slots-wide KV cache can
        # be updated in place instead of copied per dispatch (the same hazard
        # launch/dryrun.py documents for the one-shot decode step)
        self._admit = jax.jit(_admit, donate_argnums=(0,))
        self._scan_decode_slots = jax.jit(
            _scan_decode_slots, static_argnames=("n_steps",), donate_argnums=(1,)
        )

    # -- slot-batched serving API (infer/scheduler.py drives these) ---------

    def init_slots(self, n_slots: int) -> dict:
        """Fresh slot-batched decode state: a `n_slots`-wide KV cache plus
        per-slot counters/sampling params. All slots start inactive."""
        if self.cfg.input_kind != "tokens" or self.cfg.family == "vlm":
            raise ValueError(
                "slot-batched serving requires a tokens-input, non-VLM model "
                "(embed_fn/image inputs cannot run inside the slotted scan)"
            )
        if self.cfg.n_experts:
            # MoE expert capacity is shared across the batch: tokens from other
            # slots — including garbage from inactive rows — can evict an
            # active request's tokens from an expert buffer, so slot outputs
            # are neither solo-identical nor slot-history-independent. Reject
            # rather than silently break the scheduler's contract (DESIGN §4).
            raise ValueError(
                "slot-batched serving does not support MoE models: shared "
                "expert capacity couples batch rows, breaking per-request "
                "token-identity (use one-shot Engine.generate instead)"
            )
        return {
            "cache": init_cache(self.cfg, n_slots, self.max_seq),
            "logits": jnp.zeros((n_slots, self.cfg.vocab), jnp.float32),
            "pos": jnp.zeros((n_slots,), jnp.int32),
            "keys": jnp.zeros((n_slots, 2), jnp.uint32),
            "active": jnp.zeros((n_slots,), bool),
            "remaining": jnp.zeros((n_slots,), jnp.int32),
            "temperature": jnp.ones((n_slots,), jnp.float32),
            "greedy": jnp.ones((n_slots,), bool),
        }

    def admit_slot(
        self,
        slots: dict,
        slot: int,
        prompt_tokens,
        *,
        max_new_tokens: int,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> dict:
        """Prefill one request (batch-1) and install it into `slot`.

        The prefill compiles per distinct prompt length (same caveat as
        `generate`); the install itself compiles once. The slot then produces
        the exact token stream a solo `generate(prompt, max_new_tokens,
        temperature=..., seed=...)` would.
        """
        prompt = jnp.asarray(prompt_tokens, jnp.int32).reshape(1, -1)
        plen = int(prompt.shape[1])
        if plen + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt_len({plen}) + max_new_tokens({max_new_tokens}) exceeds "
                f"max_seq={self.max_seq}"
            )
        if self._unit_cache is None:
            # one zeroed batch-1 cache per engine: _prefill is purely
            # functional (no donation), so the template is reusable and the
            # admission hot path skips a full max_seq cache alloc+zero
            self._unit_cache = init_cache(self.cfg, 1, self.max_seq)
        logits, cache1 = self._prefill(self.params, prompt, None, self._unit_cache)
        greedy = temperature <= 0
        return self._admit(
            slots,
            jnp.int32(slot),
            cache1,
            logits[:, -1],
            jax.random.PRNGKey(seed),
            jnp.int32(plen),
            jnp.int32(max_new_tokens),
            jnp.float32(temperature if not greedy else 1.0),
            jnp.bool_(greedy),
        )

    def decode_slots(self, slots: dict, n_steps: int):
        """Run `n_steps` decode steps over the whole slot batch.

        Returns `(tokens (B, n_steps) int32, active (B, n_steps) bool,
        new_slots)`; `tokens[b, t]` is a real emission iff `active[b, t]`.
        """
        return self._scan_decode_slots(self.params, slots, n_steps=n_steps)

    def generate(
        self,
        prompt_tokens: np.ndarray,
        n_steps: int,
        *,
        image_emb: Optional[np.ndarray] = None,
        temperature: float = 0.0,
        seed: int = 0,
        scan: bool = True,
    ) -> GenerationResult:
        """Greedy (temperature=0) or sampled autoregressive generation.

        ``scan=True`` (default) runs the whole decode as one on-device
        ``lax.scan`` for tokens-input models; ``scan=False`` forces the
        per-token step loop (always used for embedding-input models, whose
        host-side ``embed_fn`` cannot run inside the scan).

        ``n_steps`` is a static scan length: each *distinct* value compiles
        its own scan graph once (then cached for the engine's lifetime).
        Serving highly variable lengths? Bucket them, or use ``scan=False``
        whose single ``_decode`` compilation covers every length."""
        cfg = self.cfg
        b, s = prompt_tokens.shape[:2]
        cache = init_cache(cfg, b, self.max_seq)
        logits, cache = self._prefill(
            self.params, jnp.asarray(prompt_tokens), image_emb, cache
        )
        key = jax.random.PRNGKey(seed)
        greedy = temperature <= 0

        if scan and cfg.input_kind == "tokens":
            toks, _ = self._scan_decode(
                self.params,
                logits[:, -1],
                cache,
                jnp.int32(s),
                key,
                jnp.float32(temperature if not greedy else 1.0),
                n_steps=n_steps,
                greedy=greedy,
            )
            tokens = np.concatenate([np.asarray(prompt_tokens), np.asarray(toks)], axis=1)
            return GenerationResult(tokens=tokens, prompt_len=s, steps=n_steps)

        out = [np.asarray(prompt_tokens)] if cfg.input_kind == "tokens" else []
        for step in range(n_steps):
            if not greedy:
                key, sub = jax.random.split(key)
                tok = _sample(logits[:, -1], sub, temperature, greedy=False)[:, None]
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(np.asarray(tok))
            if cfg.input_kind != "tokens":
                if self.embed_fn is None:
                    raise ValueError(
                        "embedding-input model: pass embed_fn to Engine to map "
                        "sampled codes back to frame embeddings"
                    )
                tok = jnp.asarray(self.embed_fn(np.asarray(tok))).astype(cfg.cdtype)
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(s + step)
            )
        tokens = np.concatenate(out, axis=1)
        return GenerationResult(tokens=tokens, prompt_len=s, steps=n_steps)
