"""Batched generation engine implementing the paper's Fig. 13 strategy.

Weights live in memory **once**, in packed BCQ format. The two stages consume
them differently:

- **summarization / context (prefill)** — compute-bound, large effective batch:
  weights are dequantized and fed to dense matmuls (on TPU: the fused
  dequant-in-VMEM ``bcq_mm`` tile loop; the dequantized matrix never re-enters
  HBM). Rationale (paper §V.B): dequant cost is amortised over many tokens.
- **generation (decode)** — memory-bound single-token steps: LUT-GEMM consumes
  the packed format directly.

The engine also serves *dense* models (pass unquantized params) so the
cuBLAS-analogue baseline uses the identical code path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward, init_cache
from repro.models.config import ModelConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, prompt+generated)
    prompt_len: int
    steps: int


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 2048, embed_fn=None):
        """``embed_fn(tokens (B,1) int32) → (B,1,D)`` is required for
        embedding-input (modality-stub) models to feed sampled codes back in —
        it stands in for the stubbed frontend (e.g. EnCodec codebook embed)."""
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.embed_fn = embed_fn

        def _prefill(params, tokens, image_emb, cache):
            kw = (
                {"tokens": tokens}
                if cfg.input_kind == "tokens"
                else {"embeddings": tokens}
            )
            if cfg.family == "vlm":
                kw["image_emb"] = image_emb
            logits, cache, _ = forward(
                cfg, params, **kw, cache=cache, pos=jnp.int32(0), logits_mode="last"
            )
            return logits, cache

        def _decode(params, tok, cache, pos):
            kw = {"tokens": tok} if cfg.input_kind == "tokens" else {"embeddings": tok}
            if cfg.family == "vlm":
                kw["image_emb"] = None
            logits, cache, _ = forward(
                cfg, params, **kw, cache=cache, pos=pos, logits_mode="last"
            )
            return logits, cache

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def generate(
        self,
        prompt_tokens: np.ndarray,
        n_steps: int,
        *,
        image_emb: Optional[np.ndarray] = None,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> GenerationResult:
        """Greedy (temperature=0) or sampled autoregressive generation."""
        cfg = self.cfg
        b, s = prompt_tokens.shape[:2]
        cache = init_cache(cfg, b, self.max_seq)
        logits, cache = self._prefill(
            self.params, jnp.asarray(prompt_tokens), image_emb, cache
        )
        key = jax.random.PRNGKey(seed)
        out = [np.asarray(prompt_tokens)] if cfg.input_kind == "tokens" else []
        tok = None
        for step in range(n_steps):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(np.asarray(tok))
            if cfg.input_kind != "tokens":
                if self.embed_fn is None:
                    raise ValueError(
                        "embedding-input model: pass embed_fn to Engine to map "
                        "sampled codes back to frame embeddings"
                    )
                tok = jnp.asarray(self.embed_fn(np.asarray(tok))).astype(cfg.cdtype)
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(s + step)
            )
        tokens = np.concatenate(out, axis=1)
        return GenerationResult(tokens=tokens, prompt_len=s, steps=n_steps)
