"""Radix-trie prefix cache over committed token prefixes (DESIGN.md §12).

LUT-GEMM attacks the decode-side memory wall; at serving scale the other
half of the cost is redundant *prefill* — every request recomputes KV rows
for the shared system prompt. This module indexes committed prompt prefixes
in a radix trie of fixed-size token blocks, each block backed by
device-resident cache rows, so admission can install the shared prefix and
prefill only the uncached suffix (``Engine.begin_admission`` consults it).

Structure
---------
- One trie node per block of ``block_tokens`` consecutive token ids; the
  child edge key is the block's raw token bytes, so lookup is exact (no
  hash-collision false sharing) and O(plen / block_tokens).
- A node owns the block's POSITIONAL cache rows (``(repeat, 1, bt, ...)``
  per leaf, gathered by :func:`repro.models.layers.gather_prefix_rows`) and,
  for recurrent architectures, a RECURRENT boundary snapshot of the state
  after the block's last token. STATIC leaves are never stored (no-op class).
- Nodes are **ref-counted**: :meth:`begin` pins the matched path for the
  lifetime of the admission; :meth:`complete`/:meth:`abort` unpin. Pinned
  nodes are never evicted, so accounting is exact even though installs are
  *copies* (eviction after install is correctness-harmless by construction).
- **LRU eviction** keeps ``cached_bytes <= max_bytes``: only childless,
  unpinned nodes are candidates (chains drain leaf-first), oldest
  ``last_used`` first.
- The cache is bound to one ``(model, quant-policy)`` identity
  (:func:`model_identity`): an engine with a different config, quantization
  policy, per-leaf format map, or mesh refuses to share it.

Accounting invariants (tests/test_prefix_cache.py)::

    hits + misses == commits + aborts     # every begin() ends exactly once
    pinned == 0                           # at shutdown / between requests

Host-side only: the trie, refcounts and LRU live on the host; the rows it
stores are device arrays produced by the engine's jitted gather and consumed
by its jitted install — this module never traces or compiles anything.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def model_identity(cfg, params, mesh=None) -> str:
    """Digest of the (model, quant-policy) identity a prefix cache keys on.

    Covers the config, every param leaf's path + shape/dtype, and — for
    :class:`~repro.core.qtensor.QuantizedTensor` leaves — the format tag and
    ``(q, g, k, o)`` statics, plus the mesh shape (sharded rows are reusable
    only under the same placement). Weight *values* are deliberately not
    hashed (that would force a device fetch); the identity guards against
    structural misuse — sharing a cache across quant policies or
    architectures — not against reloading different checkpoints into
    byte-identical shapes.
    """
    from repro.core.qtensor import QuantizedTensor

    parts = [repr(cfg)]
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if isinstance(leaf, QuantizedTensor):
            parts.append(
                f"{name}:{leaf.fmt}:q{leaf.q}:g{leaf.g}:{leaf.k}x{leaf.o}"
            )
        else:
            parts.append(f"{name}:dense:{leaf.dtype}:{tuple(leaf.shape)}")
    if mesh is not None:
        parts.append(f"mesh:{sorted(dict(mesh.shape).items())}")
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:16]


def concat_rows(rows_list):
    """Concatenate per-block row pytrees along the row axis (axis 2).
    Placeholder leaves (non-positional, shape ``(0,)``) pass through."""

    def cat(*xs):
        if xs[0].ndim < 3:
            return xs[0]
        return jnp.concatenate(xs, axis=2)

    return jax.tree.map(cat, *rows_list)


def pad_rows(rows, total: int):
    """Zero-pad a row pytree's row axis (axis 2) up to ``total`` rows, so the
    jitted install compiles once per row *bucket* instead of once per prefix
    length. Safe on a fresh cache: rows past the real prefix are zero there
    already (see :func:`repro.models.layers.install_prefix_rows`)."""

    def pad(x):
        if x.ndim < 3 or x.shape[2] == total:
            return x
        widths = [(0, 0)] * x.ndim
        widths[2] = (0, total - x.shape[2])
        return jnp.pad(x, widths)

    return jax.tree.map(pad, rows)


def _tree_nbytes(tree) -> int:
    return sum(int(x.nbytes) for x in jax.tree.leaves(tree))


class _Node:
    __slots__ = (
        "key", "tokens", "parent", "children", "rows", "snap",
        "nbytes", "refs", "last_used", "end",
    )

    def __init__(self, key: bytes, tokens, parent, rows, snap, end: int):
        self.key = key
        self.tokens = tokens
        self.parent = parent
        self.children: Dict[bytes, "_Node"] = {}
        self.rows = rows
        self.snap = snap
        self.nbytes = _tree_nbytes(rows) + (
            0 if snap is None else _tree_nbytes(snap)
        )
        self.refs = 0
        self.last_used = 0
        self.end = end  # prefix length (tokens) through this node


@dataclasses.dataclass
class PrefixHandle:
    """One admission's view of the cache: the pinned matched path plus the
    commit plan for the blocks the prompt would add. The engine fills
    ``rows``/``snaps`` (aligned with ``new_spans``) at finish-admission time;
    the scheduler calls :meth:`PrefixCache.complete` at the request's
    terminal transition (or :meth:`PrefixCache.abort` if admission died)."""

    tokens: np.ndarray                       # full prompt, host int32
    matched: List[_Node]                     # pinned root→leaf path
    length: int                              # matched prefix tokens
    new_spans: List[Tuple[int, int]]         # blocks to commit: [(start, end))
    rows: List[object] = dataclasses.field(default_factory=list)
    snaps: List[Optional[object]] = dataclasses.field(default_factory=list)
    closed: bool = False


class PrefixCache:
    """Ref-counted, LRU-evicted radix trie of device-resident prefix blocks.

    ``block_tokens`` is the trie granularity (a prefix is reusable in
    multiples of it); ``max_bytes`` bounds the device bytes held by
    committed blocks. ``metrics``/``tracer`` mirror the counters into a
    :class:`repro.obs.metrics.MetricsRegistry` (``prefix_<key>_total`` +
    cached-bytes/trie-size gauges) and emit ``evict`` trace instants; the
    scheduler attaches its own via :meth:`attach` when none were given.
    """

    def __init__(
        self,
        *,
        block_tokens: int = 16,
        max_bytes: int = 64 << 20,
        metrics=None,
        tracer=None,
    ):
        if block_tokens < 1:
            raise ValueError(f"block_tokens must be >= 1, got {block_tokens}")
        if max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.block_tokens = block_tokens
        self.max_bytes = max_bytes
        self.metrics = metrics
        self.tracer = tracer
        self._root = _Node(b"", None, None, {}, None, 0)
        self._root.nbytes = 0
        self._nodes: List[_Node] = []  # all non-root nodes (small; scans ok)
        self._bytes = 0
        self._tick = 0
        self._model_key: Optional[str] = None
        self.counters: Dict[str, int] = {
            "hits": 0, "misses": 0, "commits": 0, "aborts": 0, "evictions": 0,
        }
        if metrics is not None:
            self._register_series()

    # -- observability -------------------------------------------------------

    def attach(self, metrics=None, tracer=None) -> None:
        """Adopt a registry/tracer if none were given at construction (the
        scheduler calls this so serve metrics and prefix metrics share one
        exporter)."""
        if self.metrics is None and metrics is not None:
            self.metrics = metrics
            self._register_series()
        if self.tracer is None and tracer is not None:
            self.tracer = tracer

    def _register_series(self) -> None:
        for key in self.counters:
            self.metrics.counter(
                f"prefix_{key}_total", f"prefix cache events: {key}"
            )
        self._set_gauges()

    def _count(self, key: str, n: int = 1) -> None:
        self.counters[key] += n
        if self.metrics is not None:
            self.metrics.counter(f"prefix_{key}_total").inc(n)

    def _set_gauges(self) -> None:
        if self.metrics is None:
            return
        self.metrics.gauge(
            "prefix_cached_bytes", "device bytes held by committed blocks"
        ).set(self._bytes)
        self.metrics.gauge(
            "prefix_trie_nodes", "committed prefix blocks in the trie"
        ).set(len(self._nodes))
        self.metrics.gauge(
            "prefix_pinned_refs", "outstanding pins (in-flight admissions)"
        ).set(self.pinned)

    # -- identity ------------------------------------------------------------

    def bind(self, model_key: str) -> None:
        """First bind wins; a later engine with a different identity refuses
        to share the cache (its rows would be garbage for that model)."""
        if self._model_key is None:
            self._model_key = model_key
        elif self._model_key != model_key:
            raise ValueError(
                f"prefix cache is bound to model identity "
                f"{self._model_key!r}; refusing to serve {model_key!r} — "
                f"one PrefixCache per (model, quant-policy)"
            )

    # -- stats ---------------------------------------------------------------

    @property
    def cached_bytes(self) -> int:
        return self._bytes

    @property
    def n_nodes(self) -> int:
        return len(self._nodes)

    @property
    def pinned(self) -> int:
        return sum(n.refs for n in self._nodes)

    def stats(self) -> dict:
        return {
            "nodes": len(self._nodes),
            "cached_bytes": self._bytes,
            "pinned": self.pinned,
            "block_tokens": self.block_tokens,
            "max_bytes": self.max_bytes,
            **self.counters,
        }

    # -- the admission protocol ---------------------------------------------

    def begin(
        self, tokens, *, max_match: int, max_commit: int
    ) -> PrefixHandle:
        """Match-and-pin: walk the trie over the prompt's leading blocks,
        pin the matched path, and plan which new blocks a commit would add.

        ``max_match`` caps the reusable prefix (the engine passes
        ``plen - 1`` — at least the last prompt token must prefill so decode
        has logits — min the ring cap). ``max_commit`` caps the committable
        prefix (0 when a ring cache wrapped during prefill and early rows
        were clobbered). Every ``begin`` is ended by exactly one
        ``complete``/``abort``.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)  # staticcheck: host-sync(prompt ids are host input; the trie walk is host-side by design)
        bt = self.block_tokens
        self._tick += 1
        node, matched, length = self._root, [], 0
        while length + bt <= max_match:
            child = node.children.get(tokens[length : length + bt].tobytes())
            if child is None:
                break
            matched.append(child)
            node = child
            length += bt
        for n in matched:
            n.refs += 1
            n.last_used = self._tick
        want = (max(0, max_commit) // bt) * bt
        spans = [(s, s + bt) for s in range(length, want, bt)]
        self._count("hits" if length else "misses")
        return PrefixHandle(
            tokens=tokens, matched=matched, length=length, new_spans=spans
        )

    def complete(self, handle: PrefixHandle) -> None:
        """Commit the handle's new blocks (rows/snaps filled by the engine)
        and unpin its matched path. Idempotent; racing identical commits
        (two requests with the same prompt in flight) keep the first-inserted
        block and drop the duplicate rows."""
        if handle.closed:
            return
        handle.closed = True
        self._tick += 1
        node = handle.matched[-1] if handle.matched else self._root
        for i, (s, e) in enumerate(handle.new_spans):
            if i >= len(handle.rows):
                break  # engine stopped capturing (e.g. budget/ring guard)
            key = handle.tokens[s:e].tobytes()
            child = node.children.get(key)
            if child is None:
                snap = handle.snaps[i] if i < len(handle.snaps) else None
                child = _Node(
                    key, handle.tokens[s:e].copy(), node,
                    handle.rows[i], snap, e,
                )
                node.children[key] = child
                self._nodes.append(child)
                self._bytes += child.nbytes
            child.last_used = self._tick
            node = child
        self._unpin(handle)
        self._count("commits")
        self._evict_to_budget()
        self._set_gauges()

    def abort(self, handle: PrefixHandle) -> None:
        """Unpin without committing (admission failed/cancelled mid-prefill).
        Idempotent."""
        if handle.closed:
            return
        handle.closed = True
        self._unpin(handle)
        self._count("aborts")
        self._set_gauges()

    def _unpin(self, handle: PrefixHandle) -> None:
        for n in handle.matched:
            assert n.refs > 0, "refcount underflow: begin/complete mismatch"
            n.refs -= 1

    # -- eviction ------------------------------------------------------------

    def _evictable(self) -> List[_Node]:
        return [n for n in self._nodes if not n.children and n.refs == 0]

    def _evict_one(self, node: _Node) -> None:
        del node.parent.children[node.key]
        self._nodes.remove(node)
        self._bytes -= node.nbytes
        node.rows = node.snap = None  # drop the device references now
        self._count("evictions")
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.instant(
                "evict", cat="prefix", lane="scheduler",
                args={
                    "block_end": node.end, "nbytes": node.nbytes,
                    "cached_bytes": self._bytes, "nodes": len(self._nodes),
                },
            )

    def _evict_to_budget(self) -> None:
        while self._bytes > self.max_bytes:
            victims = self._evictable()
            if not victims:
                return  # everything live is pinned or interior — over-budget
            self._evict_one(min(victims, key=lambda n: n.last_used))

    def evict_to(self, max_bytes: int) -> None:
        """Shrink the budget and evict down to it immediately (memory
        pressure hook; also the test harness for mid-flight eviction)."""
        self.max_bytes = max_bytes
        self._evict_to_budget()
        self._set_gauges()
