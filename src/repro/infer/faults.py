"""Deterministic fault injection for the serving stack (DESIGN.md §9).

Every degradation path the hardened scheduler claims to handle — prefill
dispatch failures, decode-chunk dispatch failures, NaN-poisoned logit rows,
slow clients, queue floods — must be *demonstrable*, not theoretical. A
:class:`FaultPlan` is threaded through the scheduler's dispatch points (and
the async server's client-facing stream) and fires its faults at exact,
reproducible points:

- ``fail_prefill={rid: n}`` — the admission prefill for request ``rid``
  raises :class:`InjectedFault` on its first ``n`` attempts (``n=-1`` →
  every attempt, i.e. a permanent failure). Retries re-consult the plan, so
  ``n <= retries`` exercises recover-after-retry and ``n = -1`` exercises
  the quarantine path.
- ``fail_chunk={ordinal: n}`` — the ``ordinal``-th decode-chunk dispatch
  (0-based, counted over the scheduler's lifetime) raises on its first
  ``n`` attempts.
- ``nan_row={rid: k}`` — once request ``rid`` has emitted ``>= k`` tokens,
  its logits row is overwritten with NaN at the next chunk boundary; the
  scheduler's NaN/inf guard must then quarantine exactly that row.
- ``client_stall={rid: seconds}`` — the async server sleeps this long
  before forwarding each event of ``rid`` to its client, simulating a slow
  consumer (exercises the bounded per-stream buffer policy).

Faults are injected *host-side, before (or between) engine dispatches* —
never inside a jitted computation. This matters for retry soundness: an
injected failure raises before the engine consumes (and donates) the slot
state, so the state is intact and the retry is exact. The plan mutates as it
fires (countdowns decrement, one-shot faults mark themselves done); build a
fresh plan per run.

:class:`StepClock` is the companion fake clock: deadlines and backoff are
wall-clock quantities, so the scheduler takes injectable ``clock``/``sleep``
callables and the tests drive them deterministically.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


class InjectedFault(RuntimeError):
    """A fault fired by a FaultPlan (stands in for a transient XLA/dispatch
    failure at exactly the point the plan names)."""


@dataclasses.dataclass
class FaultPlan:
    fail_prefill: Dict[int, int] = dataclasses.field(default_factory=dict)
    fail_chunk: Dict[int, int] = dataclasses.field(default_factory=dict)
    nan_row: Dict[int, int] = dataclasses.field(default_factory=dict)
    client_stall: Dict[int, float] = dataclasses.field(default_factory=dict)

    # counters the tests/benchmarks read back
    fired_prefill: int = 0
    fired_chunk: int = 0
    fired_nan: int = 0

    def on_prefill(self, rid: int) -> None:
        """Called per admission-prefill *attempt* for request ``rid``."""
        left = self.fail_prefill.get(rid, 0)
        if left == 0:
            return
        if left > 0:
            self.fail_prefill[rid] = left - 1
        self.fired_prefill += 1
        raise InjectedFault(f"injected prefill failure for request {rid}")

    def on_chunk(self, ordinal: int) -> None:
        """Called per decode-chunk dispatch *attempt*; ``ordinal`` counts
        dispatched chunks over the scheduler's lifetime."""
        left = self.fail_chunk.get(ordinal, 0)
        if left == 0:
            return
        if left > 0:
            self.fail_chunk[ordinal] = left - 1
        self.fired_chunk += 1
        raise InjectedFault(f"injected decode failure at chunk {ordinal}")

    def poison_due(self, rid: int, n_emitted: int) -> bool:
        """True exactly once: when ``rid`` has emitted >= its threshold."""
        k = self.nan_row.get(rid)
        if k is None or n_emitted < k:
            return False
        del self.nan_row[rid]  # fire once
        self.fired_nan += 1
        return True

    def stall_for(self, rid: int) -> float:
        return self.client_stall.get(rid, 0.0)


class StepClock:
    """Deterministic clock for deadline tests: advances ``dt`` per reading.

    ``sleep`` advances the clock by the requested amount without real waiting,
    so backoff paths are exact and instant under test.
    """

    def __init__(self, dt: float = 0.0, start: float = 0.0):
        self.now = start
        self.dt = dt
        self.slept: float = 0.0

    def __call__(self) -> float:
        t = self.now
        self.now += self.dt
        return t

    def sleep(self, seconds: float) -> None:
        self.now += seconds
        self.slept += seconds

    def advance(self, seconds: float) -> None:
        self.now += seconds
