"""Request-lifecycle state machine for the serving stack (DESIGN.md §9).

The scheduler's correctness contract (DESIGN.md §4) covers the happy path —
every request runs its full budget and the interleaving is invisible. Real
traffic is not the happy path: clients cancel and disconnect, deadlines
expire, queues flood, and a single poisoned row must not take the batch down.
This module gives every request an explicit, *validated* state machine::

    QUEUED ──► PREFILLING ──► DECODING ──► FINISHED
      │   │         ├──► CANCELLED        ├──► CANCELLED    (client cancel/disconnect)
      │   │         ├──► TIMED_OUT        ├──► TIMED_OUT    (TTFT or wall-clock deadline)
      │   │         └──► FAILED           └──► FAILED       (dispatch/NaN quarantine)
      │   └──► CANCELLED   (cancelled while queued)
      └──► SHED            (deadline-aware queue shedding)

plus a :class:`QueueFullError` raised at submit time when the bounded
admission queue is full (backpressure is a *loud reject with a reason*, never
unbounded growth). Terminal states are terminal — a second transition out of
them is a scheduler bug and raises :class:`TransitionError` immediately
rather than corrupting accounting.

Every record carries the timestamps the serving metrics need (submit, admit,
first token, finish, measured against the scheduler's injectable clock), so
TTFT/TPOT percentiles (:func:`latency_summary`) fall out of the same
bookkeeping that drives the state machine.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    FAILED = "failed"
    SHED = "shed"

    @property
    def terminal(self) -> bool:
        return self in _TERMINAL


_TERMINAL = {
    RequestState.FINISHED,
    RequestState.CANCELLED,
    RequestState.TIMED_OUT,
    RequestState.FAILED,
    RequestState.SHED,
}

# Allowed transitions. PREFILLING -> CANCELLED/TIMED_OUT exists for the
# chunked-prefill path (DESIGN.md §12): a long-prompt admission spans many
# scheduler steps, and cancels/deadlines land at the step boundaries between
# its chunks. Synchronous (unchunked) admission still can't observe them
# mid-prefill — it is one host call — so there they fire on either side.
_ALLOWED: Dict[RequestState, set] = {
    RequestState.QUEUED: {
        RequestState.PREFILLING,
        RequestState.CANCELLED,
        RequestState.SHED,
    },
    RequestState.PREFILLING: {
        RequestState.DECODING,
        RequestState.CANCELLED,
        RequestState.TIMED_OUT,
        RequestState.FAILED,
    },
    RequestState.DECODING: {
        RequestState.FINISHED,
        RequestState.CANCELLED,
        RequestState.TIMED_OUT,
        RequestState.FAILED,
    },
}


class TransitionError(RuntimeError):
    """An illegal lifecycle transition — always a scheduler bug, never data."""


class QueueFullError(RuntimeError):
    """Admission queue is at capacity; the request was NOT enqueued.

    Raised by ``Scheduler.submit`` (and surfaced as a rejection event by the
    async server) so backpressure is visible to the caller instead of
    manifesting as unbounded queue growth.
    """


@dataclasses.dataclass
class RequestLifecycle:
    """Per-request lifecycle record: validated state + latency timestamps.

    ``new_tokens`` is populated at every terminal transition with whatever
    the request emitted — the full completion for FINISHED, the partial
    prefix for CANCELLED/TIMED_OUT/FAILED (a failed request's partial tokens
    are still useful for debugging the failure), empty for SHED.
    """

    rid: int
    state: RequestState = RequestState.QUEUED
    reason: str = ""
    submitted_at: float = 0.0
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    n_tokens: int = 0
    new_tokens: Optional[np.ndarray] = None
    # prefix-cache / chunked-prefill stamps (DESIGN.md §12): prompt tokens
    # served from cached KV at admission, and how many prefill dispatches
    # the admission took (1 = whole-shot)
    prefix_hit_tokens: int = 0
    prefill_chunks: int = 0
    history: List[Tuple[RequestState, float]] = dataclasses.field(
        default_factory=list
    )

    def transition(self, new: RequestState, at: float, reason: str = "") -> None:
        allowed = _ALLOWED.get(self.state, set())
        if new not in allowed:
            raise TransitionError(
                f"request {self.rid}: illegal transition "
                f"{self.state.value} -> {new.value}"
                + (f" (from terminal state)" if self.state.terminal else "")
            )
        self.state = new
        self.history.append((new, at))
        if reason:
            self.reason = reason
        if new is RequestState.PREFILLING:
            self.admitted_at = at
        if new.terminal:
            self.finished_at = at

    @property
    def ttft(self) -> Optional[float]:
        """Submit -> first emitted token (chunk-boundary resolution)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def tpot(self) -> Optional[float]:
        """Mean time per output token after the first."""
        if self.first_token_at is None or self.finished_at is None:
            return None
        if self.n_tokens < 2:
            return None
        return (self.finished_at - self.first_token_at) / (self.n_tokens - 1)


def _pcts(values: List[Optional[float]]) -> Dict[str, Optional[float]]:
    """Percentile block over the *finite* values. Always a dict — an empty
    or all-excluded stream yields explicit nulls with ``n == 0`` (never
    ``None`` in place of the block, never a NaN percentile), so consumers
    can subscript unconditionally and nulls survive JSON round-trips.
    ``excluded`` counts what was dropped (None entries from requests that
    never produced the measurement, plus any non-finite values)."""
    finite = [v for v in values if v is not None and np.isfinite(v)]  # staticcheck: host-sync(latency stats over host floats)
    excluded = len(values) - len(finite)
    if not finite:
        return {
            "p50": None, "p95": None, "p99": None, "mean": None,
            "n": 0, "excluded": excluded,
        }
    v = np.asarray(finite, np.float64)  # staticcheck: host-sync(latency stats over host floats)
    return {
        "p50": float(np.percentile(v, 50)),  # staticcheck: host-sync(host stats)
        "p95": float(np.percentile(v, 95)),  # staticcheck: host-sync(host stats)
        "p99": float(np.percentile(v, 99)),  # staticcheck: host-sync(host stats)
        "mean": float(v.mean()),  # staticcheck: host-sync(host stats)
        "n": len(finite),
        "excluded": excluded,
    }


def latency_summary(records: Iterable[RequestLifecycle]) -> dict:
    """TTFT/TPOT p50/p95/p99 over finished requests + terminal-state counts.

    TTFT/TPOT are measured at chunk-boundary resolution (tokens become
    visible to the host when a decode chunk returns), so ``chunk=1`` gives
    exact per-token latencies and larger chunks overstate TTFT by at most
    one chunk's wall time — the same resolution a streaming client observes.

    Edge cases are explicit, never NaN: with zero finished requests the
    ``ttft_s``/``tpot_s`` blocks still exist with null percentiles and
    ``n == 0``; a single-token completion has no TPOT (``tpot_s`` counts it
    under ``excluded``); requests that never reached a first token are
    tallied in ``no_first_token`` instead of silently vanishing from the
    percentile population.
    """
    records = list(records)
    by_state: Dict[str, int] = {}
    for r in records:
        by_state[r.state.value] = by_state.get(r.state.value, 0) + 1
    fin = [r for r in records if r.state is RequestState.FINISHED]
    return {
        "requests": len(records),
        "by_state": by_state,
        "finished": len(fin),
        # terminal without ever emitting: cancelled/timed-out/failed before
        # the first chunk returned (a FINISHED request always has one)
        "no_first_token": sum(
            1
            for r in records
            if r.state.terminal and r.first_token_at is None
        ),
        "ttft_s": _pcts([r.ttft for r in fin]),
        "tpot_s": _pcts([r.tpot for r in fin]),
    }
