"""Inference engine: prefill/decode split with quantized weights (paper Fig. 13)
plus the continuous-batching serving layer (slot-based scheduler)."""

from repro.infer.engine import Engine
from repro.infer.scheduler import Completion, Request, Scheduler

__all__ = ["Engine", "Scheduler", "Request", "Completion"]
