"""Inference engine: prefill/decode split with quantized weights (paper Fig. 13)."""

from repro.infer.engine import Engine

__all__ = ["Engine"]
