"""Inference engine: prefill/decode split with quantized weights (paper Fig. 13)
plus the continuous-batching serving layer (slot-based scheduler) and
self-speculative decoding from nested BCQ precisions (DESIGN.md §5)."""

from repro.infer.engine import Engine
from repro.infer.scheduler import Completion, Request, Scheduler
from repro.infer.speculative import SpecConfig

__all__ = ["Engine", "Scheduler", "Request", "Completion", "SpecConfig"]
