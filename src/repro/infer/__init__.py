"""Inference engine: prefill/decode split with quantized weights (paper Fig. 13)
plus the continuous-batching serving layer (slot-based scheduler),
self-speculative decoding from nested BCQ precisions (DESIGN.md §5), and the
request-lifecycle robustness layer (DESIGN.md §9): per-request state machine,
cancellation/deadlines/backpressure, NaN quarantine, fault injection; plus
the prefix-cache KV-reuse + chunked-prefill subsystem (DESIGN.md §12)."""

from repro.infer.engine import Engine, PendingAdmission
from repro.infer.faults import FaultPlan, InjectedFault, StepClock
from repro.infer.lifecycle import (
    QueueFullError,
    RequestLifecycle,
    RequestState,
    TransitionError,
    latency_summary,
)
from repro.infer.prefix_cache import PrefixCache, PrefixHandle, model_identity
from repro.infer.scheduler import (
    Completion,
    DispatchError,
    Request,
    Scheduler,
)
from repro.infer.speculative import SpecConfig

__all__ = [
    "Engine",
    "PendingAdmission",
    "PrefixCache",
    "PrefixHandle",
    "model_identity",
    "Scheduler",
    "Request",
    "Completion",
    "SpecConfig",
    "RequestState",
    "RequestLifecycle",
    "QueueFullError",
    "TransitionError",
    "DispatchError",
    "FaultPlan",
    "InjectedFault",
    "StepClock",
    "latency_summary",
]
