"""Async streaming serve front end (DESIGN.md §9, ROADMAP open item 4).

``launch/serve.py`` is a synchronous driver; production traffic is concurrent
clients, streamed tokens, and tail-latency SLOs. This module serves the
continuous-batching scheduler to real clients:

- :class:`ServeSession` — the transport-agnostic core. One dedicated **pump
  thread** owns the :class:`~repro.infer.Scheduler` (JAX dispatches block, so
  they must stay off the event loop); the asyncio side talks to it through a
  thread-safe inbox (submits/cancels) and per-request ``asyncio.Queue``
  streams fed via ``loop.call_soon_threadsafe``. Tokens stream per-slot as
  chunks complete; terminal lifecycle events (finished / cancelled /
  timed-out / failed / shed) close the stream with a per-request status.
- an **aiohttp app** (:func:`make_app`) on top with two stream transports
  over the *same* session core and frame schema: a WebSocket endpoint
  (``/v1/stream``) and an HTTP SSE endpoint (``POST /v1/generate``, one
  ``data:`` line per frame — curl-able, no WS client needed). Both honour
  client disconnect as cancellation at the next chunk boundary and apply
  admission control under burst load (a full queue rejects loudly instead
  of buffering without bound). A ``/v1/metrics`` endpoint reports
  per-request TTFT/TPOT p50/p95/p99 as JSON (``?format=prometheus`` for the
  text exposition over the session's metrics registry + the process-global
  qmatmul dispatch counts), and a ``/v1/trace`` endpoint exports the
  session tracer's recent window as Chrome/Perfetto trace-event JSON
  (DESIGN.md §11).
  aiohttp is optional — the session core works without it (and is what the
  differential tests drive); ``make_app`` raises if it is missing.

Slow clients: each stream buffer is bounded (``max_buffer`` events). A client
that stops reading while the scheduler keeps emitting overflows its buffer
and is **cancelled with a reason** — one stalled consumer must not grow host
memory or, worse, backpressure the whole decode batch. (Deterministic stalls
are injectable via ``FaultPlan.client_stall`` for exactly this test.)

Run it::

    PYTHONPATH=src python -m repro.launch.server --arch llama3.2-3b \
        --q 4 --g 128 --slots 4 --port 8777

WebSocket protocol (``/v1/stream``, JSON frames)::

    -> {"prompt": [...], "max_new_tokens": 16, "temperature": 0.7,
        "seed": 1, "stop_tokens": [2], "deadline_s": 30.0}
    <- {"type": "accepted", "rid": 0}
    <- {"type": "tokens", "rid": 0, "tokens": [5, 17, ...]}   (per chunk)
    <- {"type": "done", "rid": 0, "status": "finished", "n_tokens": 16}
    or {"type": "error", "rid": 0, "status": "timed_out", "reason": "..."}
    or {"type": "rejected", "reason": "admission queue full (...)"}
    -> {"type": "cancel"}        (or just close the socket)

SSE protocol (``POST /v1/generate``, same JSON frames, one per ``data:``
line; closing the connection cancels the request)::

    curl -N -X POST http://HOST:PORT/v1/generate \
        -d '{"prompt": [1, 2, 3], "max_new_tokens": 16}'
    data: {"type": "accepted", "rid": 0}
    data: {"type": "tokens", "rid": 0, "tokens": [5, 17]}
    data: {"type": "done", "rid": 0, "status": "finished", "n_tokens": 16}
"""

from __future__ import annotations

import asyncio
import dataclasses
import itertools
import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.infer import (
    FaultPlan,
    QueueFullError,
    Request,
    RequestLifecycle,
    RequestState,
    Scheduler,
)
from repro.obs import MetricsRegistry, Tracer, default_registry, prometheus_text

try:  # aiohttp is optional: the session core must import without it
    from aiohttp import WSMsgType, web
except ImportError:  # pragma: no cover - exercised on minimal installs
    web = None
    WSMsgType = None


@dataclasses.dataclass
class StreamEvent:
    """One event on a request's stream. ``kind``: accepted | tokens | done |
    error | rejected. Terminal kinds (done/error/rejected) end the stream."""

    kind: str
    rid: int = -1
    tokens: Optional[List[int]] = None
    status: str = ""
    reason: str = ""
    n_tokens: int = 0

    @property
    def terminal(self) -> bool:
        return self.kind in ("done", "error", "rejected")

    def to_json(self) -> dict:
        d = {"type": self.kind, "rid": self.rid}
        if self.tokens is not None:
            d["tokens"] = self.tokens
        if self.status:
            d["status"] = self.status
        if self.reason:
            d["reason"] = self.reason
        if self.kind == "done":
            d["n_tokens"] = self.n_tokens
        return d


class RequestStream:
    """Async view of one in-flight request: iterate to receive events until a
    terminal one; ``cancel()`` flags host-side cancellation (applied at the
    next chunk boundary)."""

    def __init__(self, rid: int, queue: "asyncio.Queue[StreamEvent]",
                 session: "ServeSession"):
        self.rid = rid
        self._queue = queue
        self._session = session
        self._done = False

    def __aiter__(self):
        return self

    async def __anext__(self) -> StreamEvent:
        if self._done:
            raise StopAsyncIteration
        ev = await self._queue.get()
        if ev.terminal:
            self._done = True
        return ev

    def cancel(self, reason: str = "cancelled by client") -> None:
        self._session.cancel(self.rid, reason)

    async def drain(self) -> Tuple[List[int], StreamEvent]:
        """Collect the whole stream: (all tokens, terminal event)."""
        toks: List[int] = []
        last = StreamEvent(kind="error", rid=self.rid, reason="stream ended")
        async for ev in self:
            if ev.kind == "tokens" and ev.tokens:
                toks.extend(ev.tokens)
            last = ev
        return toks, last


class ServeSession:
    """Pump a Scheduler off-thread and expose async per-request streams.

    The scheduler is single-threaded by contract; every mutation (submit,
    step, cancel application) happens on the pump thread. The asyncio side
    only appends to a thread-safe inbox and reads from per-request queues.
    """

    def __init__(
        self,
        engine,
        *,
        n_slots: int = 4,
        chunk: int = 8,
        speculate=None,
        prefill_chunk: Optional[int] = None,
        max_queue: Optional[int] = 64,
        max_buffer: int = 1024,
        nan_guard: bool = True,
        faults: Optional[FaultPlan] = None,
        idle_wait_s: float = 0.005,
        tracer: Optional[Tracer] = None,
        metrics: Optional[MetricsRegistry] = None,
        observe: bool = True,
    ):
        """``tracer``/``metrics`` default to fresh per-session instances
        (``observe=False`` turns both off unless passed explicitly): a
        serving session should always be able to answer ``/v1/trace`` and
        ``/v1/metrics`` — the observability layer is host-side-only and
        never perturbs tokens (tests/test_obs.py), so on-by-default is
        safe. Pass a shared registry/tracer to aggregate across sessions.
        ``prefill_chunk`` enables chunked prefill on the scheduler
        (DESIGN.md §12) — long-prompt admissions then interleave with
        decode instead of stalling it."""
        self._engine = engine
        self._faults = faults
        self._max_buffer = max_buffer
        self._idle_wait_s = idle_wait_s
        if observe:
            tracer = Tracer() if tracer is None else tracer
            metrics = MetricsRegistry() if metrics is None else metrics
        self.tracer = tracer
        self.registry = metrics
        self.sched = Scheduler(
            engine,
            n_slots=n_slots,
            chunk=chunk,
            speculate=speculate,
            prefill_chunk=prefill_chunk,
            max_queue=max_queue,
            nan_guard=nan_guard,
            faults=faults,
            on_tokens=self._on_tokens,
            on_event=self._on_event,
            tracer=tracer,
            metrics=metrics,
        )
        self._inbox: deque = deque()  # ("submit", req) | ("cancel", rid, reason)
        self._wake = threading.Event()
        self._stop_flag = False
        self._thread: Optional[threading.Thread] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        # rid -> (asyncio queue, overflowed flag holder)
        self._streams: Dict[int, "asyncio.Queue[StreamEvent]"] = {}
        self._rids = itertools.count()
        self.counters = {"overflow_cancelled": 0, "rejected": 0}
        if self.registry is not None:
            for key in self.counters:
                self.registry.counter(
                    f"server_{key}_total", f"server-side events: {key}"
                )

    def _count(self, key: str, n: int = 1) -> None:
        """Server-side counter increments, mirrored into the registry as
        ``server_<key>_total`` (same lockstep contract as the scheduler)."""
        self.counters[key] += n
        if self.registry is not None:
            self.registry.counter(f"server_{key}_total").inc(n)

    # -- lifecycle of the session itself -------------------------------------

    async def __aenter__(self) -> "ServeSession":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def start(self) -> None:
        if self._thread is not None:
            raise RuntimeError("session already started")
        self._loop = asyncio.get_running_loop()
        self._thread = threading.Thread(
            target=self._pump, name="repro-serve-pump", daemon=True
        )
        self._thread.start()

    async def stop(self, drain: bool = False) -> None:
        """Stop the pump. ``drain=True`` serves out everything in flight
        first; otherwise in-flight requests are cancelled at the next chunk
        boundary (their streams receive a terminal event either way)."""
        if self._thread is None:
            return
        if drain:
            while not self.sched.idle or self._inbox:
                await asyncio.sleep(self._idle_wait_s)
        self._stop_flag = True
        self._wake.set()
        await asyncio.get_running_loop().run_in_executor(
            None, self._thread.join
        )
        self._thread = None

    # -- async client API -----------------------------------------------------

    async def submit_stream(self, req: Request) -> RequestStream:
        """Submit a request; returns its stream. Admission happens on the
        pump thread — a full queue surfaces as a terminal ``rejected`` event
        on the stream (never an unbounded enqueue)."""
        if self._loop is None:
            raise RuntimeError("session not started")
        rid = req.rid if req.rid is not None else next(self._rids)
        req.rid = rid
        q: "asyncio.Queue[StreamEvent]" = asyncio.Queue()
        self._streams[rid] = q
        self._inbox.append(("submit", req))
        self._wake.set()
        return RequestStream(rid, q, self)

    def cancel(self, rid: int, reason: str = "cancelled by client") -> None:
        self._inbox.append(("cancel", rid, reason))
        self._wake.set()

    def metrics(self) -> dict:
        """Scheduler lifecycle/latency summary + server-side counters.
        Snapshot read across threads: dict/int reads are atomic under the
        GIL, and the records it summarises are terminal (immutable). With a
        registry attached, its full snapshot (queue depth, slot occupancy,
        speculative acceptance, histograms, ...) rides along under
        ``registry`` and the tracer's ring stats under ``tracer``."""
        out = self.sched.summary()
        out["server"] = dict(self.counters)
        if self.registry is not None:
            out["registry"] = self.registry.snapshot()
        if self.tracer is not None:
            out["tracer"] = self.tracer.stats()
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition over the session registry plus the
        process-global default registry (per-format qmatmul dispatch counts),
        deduplicated when they are the same object."""
        if self.registry is None:
            raise RuntimeError("session has no metrics registry (observe=False)")
        regs = [self.registry]
        if self.registry is not default_registry():
            regs.append(default_registry())
        return prometheus_text(*regs)

    def trace_json(self) -> dict:
        """The session tracer's buffered window as a Chrome trace object."""
        if self.tracer is None:
            raise RuntimeError("session has no tracer (observe=False)")
        return self.tracer.to_chrome()

    # -- pump thread ----------------------------------------------------------

    def _post(self, rid: int, ev: StreamEvent) -> None:
        """Pump thread -> event loop: deliver one event to a stream, applying
        the bounded-buffer slow-client policy."""
        q = self._streams.get(rid)
        if q is None or self._loop is None:
            return
        if ev.terminal:
            self._streams.pop(rid, None)
        elif q.qsize() >= self._max_buffer:
            # slow client: its buffer is full. Cancel the request rather than
            # grow host memory; the terminal event will still be delivered
            # (terminal events bypass the bound — the stream is closing).
            self._count("overflow_cancelled")
            if self.tracer is not None:
                self.tracer.instant(
                    "overflow_cancel", cat="server", lane="pump",
                    args={"rid": rid, "max_buffer": self._max_buffer},
                )
            self.sched.cancel(
                rid,
                f"slow client: stream buffer overflowed ({self._max_buffer} "
                f"events unread)",
            )
            return
        self._loop.call_soon_threadsafe(q.put_nowait, ev)

    def _on_tokens(self, rid: int, tokens: List[int]) -> None:
        if self._faults is not None:
            stall = self._faults.stall_for(rid)
            if stall > 0:
                time.sleep(stall)  # injected slow consumer (pump-side stall)
        self._post(rid, StreamEvent(kind="tokens", rid=rid, tokens=tokens))

    def _on_event(self, rec: RequestLifecycle) -> None:
        if rec.state is RequestState.FINISHED:
            ev = StreamEvent(
                kind="done", rid=rec.rid, status=rec.state.value,
                reason=rec.reason, n_tokens=rec.n_tokens,
            )
        else:
            ev = StreamEvent(
                kind="error", rid=rec.rid, status=rec.state.value,
                reason=rec.reason,
            )
        self._post(rec.rid, ev)

    def _drain_inbox(self) -> int:
        n = 0
        while self._inbox:
            item = self._inbox.popleft()
            n += 1
            if item[0] == "submit":
                req = item[1]
                try:
                    self.sched.submit(req)
                    self._post(req.rid, StreamEvent(kind="accepted", rid=req.rid))
                except QueueFullError as e:
                    self._count("rejected")
                    self._post(
                        req.rid,
                        StreamEvent(kind="rejected", rid=req.rid, reason=str(e)),
                    )
                except (ValueError, OverflowError) as e:
                    # invalid request (too long for the cache, bad token ids):
                    # reject on the stream instead of killing the pump
                    self._post(
                        req.rid,
                        StreamEvent(kind="rejected", rid=req.rid, reason=str(e)),
                    )
            else:
                _, rid, reason = item
                self.sched.cancel(rid, reason)
        return n

    def _pump(self) -> None:
        tr = self.tracer
        if tr is not None:
            tr.instant("pump_start", cat="server", lane="pump")
        while True:
            if self._inbox and tr is not None:
                # span only when there is work: the idle poll must not fill
                # the ring with empty drains
                with tr.span("drain_inbox", cat="server", lane="pump") as sp:
                    drained = self._drain_inbox()
                    sp.annotate(items=drained)
            else:
                drained = self._drain_inbox()
            if self._stop_flag:
                break
            if self.sched.idle and not drained:
                self._wake.wait(timeout=self._idle_wait_s)
                self._wake.clear()
                continue
            self.sched.step()
        # shutdown: everything still queued or decoding is cancelled so no
        # stream is left hanging without a terminal event
        for rid, rec in list(self.sched.outcomes.items()):
            if not rec.state.terminal:
                self.sched.cancel(rid, "server shutting down")
        self.sched.step()
        if tr is not None:
            tr.instant("pump_stop", cat="server", lane="pump")


# -- aiohttp transport --------------------------------------------------------


def _require_aiohttp() -> None:
    if web is None:
        raise RuntimeError(
            "the websocket front end needs aiohttp (pip install aiohttp); "
            "the ServeSession core works without it"
        )


def request_from_json(msg: dict) -> Request:
    """Build a Request from one client JSON frame (validation happens in
    Request.__post_init__ / Scheduler.submit and surfaces as a rejection)."""
    return Request(
        # no dtype coercion: a JSON list of ints arrives as an integer array,
        # and float token ids must hit Request's loud dtype validation
        # instead of being silently truncated here
        prompt=np.asarray(msg["prompt"]),
        max_new_tokens=int(msg.get("max_new_tokens", 16)),
        temperature=float(msg.get("temperature", 0.0)),
        seed=msg.get("seed", 0),
        stop_tokens=msg.get("stop_tokens"),
        ttft_deadline_s=msg.get("ttft_deadline_s"),
        deadline_s=msg.get("deadline_s"),
        speculate=msg.get("speculate"),
    )


def make_app(session: ServeSession) -> "web.Application":
    """The aiohttp app: WS streaming + health + metrics."""
    _require_aiohttp()

    async def healthz(_request):
        return web.json_response({"ok": True})

    async def metrics(request):
        # ?format=prometheus (or an Accept header naming the exposition
        # content type) switches to Prometheus text; default stays the JSON
        # summary existing consumers parse
        fmt = request.query.get("format", "")
        accept = request.headers.get("Accept", "")
        if fmt == "prometheus" or "application/openmetrics-text" in accept:
            if session.registry is None:
                return web.json_response(
                    {"error": "session has no metrics registry"}, status=501
                )
            return web.Response(
                text=session.prometheus(),
                content_type="text/plain",
                charset="utf-8",
            )
        return web.json_response(session.metrics())

    async def trace(_request):
        if session.tracer is None:
            return web.json_response(
                {"error": "session has no tracer"}, status=501
            )
        return web.json_response(session.trace_json())

    async def stream(request):
        ws = web.WebSocketResponse()
        await ws.prepare(request)
        try:
            msg = await ws.receive_json()
            req = request_from_json(msg)
        except (KeyError, TypeError, ValueError) as e:
            await ws.send_json(
                {"type": "rejected", "reason": f"bad request: {e!r}"}
            )
            await ws.close()
            return ws
        stream = await session.submit_stream(req)

        async def watch_client():
            # a close/cancel frame — or the socket dropping — cancels the
            # request at the next chunk boundary (disconnect-as-cancel)
            async for m in ws:
                if m.type == WSMsgType.TEXT:
                    try:
                        frame = m.json()
                    except ValueError:
                        continue
                    if frame.get("type") == "cancel":
                        stream.cancel("cancel frame from client")
            stream.cancel("client disconnected")

        watcher = asyncio.ensure_future(watch_client())
        try:
            async for ev in stream:
                if ws.closed:
                    stream.cancel("client disconnected")
                    break
                try:
                    await ws.send_json(ev.to_json())
                except (ConnectionResetError, RuntimeError):
                    stream.cancel("client disconnected")
                    break
        finally:
            watcher.cancel()
            if not ws.closed:
                await ws.close()
        return ws

    async def generate(request):
        # SSE transport: same session core and frame schema as the WS
        # endpoint, but over plain HTTP — one JSON frame per ``data:`` line.
        try:
            msg = await request.json()
            req = request_from_json(msg)
        except (KeyError, TypeError, ValueError) as e:
            return web.json_response(
                {"type": "rejected", "reason": f"bad request: {e!r}"},
                status=400,
            )
        stream = await session.submit_stream(req)
        resp = web.StreamResponse(
            headers={
                "Content-Type": "text/event-stream",
                "Cache-Control": "no-cache",
                "X-Accel-Buffering": "no",
            }
        )
        await resp.prepare(request)
        try:
            async for ev in stream:
                frame = f"data: {json.dumps(ev.to_json())}\n\n"
                try:
                    await resp.write(frame.encode("utf-8"))
                except (ConnectionResetError, RuntimeError):
                    stream.cancel("client disconnected")
                    break
        except asyncio.CancelledError:
            # aiohttp cancels the handler when the peer drops mid-stream:
            # disconnect-as-cancel, same contract as the WS endpoint
            stream.cancel("client disconnected")
            raise
        try:
            await resp.write_eof()
        except (ConnectionResetError, RuntimeError):
            pass
        return resp

    app = web.Application()
    app.router.add_get("/healthz", healthz)
    app.router.add_get("/v1/metrics", metrics)
    app.router.add_get("/v1/trace", trace)
    app.router.add_get("/v1/stream", stream)
    app.router.add_post("/v1/generate", generate)
    return app


async def run_server(
    session: ServeSession, host: str = "127.0.0.1", port: int = 8777
) -> "web.AppRunner":
    """Start the app on (host, port); returns the runner (cleanup() to stop).
    port=0 binds an ephemeral port — read it back from the runner for tests."""
    _require_aiohttp()
    app = make_app(session)
    runner = web.AppRunner(app)
    await runner.setup()
    site = web.TCPSite(runner, host, port)
    await site.start()
    return runner


def bound_port(runner: "web.AppRunner") -> int:
    for site in runner.sites:
        server = site._server  # noqa: SLF001 - aiohttp exposes no public port
        if server and server.sockets:
            return server.sockets[0].getsockname()[1]
    raise RuntimeError("server has no bound socket")


# -- CLI ----------------------------------------------------------------------


def main() -> None:  # pragma: no cover - CLI wrapper over tested pieces
    import argparse

    import jax

    from repro.configs import ARCH_IDS, get_config
    from repro.infer import SpecConfig
    from repro.models import init_params, reduced
    from repro.quant import QuantPolicy, quantize_params

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-3b")
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--g", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--speculate", type=str, default=None, metavar="QD:GAMMA")
    ap.add_argument("--prefix-cache-mb", type=int, default=0,
                    help="KV prefix-cache budget in MiB (0 disables)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill token budget per step (0 = whole-shot)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8777)
    args = ap.parse_args()
    _require_aiohttp()

    spec = SpecConfig.parse(args.speculate) if args.speculate else None
    cfg = reduced(get_config(args.arch), d_model=256, n_kv_heads=4,
                  d_ff=512 if get_config(args.arch).d_ff else 0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.q:
        params = quantize_params(params, QuantPolicy(q=args.q, g=args.g, iters=4))
    engine_max_seq = args.max_seq + (spec.gamma + 1 if spec else 0)
    from repro.infer import Engine, PrefixCache

    pc = (
        PrefixCache(max_bytes=args.prefix_cache_mb << 20)
        if args.prefix_cache_mb > 0
        else None
    )
    engine = Engine(cfg, params, max_seq=engine_max_seq, prefix_cache=pc)

    async def serve():
        session = ServeSession(
            engine, n_slots=args.slots, chunk=args.chunk, speculate=spec,
            prefill_chunk=args.prefill_chunk or None,
            max_queue=args.max_queue,
        )
        async with session:
            runner = await run_server(session, args.host, args.port)
            print(f"serving {args.arch} (q={args.q}) on "
                  f"ws://{args.host}:{bound_port(runner)}/v1/stream "
                  f"({args.slots} slots, chunk={args.chunk}, "
                  f"prefill_chunk={args.prefill_chunk or 'off'}, "
                  f"prefix_cache={args.prefix_cache_mb}MiB, "
                  f"max_queue={args.max_queue})")
            try:
                while True:
                    await asyncio.sleep(3600)
            finally:
                await runner.cleanup()

    asyncio.run(serve())


if __name__ == "__main__":
    main()
