"""Pre-jax-import host device forcing for tensor-parallel CLI entry points.

The host (CPU) platform's device count is fixed the moment the jax backend
initialises, so ``--tp N`` launchers must set
``--xla_force_host_platform_device_count`` BEFORE their first jax import —
the same constraint ``launch/dryrun.py`` documents. This module is
deliberately jax-free so entry points can import it first.
"""

from __future__ import annotations

import os
import sys
from typing import List, Optional


def _peek_int_flag(argv: List[str], flag: str) -> Optional[int]:
    """Value of ``--flag N`` or ``--flag=N`` from raw argv, else None."""
    for i, tok in enumerate(argv):
        if tok == flag:
            try:
                return int(argv[i + 1])
            except (IndexError, ValueError):
                return None
        if tok.startswith(flag + "="):
            try:
                return int(tok.split("=", 1)[1])
            except ValueError:
                return None
    return None


def force_host_devices(n: int) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS unless
    a device count is already forced (an unrelated pre-existing XLA_FLAGS
    value is preserved, not clobbered). Call before the first jax import."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()


def force_host_devices_for_tp(argv: Optional[List[str]] = None) -> None:
    """Peek at ``--tp`` and force that many host devices if none are forced
    yet. Call before the first jax import; argparse re-validates later."""
    n = _peek_int_flag(sys.argv if argv is None else argv, "--tp")
    if n is not None and n > 1:
        force_host_devices(n)
