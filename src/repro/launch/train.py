"""Training launcher: the production loop on any assigned arch.

On real hardware this runs the full-size config under the production mesh; on
this CPU container it runs the reduced same-family config so the entire stack
(data → scan/remat step → checkpoint/resume → preemption) is exercised end to
end.

PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --steps 50
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data import MarkovCorpus, batch_iterator
from repro.models import init_params, reduced
from repro.train import adamw_init, make_train_step
from repro.train.loop import LoopConfig, PreemptionGuard, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-size", action="store_true",
                    help="use the published config (needs a real pod)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_size else reduced(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=args.lr, accum_steps=args.accum))
    corpus = MarkovCorpus(cfg.vocab, seed=0)
    emb = cfg.d_model if cfg.input_kind == "embeddings" else None
    batches = (
        {k: jnp.asarray(v) for k, v in b.items()}
        for b in batch_iterator(corpus, batch=args.batch, seq_len=args.seq,
                                embed_dim=emb)
    )
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=max(args.steps // 4, 1), log_every=10)
    train_loop(step, params, opt, batches, loop_cfg, guard=PreemptionGuard())


if __name__ == "__main__":
    main()
