"""Serving launcher: quantize + continuous-batching generation (paper §V
workload shape: many concurrent decode requests against one weight-resident
quantized model).

PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --q 4 --g 128 \
    --requests 12 --slots 4 --rate 8 --speculate 2:4

PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --q 4 --g 64 \
    --requests 12 --slots 4 --tp 4     # tensor-parallel serving (g must keep
                                       # (k/g) divisible by tp for the
                                       # row-parallel weights; the engine
                                       # errors loudly naming the leaf else)

Requests enter an admission queue and are continuously batched into a
``--slots``-wide decode batch (``repro.infer.Scheduler``): a request joins as
soon as a slot frees up, finishes on its own budget, and its tokens are
identical to a solo ``Engine.generate`` call (tests/test_scheduler.py).
``--rate`` simulates a Poisson arrival process (requests/s; 0 = all queued at
t=0). ``--sequential`` instead serves the same workload as one-shot scanned
``generate`` calls in arrival order — the PR 1 fast path, kept as the
baseline the scheduler is measured against (BENCH_serve.json).

``--format NAME`` picks the registered quantization format (DESIGN.md §2.4);
the choices come straight from the registry (``core/formats.py``), so a newly
registered format serves here with zero launcher changes: the paper's BCQ
(default), FineQuant-style group-wise uniform int-q, the dequantize-then-
matmul baseline the paper benchmarks against, FLUTE-style arbitrary-codebook,
and T-MAC-style ternary — all serve end-to-end through the identical
scheduler/engine stack, so format comparisons isolate the kernel pipeline.

``--speculate q_draft:gamma`` turns decode dispatches into self-speculative
chunks (DESIGN.md §5): a ``q_draft``-bit truncation of the same quantized
weights drafts ``gamma`` tokens per chunk and the full-precision model
verifies them in one batched forward — greedy output stays token-identical,
sampled output follows the exact target distribution, and the draft-acceptance
rate is reported alongside tok/s. Requests opt in per row (every CLI request
opts in). Needs a truncation-capable format (``supports_truncate`` in the
registry — ``bcq`` and ``ternary``); the launcher checks the capability flag,
not a name list.

``--tp N`` serves tensor-parallel (DESIGN.md §7): weights are placed
column/row-parallel over an N-way ``model`` mesh under ``shard_map``, KV
caches shard their kv-head dim, and greedy tokens stay identical to the
single-device engine. On a CPU host the launcher forces N placeholder
devices (the flag below must be set before jax initialises, hence the
pre-import peek — same constraint launch/dryrun.py documents).
"""

from __future__ import annotations

import argparse
import contextlib
import json
import time

from repro.launch._hostdev import force_host_devices_for_tp

if __name__ == "__main__":
    # CLI only (python -m repro.launch.serve): must run before the first jax
    # import. Library imports of this module (benchmarks pull build_requests)
    # must NOT sniff the host program's argv or mutate its XLA topology.
    force_host_devices_for_tp()

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.formats import format_names, get_format
from repro.data import MarkovCorpus
from repro.infer import Engine, Request, Scheduler, SpecConfig
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params, quantized_bytes


def build_requests(cfg, n, prompt_len, gen, *, mixed_temperature=True, seed=3,
                   shared_prefix_len=0):
    """``shared_prefix_len > 0`` gives every request the same leading tokens
    (a shared system prompt) followed by a per-request tail — the workload
    shape the prefix cache (DESIGN.md §12) exists for. The total prompt
    length stays ``prompt_len``."""
    corpus = MarkovCorpus(cfg.vocab, seed=seed)
    if shared_prefix_len >= prompt_len:
        raise ValueError(
            f"shared_prefix_len ({shared_prefix_len}) must leave at least one "
            f"per-request token (prompt_len={prompt_len})"
        )
    shared = corpus.sample(1, prompt_len, seed=99)[0, :shared_prefix_len]
    reqs = []
    for i in range(n):
        prompt = corpus.sample(1, prompt_len, seed=100 + i)[0, :prompt_len]
        if shared_prefix_len:
            prompt = np.concatenate([shared, prompt[shared_prefix_len:]])
        temp = [0.0, 1.0, 0.7][i % 3] if mixed_temperature else 0.0
        reqs.append(
            Request(
                prompt=prompt.astype(np.int32),
                max_new_tokens=gen,
                temperature=temp,
                seed=10 + i,
            )
        )
    return reqs


def poisson_arrivals(n, rate, seed=0):
    """Cumulative arrival offsets (seconds). rate<=0 → everything at t=0."""
    if rate <= 0:
        return np.zeros(n)
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def pareto_arrivals(n, rate, alpha=1.5, seed=0):
    """Heavy-tailed (Lomax / Pareto-II) interarrivals with the same *mean*
    rate as :func:`poisson_arrivals` but bursty clumps and long gaps — the
    tail regime where TTFT percentiles, queue bounds and deadline shedding
    actually matter (Poisson traffic barely exercises them). ``alpha`` is the
    tail index: smaller → heavier tail (alpha must be > 1 for a finite mean;
    the Lomax mean is scale/(alpha-1), so scale = (alpha-1)/rate)."""
    if rate <= 0:
        return np.zeros(n)
    if alpha <= 1:
        raise ValueError(f"alpha must be > 1 for a finite mean, got {alpha}")
    rng = np.random.default_rng(seed)
    scale = (alpha - 1.0) / rate
    return np.cumsum(rng.pareto(alpha, size=n) * scale)


def drive_continuous(engine, reqs, arrivals, *, n_slots, chunk, speculate=None,
                     prefill_chunk=None, tracer=None, metrics=None):
    """Wall-clock serve loop: submit each request at its arrival offset, step
    the scheduler whenever there is work. Returns (scheduler, completions,
    makespan_s) — the scheduler is handed back for utilisation stats.

    ``tracer``/``metrics`` (repro.obs) instrument the run: per-request
    lifecycle spans and the serving metric catalog (DESIGN.md §11).
    ``prefill_chunk`` enables chunked prefill (DESIGN.md §12)."""
    sched = Scheduler(engine, n_slots=n_slots, chunk=chunk, speculate=speculate,
                      prefill_chunk=prefill_chunk, tracer=tracer,
                      metrics=metrics)
    done = []
    t0 = time.perf_counter()
    i = 0
    while len(done) < len(reqs):
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            sched.submit(reqs[i])
            i += 1
        if sched.idle:
            # nothing in flight: sleep until the next arrival
            time.sleep(max(0.0, arrivals[i] - now))
            continue
        done.extend(sched.step())
    return sched, done, time.perf_counter() - t0


def drive_sequential(engine, reqs, arrivals):
    """Baseline: one-shot scanned `generate` per request, in arrival order."""
    t0 = time.perf_counter()
    outs = []
    for req, at in zip(reqs, arrivals):
        wait = at - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(wait)
        outs.append(
            engine.generate(
                req.prompt[None],
                req.max_new_tokens,
                temperature=req.temperature,
                seed=req.seed,
            )
        )
    return outs, time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-3b")
    ap.add_argument("--q", type=int, default=4,
                    help="quantization bits / code planes (0 = dense)")
    ap.add_argument("--g", type=int, default=128)
    ap.add_argument("--format", choices=format_names(), default="bcq",
                    help="registered quantization format (core/formats.py); "
                         "choices track the registry. 'bcq' is the paper's "
                         "LUT-GEMM format; truncation-capable formats "
                         "(supports_truncate) also serve --speculate")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode-batch width (concurrent requests)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per scheduler dispatch (admission "
                         "happens at chunk boundaries)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate in requests/s (0 = all at t=0)")
    ap.add_argument("--sequential", action="store_true",
                    help="serve with one-shot scanned generate calls instead "
                         "of the continuous-batching scheduler (baseline)")
    ap.add_argument("--speculate", type=str, default=None, metavar="QD:GAMMA",
                    help="self-speculative decode chunks from the nested "
                         "QD-bit draft, GAMMA proposals per chunk (e.g. 2:4); "
                         "requires --q > QD to actually speed anything up")
    ap.add_argument("--prefix-cache-mb", type=int, default=0,
                    help="KV prefix-cache budget in MiB (DESIGN.md §12): "
                         "committed prompt prefixes are reused across "
                         "requests under ref-counted LRU eviction (0 = off)")
    ap.add_argument("--prefix-block", type=int, default=16,
                    help="prefix-cache block granularity in tokens: prefixes "
                         "match and commit in whole blocks, so a shared "
                         "system prompt shorter than one block never hits")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill token budget per scheduler step "
                         "(DESIGN.md §12): long prompts prefill in bucketed "
                         "chunks interleaved with decode (0 = whole-shot)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="give every request the same leading tokens (shared "
                         "system prompt) — the prefix-cache workload shape")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard weights/KV over an "
                         "N-way model mesh under shard_map (greedy tokens "
                         "identical to --tp 1; CPU hosts get N forced "
                         "placeholder devices)")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="instrument the serve loop with the repro.obs span "
                         "tracer + metrics registry and write a Chrome/"
                         "Perfetto trace-event JSON here (open in "
                         "ui.perfetto.dev); prints the metric snapshot too. "
                         "Host-side spans only — see --profile-dir for "
                         "device timelines")
    ap.add_argument("--profile-dir", type=str, default=None, metavar="DIR",
                    help="opt-in jax.profiler capture: wrap the serve loop "
                         "in jax.profiler.trace(DIR), recording XLA device "
                         "timelines (plus the engine's TraceAnnotation "
                         "scopes) for TensorBoard/Perfetto. Off by default — "
                         "profiling is never free")
    args = ap.parse_args()
    if args.tp < 1:
        ap.error("--tp must be >= 1")
    spec = None
    if args.speculate:
        try:
            spec = SpecConfig.parse(args.speculate)
        except ValueError as e:
            ap.error(f"--speculate: {e}")
    if spec and not args.q:
        ap.error("--speculate requires a quantized model (--q > 0)")
    if spec and not get_format(args.format).supports_truncate:
        capable = [n for n in format_names() if get_format(n).supports_truncate]
        ap.error(f"--speculate needs a truncation-capable format; "
                 f"{args.format!r} has no nested low-bit draft "
                 f"(truncation-capable formats: {', '.join(capable)})")
    if spec and args.sequential:
        ap.error("--speculate drives the continuous-batching scheduler; "
                 "it cannot be combined with --sequential")
    if args.sequential and (args.prefix_cache_mb or args.prefill_chunk):
        ap.error("--prefix-cache-mb/--prefill-chunk drive the scheduler; "
                 "they cannot be combined with --sequential")

    # reduced config sized so quantization actually bites (>=128-dim linears)
    cfg = reduced(get_config(args.arch), d_model=256, n_kv_heads=4,
                  d_ff=512 if get_config(args.arch).d_ff else 0,
                  moe_d_ff=256 if get_config(args.arch).n_experts else None)
    if cfg.input_kind != "tokens":
        ap.error(f"{args.arch} is a modality-stub arch; see examples/ for the "
                 "embedding-input serving path")
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"dense bytes: {quantized_bytes(params)/2**20:.2f} MiB")
    if args.q:
        params = quantize_params(
            params, QuantPolicy(q=args.q, g=args.g, iters=4, fmt=args.format)
        )
        print(f"{args.format} q={args.q} g={args.g}: "
              f"{quantized_bytes(params)/2**20:.2f} MiB")

    mesh = None
    if args.tp > 1:
        from repro.parallel.tp import make_tp_mesh

        mesh = make_tp_mesh(args.tp)
        print(f"tensor-parallel: {args.tp}-way model mesh over "
              f"{[str(d) for d in mesh.devices.flat]}")

    tracer = registry = None
    if args.trace_out:
        from repro.obs import MetricsRegistry, Tracer

        tracer, registry = Tracer(), MetricsRegistry()

    profile_cm = contextlib.nullcontext()
    if args.profile_dir:
        profile_cm = jax.profiler.trace(args.profile_dir)
        print(f"jax.profiler capture -> {args.profile_dir}")

    headroom = (spec.gamma + 1) if spec else 0
    prefix_cache = None
    if args.prefix_cache_mb > 0:
        from repro.infer import PrefixCache

        prefix_cache = PrefixCache(
            block_tokens=args.prefix_block,
            max_bytes=args.prefix_cache_mb << 20,
        )
    engine = Engine(cfg, params, mesh=mesh,
                    max_seq=args.prompt_len + args.gen + 8 + headroom,
                    tracer=tracer, prefix_cache=prefix_cache)
    del params  # the engine holds the fused layout; free the unfused tree
    reqs = build_requests(cfg, args.requests, args.prompt_len, args.gen,
                          shared_prefix_len=args.shared_prefix_len)
    arrivals = poisson_arrivals(args.requests, args.rate, seed=1)
    total_new = sum(r.max_new_tokens for r in reqs)

    if args.sequential:
        with profile_cm:
            outs, dt = drive_sequential(engine, reqs, arrivals)
        print(f"[sequential] {len(outs)} requests, {total_new} tokens in "
              f"{dt:.2f}s ({total_new/dt:.1f} tok/s on this host)")
        print("sample:", outs[0].tokens[0, args.prompt_len:])
    else:
        with profile_cm:
            sched, done, dt = drive_continuous(
                engine, reqs, arrivals, n_slots=args.slots, chunk=args.chunk,
                speculate=spec, prefill_chunk=args.prefill_chunk or None,
                tracer=tracer, metrics=registry,
            )
        util = sched.steps_active / max(1, sched.decode_steps * sched.n_slots)
        tag = "continuous"
        extra = ""
        if spec:
            # steps_active counts emitted tokens in spec mode; occupancy is
            # dispatched row-chunks over capacity
            util = sched.chunk_rows / max(1, sched.decode_steps * sched.n_slots)
            tag = f"speculative q'={spec.q_draft} γ={spec.gamma}"
            extra = f", draft acceptance ~{sched.spec_accept_rate:.0%}"
        print(f"[{tag}] {len(done)} requests, {total_new} tokens in "
              f"{dt:.2f}s ({total_new/dt:.1f} tok/s on this host, "
              f"{args.slots} slots, chunk={args.chunk}, "
              f"slot utilisation {util:.0%}{extra})")
        if prefix_cache is not None:
            st = prefix_cache.stats()
            print(f"prefix cache: {st['hits']} hits / {st['misses']} misses, "
                  f"{st['commits']} commits, {st['evictions']} evictions, "
                  f"{st['cached_bytes']/2**20:.2f} MiB cached "
                  f"({st['nodes']} blocks)")
        print("sample:", done[0].new_tokens)

    if tracer is not None:
        with open(args.trace_out, "w") as f:
            json.dump(tracer.to_chrome(), f)
        st = tracer.stats()
        print(f"trace: {args.trace_out} ({st['buffered']} events, "
              f"{st['evicted']} evicted) — open in ui.perfetto.dev")
        if registry is not None and not args.sequential:
            counters = {
                name: sum(s["value"] for s in fam["series"])
                for name, fam in registry.snapshot().items()
                if fam["type"] == "counter"
            }
            print("metrics:", json.dumps(counters, sort_keys=True))


if __name__ == "__main__":
    main()
