"""Serving launcher: quantize + batched generation (paper Fig. 13 pipeline).

PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --q 4 --g 128

Decode runs the scanned fast path by default (``Engine.generate(scan=True)``:
one ``lax.scan`` dispatch for all generated tokens, on-device sampling, fused
QKV/gate-up projection kernels — DESIGN.md §2.3/§3). ``--no-scan`` forces the
per-token step loop, e.g. to measure the dispatch overhead it removes.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data import MarkovCorpus
from repro.infer import Engine
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params, quantized_bytes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-3b")
    ap.add_argument("--q", type=int, default=4, help="BCQ bits (0 = dense)")
    ap.add_argument("--g", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--no-scan", action="store_true",
                    help="per-token step loop instead of the scanned decode")
    args = ap.parse_args()

    # reduced config sized so quantization actually bites (>=128-dim linears)
    cfg = reduced(get_config(args.arch), d_model=256, n_kv_heads=4,
                  d_ff=512 if get_config(args.arch).d_ff else 0,
                  moe_d_ff=256 if get_config(args.arch).n_experts else None)
    if cfg.input_kind != "tokens":
        ap.error(f"{args.arch} is a modality-stub arch; see examples/ for the "
                 "embedding-input serving path")
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"dense bytes: {quantized_bytes(params)/2**20:.2f} MiB")
    if args.q:
        params = quantize_params(params, QuantPolicy(q=args.q, g=args.g, iters=4))
        print(f"BCQ q={args.q} g={args.g}: {quantized_bytes(params)/2**20:.2f} MiB")

    corpus = MarkovCorpus(cfg.vocab, seed=3)
    prompts = corpus.sample(args.batch, args.prompt_len, seed=7)
    prompts = prompts[:, : args.prompt_len].astype(np.int32)
    eng = Engine(cfg, params, max_seq=args.prompt_len + args.gen + 8)
    del params  # the engine holds the fused layout; free the unfused tree
    t0 = time.perf_counter()
    res = eng.generate(prompts, args.gen, scan=not args.no_scan)
    dt = time.perf_counter() - t0
    toks = args.batch * args.gen
    mode = "step-loop" if args.no_scan else "scanned"
    print(f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s on this host, {mode} decode)")
    print("sample:", res.tokens[0, args.prompt_len :])


if __name__ == "__main__":
    main()
