"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init.
"""

from __future__ import annotations

import contextlib

import jax

from repro.parallel.sharding import MeshAxes, multi_pod_axes, single_pod_axes


@contextlib.contextmanager
def set_mesh(mesh):
    """Activate ``mesh`` as the ambient mesh, across JAX generations.

    Newest JAX spells this ``jax.set_mesh``; before that ``jax.sharding
    .use_mesh``; older releases enter the ``Mesh`` object itself as a context
    manager (which populates the thread-local resource env that
    :func:`repro.parallel.compat.get_abstract_mesh` reads back). All mesh
    activation in this repo goes through here — never call the jax API
    directly.
    """
    if hasattr(jax, "set_mesh"):
        ctx = jax.set_mesh(mesh)
    elif hasattr(jax.sharding, "use_mesh"):
        ctx = jax.sharding.use_mesh(mesh)
    else:
        ctx = mesh
    with ctx:
        yield mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_axes(*, multi_pod: bool = False) -> MeshAxes:
    return multi_pod_axes(2, 16, 16) if multi_pod else single_pod_axes(16, 16)


def make_mesh_from_axes(ax: MeshAxes):
    names = tuple(n for n, _ in ax.sizes)
    shape = tuple(s for _, s in ax.sizes)
    return jax.make_mesh(shape, names)
