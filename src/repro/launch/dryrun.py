import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input-shape × mesh) cell:
``jax.jit(step, in_shardings, out_shardings).lower(*structs).compile()`` on the
production mesh built from 512 host placeholder devices, then record
``memory_analysis()`` / ``cost_analysis()`` / HLO collective bytes for the
roofline (deliverable g).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all          # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Results land in experiments/dryrun/<arch>__<shape>__<mesh>__<quant>.json and
existing cells are skipped (resumable sweep).
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.analysis.hlo import collective_bytes, total_collective_bytes
from repro.analysis.hlo_cost import analyze as hlo_analyze, normalize_cost_analysis
from repro.analysis.roofline import model_flops_estimate, roofline
from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh, production_axes, set_mesh
from repro.models import init_cache, init_params
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.parallel import batch_specs, cache_specs, param_specs
from repro.parallel.sharding import MeshAxes, logits_spec, qt_specs_like
from repro.quant import QuantPolicy, quantized_structs
from repro.train.optimizer import adamw_init
from repro.train.step import make_prefill_step, make_serve_step, make_train_step
from repro.core.qtensor import QuantizedTensor

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def batch_structs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    out = {}
    if cfg.input_kind == "tokens":
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    else:
        out["embeddings"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)
        out["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.family == "vlm":
        out["image_emb"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16
        )
    return out


def param_structs(cfg: ModelConfig, quant: Optional[QuantPolicy]):
    structs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if quant is not None:
        structs = quantized_structs(structs, quant)
    return structs


def _spec_tree_for(structs, dense_specs, ax: MeshAxes):
    """Match the (possibly quantized) struct tree with PartitionSpecs."""

    def visit(struct, spec):
        if isinstance(struct, QuantizedTensor):
            return qt_specs_like(spec, struct, ax)
        return spec

    return jax.tree.map(
        visit,
        structs,
        dense_specs,
        is_leaf=lambda x: isinstance(x, QuantizedTensor),
    )


def input_specs(
    arch: str, shape: str, quant_q: int = 0, dp_size: int = 16, kv_quant: bool = False
):
    """→ (step_fn, arg_structs, in_specs, out_specs, meta) for one cell.

    ``quant_q``: 0 = dense bf16; 2/4 = group-wise BCQ with g=128 on serve paths
    (paper Fig. 13: prefill dequantizes, decode consumes packed — on TPU via
    the Pallas kernels, in this CPU lowering via the jnp reference path).
    """
    cfg = get_config(arch)
    if kv_quant:
        import dataclasses as _dc

        cfg = _dc.replace(cfg, kv_cache_dtype="int8", stages=None)
    sc: ShapeConfig = SHAPES[shape]
    if sc.name == "long_500k" and not cfg.supports_long_context:
        raise SkipCell(f"{arch} is pure full-attention; long_500k skipped (DESIGN.md §6)")

    policy = QuantPolicy(q=quant_q, g=128) if quant_q else None
    p_structs = param_structs(cfg, policy)

    if sc.kind == "train":
        accum = max(1, min(16, sc.global_batch // dp_size))
        while sc.global_batch % accum or (sc.global_batch // accum) % dp_size:
            accum -= 1
        step = make_train_step(cfg, remat=True, accum_steps=accum)
        opt_structs = jax.eval_shape(adamw_init, p_structs)
        b_structs = batch_structs(cfg, sc.global_batch, sc.seq_len)
        args = (p_structs, opt_structs, b_structs)

        def spec_fn(ax):
            from jax.sharding import PartitionSpec as P
            from repro.train.optimizer import AdamWState

            ps = param_specs(cfg, ax)
            opt_specs = AdamWState(step=P(), m=ps, v=jax.tree.map(lambda x: x, ps))
            bs = batch_specs(cfg, ax, sc.global_batch)
            metrics_specs = {"loss": P(), "moe_aux": P(), "grad_norm": P()}
            return (ps, opt_specs, bs), (ps, opt_specs, metrics_specs)

        tokens = sc.global_batch * sc.seq_len
        training = True
    elif sc.kind == "prefill":
        step = make_prefill_step(cfg)
        # serving: no FSDP on weights — DP replicas hold full TP-sharded
        # weights (BCQ makes them small; re-gathering them every step over
        # `data` is pure overhead)
        b_structs = batch_structs(cfg, sc.global_batch, sc.seq_len)
        b_structs.pop("labels")
        cache_structs = jax.eval_shape(
            lambda: init_cache(cfg, sc.global_batch, sc.seq_len)
        )
        args = (p_structs, b_structs, cache_structs)

        def spec_fn(ax):
            import dataclasses as _dc

            ps = param_specs(cfg, _dc.replace(ax, fsdp=None))
            bs = batch_specs(cfg, ax, sc.global_batch)
            bs.pop("labels")
            cs = cache_specs(cfg, ax, sc.global_batch)
            return (ps, bs, cs), (logits_spec(cfg, ax, sc.global_batch), cs)

        tokens = sc.global_batch * sc.seq_len
        training = False
    else:  # decode
        step = make_serve_step(cfg)
        b_structs = batch_structs(cfg, sc.global_batch, 1)
        b_structs.pop("labels")
        cache_structs = jax.eval_shape(
            lambda: init_cache(cfg, sc.global_batch, sc.seq_len)
        )
        pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
        args = (p_structs, cache_structs, b_structs, pos_struct)

        def spec_fn(ax):
            import dataclasses as _dc

            from jax.sharding import PartitionSpec as P

            ps = param_specs(cfg, _dc.replace(ax, fsdp=None))
            bs = batch_specs(cfg, ax, sc.global_batch)
            bs.pop("labels")
            cs = cache_specs(cfg, ax, sc.global_batch)
            return (ps, cs, bs, P()), (logits_spec(cfg, ax, sc.global_batch), cs)

        tokens = sc.global_batch
        training = False

    counts = count_params(cfg, p_structs)
    meta = {
        "arch": arch,
        "shape": shape,
        "kind": sc.kind,
        "tokens_per_step": tokens,
        "training": training,
        "quant_q": quant_q,
        "accum_steps": locals().get("accum", 1),
        "params_total": counts["total"],
        "params_active": counts["active_nonembed"],
        "embed_params": counts["embed"],
    }
    return step, args, spec_fn, p_structs, meta


class SkipCell(Exception):
    pass


def count_params(cfg: ModelConfig, p_structs) -> dict:
    """Exact logical param counts from the struct tree (QT leaves count their
    dense k·o size). active = total with MoE experts scaled by top_k/E."""
    import numpy as np

    total = active = embed = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(
        p_structs, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )
    for path, leaf in flat:
        keys = [getattr(pp, "key", getattr(pp, "name", str(pp))) for pp in path]
        if isinstance(leaf, QuantizedTensor):
            lead = (
                int(np.prod(leaf.packed.shape[:-3])) if leaf.packed.ndim > 3 else 1
            )
            n = lead * leaf.k * leaf.o
        else:
            n = int(np.prod(leaf.shape))
        total += n
        if keys and keys[0] == "embed":
            embed += n
            continue
        is_expert = (
            cfg.n_experts > 0
            and "mlp" in keys
            and keys[-1] in ("w_gate", "w_up", "w_down")
            and "shared" not in keys
        )
        if is_expert:
            active += n * cfg.top_k // cfg.n_experts
        else:
            active += n
    return {"total": total, "active_nonembed": active, "embed": embed}


# ---------------------------------------------------------------------------
# HBM adjustment for the fused BCQ kernel (see DESIGN.md §2 / EXPERIMENTS.md)
# ---------------------------------------------------------------------------


def bcq_hbm_adjustment(p_structs) -> int:
    """Bytes the TPU Pallas kernel does NOT move, but the CPU-lowered jnp
    reference does: the dequantised f32 weight round-trip (write+read, 8·k·o)
    and the unpacked int8 signs round-trip (2·q·k·o) per quantized matmul use.
    """
    adj = 0
    for leaf in jax.tree.leaves(
        p_structs, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    ):
        if isinstance(leaf, QuantizedTensor):
            import numpy as np

            lead = int(np.prod(leaf.packed.shape[:-3])) if leaf.packed.ndim > 3 else 1
            q = leaf.packed.shape[-3]
            ko = leaf.k * leaf.o
            adj += lead * (8 * ko + 2 * q * ko)
    return adj


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------


def run_cell(
    arch: str, shape: str, mesh_kind: str, quant_q: int = 0, verbose: bool = True,
    kv_quant: bool = False,
) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    ax = production_axes(multi_pod=multi)
    chips = mesh.devices.size

    step, args, spec_fn, p_structs, meta = input_specs(
        arch, shape, quant_q, dp_size=ax.data_size, kv_quant=kv_quant
    )
    in_specs, out_specs = spec_fn(ax)
    # expand dense weight specs into QuantizedTensor-structured specs
    in_specs = _spec_tree_for(args, in_specs, ax)
    in_shardings = jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s),
        in_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    out_shardings = jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s),
        out_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )

    # donate the state that flows through: params/opt (train), cache (serve) —
    # removes whole-buffer copies at the step boundary (in-place production
    # semantics; without this every decode step would copy the full KV cache)
    donate = {"train": (0, 1), "prefill": (2,), "decode": (1,)}[meta["kind"]]
    t0 = time.time()
    with set_mesh(mesh):
        lowered = jax.jit(
            step,
            in_shardings=in_shardings,
            out_shardings=out_shardings,
            donate_argnums=donate,
        ).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)  # raw single-pass HLO sweep (reference)
    tc = hlo_analyze(hlo)  # trip-count-aware custom cost model (the roofline)

    if verbose:
        print(f"--- {arch} × {shape} × {mesh_kind} (q={quant_q or 'dense'}) ---")
        print("memory_analysis:", mem)
        print(
            "cost_analysis flops:", cost.get("flops"),
            "bytes:", cost.get("bytes accessed"),
            "| trip-aware flops:", tc.flops, "bytes:", tc.bytes,
        )

    flops_pc = tc.flops
    bytes_pc = tc.bytes
    coll_total = tc.collective_bytes
    coll_wire = tc.collective_wire_bytes

    n_active = meta["params_active"]
    mf = model_flops_estimate(n_active, meta["tokens_per_step"], meta["training"])

    adj = bcq_hbm_adjustment(p_structs) if quant_q else 0
    rf = roofline(flops_pc, bytes_pc, coll_wire, chips=chips, model_flops=mf)
    rf_adj = roofline(
        flops_pc, max(bytes_pc - adj, 0.0), coll_wire, chips=chips, model_flops=mf
    )

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "chips": int(chips),
        "quant_q": quant_q,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory_analysis": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost_analysis_builtin": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
        },
        "trip_aware": {
            "flops": tc.flops,
            "bytes": tc.bytes,
            "collectives": tc.coll,
            "unparsed_loops": tc.unparsed_loops,
        },
        "collectives": coll,
        "collective_bytes": coll_total,
        "collective_wire_bytes": coll_wire,
        "model_flops": mf,
        "bcq_hbm_adjustment": adj,
        "roofline": rf.to_dict(),
        "roofline_kernel_adjusted": rf_adj.to_dict(),
        "meta": meta,
    }
    return result


def cell_list(mesh_kinds):
    """Assigned cells: train=bf16, serve=q4 (the system as the paper intends).
    Single-pod serve cells also get dense + q2 variants — the paper-comparison
    baselines the roofline report pairs against."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape, sc in SHAPES.items():
            if shape == "long_500k" and not cfg.supports_long_context:
                continue
            for mesh_kind in mesh_kinds:
                if sc.kind == "train":
                    cells.append((arch, shape, mesh_kind, 0))
                    continue
                quants = (4,) if mesh_kind == "multi" else (4, 2, 0)
                for q in quants:
                    cells.append((arch, shape, mesh_kind, q))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--quant", type=int, default=None, help="BCQ q bits (0=dense)")
    ap.add_argument("--kv-quant", action="store_true", help="int8 KV cache")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    mesh_kinds = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        cells = cell_list(mesh_kinds)
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        q = args.quant
        if q is None:
            q = 0 if SHAPES[args.shape].kind == "train" else 4
        cells = [(args.arch, args.shape, mk, q) for mk in mesh_kinds]
    kvq = getattr(args, "kv_quant", False)

    os.makedirs(args.out_dir, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch, shape, mesh_kind, q in cells:
        suffix = "__kvq8" if kvq else ""
        name = f"{arch}__{shape}__{mesh_kind}__q{q}{suffix}.json"
        path = os.path.join(args.out_dir, name)
        if os.path.exists(path) and not args.force:
            n_skip += 1
            continue
        try:
            res = run_cell(arch, shape, mesh_kind, q, kv_quant=kvq)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            n_ok += 1
            r = res["roofline"]
            print(
                f"OK  {name}: dominant={r['dominant']} bound={r['bound_s']*1e3:.2f}ms "
                f"compile={res['compile_s']:.1f}s"
            )
        except SkipCell as e:
            print(f"SKIP {name}: {e}")
            n_skip += 1
        except Exception:
            print(f"FAIL {name}:")
            traceback.print_exc()
            n_fail += 1
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
