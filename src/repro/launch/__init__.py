"""Launch entry points: mesh construction, multi-pod dry-run, train, serve."""
