"""Deterministic synthetic language data.

``MarkovCorpus`` is a fixed sparse first-order Markov chain over a Zipfian
vocabulary — learnable structure (so training loss actually falls and
quantization-induced PPL degradation is measurable, paper Figs. 5/6) while
being fully reproducible offline. The chain and all sampling are
seed-deterministic.

The loader is host-sharded: each process takes its ``process_index``-th slice
of the global batch (single-process here, but the interface is the multi-host
one).
"""

from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


class MarkovCorpus:
    def __init__(self, vocab: int, branching: int = 8, seed: int = 0):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        # each token transitions to `branching` successors with Zipf weights
        self.successors = rng.integers(0, vocab, size=(vocab, branching))
        w = 1.0 / np.arange(1, branching + 1)
        self.weights = w / w.sum()
        self.branching = branching

    def sample(self, batch: int, seq_len: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq_len):
            choice = rng.choice(self.branching, size=batch, p=self.weights)
            toks[:, t + 1] = self.successors[toks[:, t], choice]
        return toks


def batch_iterator(
    corpus: MarkovCorpus,
    *,
    batch: int,
    seq_len: int,
    seed: int = 0,
    process_index: int = 0,
    process_count: int = 1,
    embed_dim: Optional[int] = None,
) -> Iterator[dict]:
    """Yields {"tokens","labels"} (next-token shifted) or {"embeddings","labels"}
    for embedding-input (modality-stub) models."""
    assert batch % process_count == 0
    local = batch // process_count
    step = 0
    while True:
        toks = corpus.sample(batch, seq_len, seed=seed * 1_000_003 + step)
        toks = toks[process_index * local : (process_index + 1) * local]
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}
        if embed_dim is not None:
            rng = np.random.default_rng(seed * 7 + step)
            table = _embed_table(corpus.vocab, embed_dim)
            out = {
                "embeddings": table[out["tokens"]],
                "labels": out["labels"],
            }
        yield out
        step += 1


_TABLES: dict = {}


def _embed_table(vocab: int, dim: int) -> np.ndarray:
    key = (vocab, dim)
    if key not in _TABLES:
        rng = np.random.default_rng(1234)
        _TABLES[key] = rng.standard_normal((vocab, dim)).astype(np.float32) * 0.4
    return _TABLES[key]
