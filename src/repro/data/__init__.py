"""Data pipeline: synthetic corpora + host-sharded loaders."""

from repro.data.synthetic import MarkovCorpus, batch_iterator

__all__ = ["MarkovCorpus", "batch_iterator"]
