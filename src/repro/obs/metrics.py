"""Metrics registry: counters, gauges, exponential-bucket histograms.

Pure stdlib, thread-safe, host-side only (the ``lint/obs-host-only`` rule
keeps jax and the kernel modules out of this package). The registry is the
one sink for serving metrics — the scheduler, engine, async server and
``kernels/ops.py::qmatmul`` all write here — and it exports two ways:

- :meth:`MetricsRegistry.snapshot` — a plain JSON-able dict (the ``/v1/
  metrics`` JSON body and the ``benchmarks/serve_bench.py`` artifact);
- :func:`prometheus_text` — Prometheus text exposition format
  (``/v1/metrics?format=prometheus``), with :func:`parse_prometheus` as the
  matching mini-parser so the CI smoke job and tests validate the exact
  bytes a scraper would see.

Design notes:

- **Labels** are kwargs at lookup time: ``reg.counter("qmatmul_dispatch_total",
  fmt="bcq", impl="bcq_mm")``. Each distinct label set is its own series;
  lookups are get-or-create and return the same object every time, so hot
  paths hold the metric handle instead of re-resolving it.
- **Histograms use exponential buckets** (``start * factor**i``): serving
  latencies span 4+ decades (µs-scale span overhead to multi-second TTFT
  under overload), where linear buckets either blur the head or truncate
  the tail. Counts are kept per-bucket (non-cumulative) internally and
  cumulated only at export, matching Prometheus semantics.
- **Thread safety**: one lock per registry guards series creation; each
  metric carries its own lock for updates. The GIL already makes single
  ``+=`` updates atomic in CPython, but the histogram's (bucket, sum,
  count) triple must move together — and the lock documents intent.
"""

from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# default exponential ladder: 1e-4 * 2**i for 22 buckets → ~0.1 ms .. ~210 s,
# covering span overhead, chunk latencies, TTFT under overload, and makespans
DEFAULT_BUCKET_START = 1e-4
DEFAULT_BUCKET_FACTOR = 2.0
DEFAULT_BUCKET_COUNT = 22


def exponential_buckets(
    start: float = DEFAULT_BUCKET_START,
    factor: float = DEFAULT_BUCKET_FACTOR,
    count: int = DEFAULT_BUCKET_COUNT,
) -> Tuple[float, ...]:
    """Upper bounds ``start * factor**i`` for i in [0, count). The implicit
    final bucket is +Inf (kept out of the tuple; exporters add it)."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError(
            f"exponential_buckets needs start > 0, factor > 1, count >= 1; "
            f"got start={start}, factor={factor}, count={count}"
        )
    return tuple(start * factor**i for i in range(count))


class Counter:
    """Monotonically increasing count. ``inc`` only goes up — a decrement is
    a programming error, raised loudly (use a Gauge for levels)."""

    __slots__ = ("_value", "_lock")

    def __init__(self) -> None:
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increments must be >= 0, got {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """A level that goes both ways (queue depth, slot occupancy)."""

    __slots__ = ("_value", "_lock")

    def __init__(self) -> None:
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Exponential-bucket histogram: per-bucket counts + sum + count.

    ``observe(v)`` files ``v`` under the first bucket whose upper bound is
    ``>= v`` (overflow goes to the implicit +Inf bucket). Non-finite values
    are counted separately (``nonfinite``) instead of poisoning ``sum`` —
    a NaN latency is a bug upstream, not a data point.
    """

    __slots__ = ("bounds", "_counts", "_inf", "_sum", "_count", "nonfinite", "_lock")

    def __init__(self, bounds: Optional[Sequence[float]] = None) -> None:
        bounds = tuple(bounds) if bounds is not None else exponential_buckets()
        if not bounds or any(b <= a for a, b in zip(bounds, bounds[1:])):
            raise ValueError(f"bucket bounds must be strictly increasing: {bounds}")
        self.bounds = bounds
        self._counts = [0] * len(bounds)
        self._inf = 0
        self._sum = 0.0
        self._count = 0
        self.nonfinite = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        if not math.isfinite(v):
            with self._lock:
                self.nonfinite += 1
            return
        # bisect by hand: bounds are short (~22) and this avoids an import
        idx = None
        for i, b in enumerate(self.bounds):
            if v <= b:
                idx = i
                break
        with self._lock:
            if idx is None:
                self._inf += 1
            else:
                self._counts[idx] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def cumulative(self) -> List[Tuple[float, int]]:
        """(upper_bound, cumulative_count) pairs ending with (+Inf, count) —
        the Prometheus exposition shape."""
        with self._lock:
            out, acc = [], 0
            for b, c in zip(self.bounds, self._counts):
                acc += c
                out.append((b, acc))
            out.append((math.inf, acc + self._inf))
        return out

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        holding the q-th observation); None when empty. Coarse by design —
        exact percentiles come from ``infer.lifecycle.latency_summary``."""
        if not 0 <= q <= 1:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        cum = self.cumulative()
        total = cum[-1][1]
        if total == 0:
            return None
        rank = q * total
        for bound, acc in cum:
            if acc >= rank:
                return bound
        return math.inf


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create metric series keyed by (name, sorted label items).

    A name is bound to one kind and one label-key set at first use; a later
    lookup with a different kind or label keys raises — silent type morphing
    is how dashboards end up graphing garbage.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # name -> (kind, help, label_keys, bucket bounds or None)
        self._meta: Dict[str, Tuple[str, str, Tuple[str, ...], Optional[Tuple[float, ...]]]] = {}
        # (name, ((k, v), ...)) -> metric
        self._series: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], object] = {}

    def _get(self, kind: str, name: str, help: str, labels: Dict[str, str],
             buckets: Optional[Sequence[float]] = None):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for k in labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"invalid label name {k!r} on metric {name!r}")
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        label_keys = tuple(sorted(labels))
        with self._lock:
            meta = self._meta.get(name)
            if meta is None:
                self._meta[name] = (
                    kind, help, label_keys,
                    tuple(buckets) if buckets is not None else None,
                )
            else:
                if meta[0] != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {meta[0]}, "
                        f"requested {kind}"
                    )
                if meta[2] != label_keys:
                    raise ValueError(
                        f"metric {name!r} registered with labels {meta[2]}, "
                        f"requested {label_keys} — one name, one label set"
                    )
            m = self._series.get(key)
            if m is None:
                if kind == "histogram":
                    m = Histogram(self._meta[name][3])
                else:
                    m = _KINDS[kind]()
                self._series[key] = m
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None, **labels) -> Histogram:
        return self._get("histogram", name, help, labels, buckets=buckets)

    # -- export ----------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able view: ``{name: {"type", "help", "series": [{"labels",
        ...values...}]}}``. Histograms carry sum/count/buckets plus coarse
        p50/p95/p99 estimates so the JSON body is directly dashboardable."""
        with self._lock:
            meta = dict(self._meta)
            series = list(self._series.items())
        out: Dict[str, dict] = {}
        for name, (kind, help, _keys, _buckets) in sorted(meta.items()):
            out[name] = {"type": kind, "help": help, "series": []}
        for (name, labels), m in sorted(series, key=lambda kv: kv[0]):
            entry: dict = {"labels": dict(labels)}
            if isinstance(m, Histogram):
                entry["count"] = m.count
                entry["sum"] = m.sum
                entry["buckets"] = [
                    ["+Inf" if math.isinf(b) else b, c] for b, c in m.cumulative()
                ]
                entry["p50"] = m.quantile(0.50)
                entry["p95"] = m.quantile(0.95)
                entry["p99"] = m.quantile(0.99)
                if m.nonfinite:
                    entry["nonfinite"] = m.nonfinite
            else:
                entry["value"] = m.value
            out[name]["series"].append(entry)
        return out


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 2**53:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(labels: Dict[str, str], extra: Optional[Tuple[str, str]] = None) -> str:
    items = list(labels.items())
    if extra is not None:
        items.append(extra)
    if not items:
        return ""
    body = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in items
    )
    return "{" + body + "}"


def prometheus_text(*registries: MetricsRegistry) -> str:
    """Prometheus text exposition (v0.0.4) over one or more registries —
    the async server concatenates its own registry with the process-global
    :func:`default_registry` (kernel dispatch counts) into one scrape."""
    lines: List[str] = []
    seen_names = set()
    for reg in registries:
        with reg._lock:
            meta = dict(reg._meta)
            series = sorted(reg._series.items(), key=lambda kv: kv[0])
        for name, (kind, help, _keys, _buckets) in sorted(meta.items()):
            if name in seen_names:
                raise ValueError(
                    f"metric {name!r} exported by more than one registry — "
                    "a scrape must not carry duplicate metric families"
                )
            seen_names.add(name)
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {kind}")
            for (sname, labels), m in series:
                if sname != name:
                    continue
                ld = dict(labels)
                if isinstance(m, Histogram):
                    for bound, acc in m.cumulative():
                        lines.append(
                            f"{name}_bucket"
                            f"{_fmt_labels(ld, ('le', _fmt_value(bound)))} {acc}"
                        )
                    lines.append(f"{name}_sum{_fmt_labels(ld)} {_fmt_value(m.sum)}")
                    lines.append(f"{name}_count{_fmt_labels(ld)} {m.count}")
                else:
                    lines.append(f"{name}{_fmt_labels(ld)} {_fmt_value(m.value)}")
    return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$"
)
_LABEL_PAIR_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> Dict[str, List[Tuple[Dict[str, str], float]]]:
    """Strict mini-parser for the exposition format: returns
    ``{sample_name: [(labels, value), ...]}``. Raises ``ValueError`` on any
    malformed line — the CI smoke job scrapes ``/v1/metrics`` through this,
    so an export regression fails loudly instead of silently scraping junk."""
    out: Dict[str, List[Tuple[Dict[str, str], float]]] = {}
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            if not (line.startswith("# HELP ") or line.startswith("# TYPE ")):
                raise ValueError(f"line {lineno}: malformed comment {raw!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample {raw!r}")
        labels: Dict[str, str] = {}
        body = m.group("labels")
        if body:
            consumed = 0
            for pm in _LABEL_PAIR_RE.finditer(body):
                labels[pm.group(1)] = pm.group(2)
                consumed += len(pm.group(0))
            # commas between pairs
            if consumed + max(0, len(labels) - 1) != len(body):
                raise ValueError(f"line {lineno}: malformed labels {body!r}")
        v = m.group("value")
        if v == "+Inf":
            value = math.inf
        elif v == "-Inf":
            value = -math.inf
        elif v == "NaN":
            value = math.nan
        else:
            try:
                value = float(v)
            except ValueError:
                raise ValueError(f"line {lineno}: malformed value {v!r}") from None
        out.setdefault(m.group("name"), []).append((labels, value))
    return out


_DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry. ``kernels/ops.py::qmatmul`` counts its
    per-format dispatches here (trace-time counts: one per kernel call site
    per compilation, zero runtime overhead); servers merge it into their
    scrape via :func:`prometheus_text`."""
    return _DEFAULT_REGISTRY


def counters_agree(registry: MetricsRegistry, counters: Dict[str, float],
                   prefix: str = "serve_", suffix: str = "_total") -> List[str]:
    """Diff helper for the accounting tests: returns the mismatches between a
    scheduler's host-side ``counters`` dict and the registry series named
    ``{prefix}{key}{suffix}`` (empty list == perfect agreement)."""
    snap = registry.snapshot()
    problems = []
    for key, want in sorted(counters.items()):
        name = f"{prefix}{key}{suffix}"
        fam = snap.get(name)
        if fam is None:
            if want:
                problems.append(f"{name}: missing from registry (counters={want})")
            continue
        got = sum(s.get("value", 0.0) for s in fam["series"])
        if got != want:
            problems.append(f"{name}: registry={got} counters={want}")
    return problems
