"""Serving-stack observability (DESIGN.md §11): host-side, zero-dependency.

LUT-GEMM's claims are latency claims — the paper's headline is measured
token-generation speedup — so the serving stack must be *observable* at the
same granularity it is optimised: per request, per decode chunk, per
quantized-kernel dispatch. This package is the one place that machinery
lives:

- :mod:`repro.obs.trace`   — a low-overhead ring-buffered span tracer with an
  injectable clock, exportable as Chrome/Perfetto trace-event JSON
  (``python -m repro.obs.trace`` captures a demo serve; ``/v1/trace`` on the
  async server exports a live session).
- :mod:`repro.obs.metrics` — counters / gauges / exponential-bucket
  histograms behind a thread-safe registry, exportable as a JSON snapshot or
  Prometheus text format (``/v1/metrics``).

Contract: **everything here is host-side**. Nothing in ``repro.obs`` may
import jax or the jitted kernel/model modules (enforced by the
``lint/obs-host-only`` staticcheck rule), and the instrumentation hooks in
``infer/``/``launch/`` fire only *between* engine dispatches — never inside a
jitted computation — so instrumented serving stays bit-identical to
uninstrumented serving and the §3 trace-once invariant holds (both asserted
in tests/test_obs.py).
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    parse_prometheus,
    prometheus_text,
)
from repro.obs.trace import Tracer, validate_chrome_trace

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Tracer",
    "default_registry",
    "parse_prometheus",
    "prometheus_text",
    "validate_chrome_trace",
]
