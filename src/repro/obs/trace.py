"""Ring-buffered span tracer with Chrome/Perfetto trace-event export.

The serving stack (scheduler chunk loop, engine dispatches, ServeSession
pump thread) emits spans here; ``to_chrome()`` renders them in the Chrome
trace-event JSON format, loadable in ``chrome://tracing`` or
https://ui.perfetto.dev. Design constraints (DESIGN.md §11):

- **Low overhead**: a recorded span is one clock reading at enter, one at
  exit, and one tuple append under a lock — no dict churn, no string
  formatting until export. A disabled tracer never touches its clock, so
  ``tracer=None`` and ``Tracer(enabled=False)`` are both true zeros.
- **Bounded memory**: a ring of ``capacity`` events; the oldest are evicted
  and counted (``evicted``) so a long-lived server can always answer
  ``/v1/trace`` with its recent window without growing without bound.
- **Injectable clock**: defaults to ``time.monotonic``; tests drive it with
  ``infer.faults.StepClock``. The tracer's clock is deliberately *separate*
  from the scheduler's — recording spans must never consume scheduler clock
  readings, or tracing would perturb deadline behaviour under StepClock.
- **Two timestamp sources, one rule**: live spans (:meth:`Tracer.span`)
  read the tracer clock; lifecycle spans replayed from
  ``RequestLifecycle`` records (:meth:`Tracer.complete`) reuse timestamps
  the scheduler already took. In production both clocks are
  ``time.monotonic`` so the lanes align; under a fake clock they are
  separate timebases and tests assert within-lane ordering only.

``python -m repro.obs.trace`` runs a short fault-injected serve (cancel +
NaN poison + deadline shed, mirroring tests/test_lifecycle.py), dumps the
trace JSON, and validates it — ``--smoke`` mode is the CI obs job.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

# event tuples: (ph, name, cat, lane, ts, dur, args)
#   ph "X" = complete span (dur set), "i" = instant (dur None)
_Event = Tuple[str, str, str, str, float, Optional[float], Optional[dict]]


class _SpanHandle:
    """Context manager for one live span; ``annotate()`` adds args mid-span
    (e.g. tokens committed, discovered only at chunk end)."""

    __slots__ = ("_tracer", "name", "cat", "lane", "args", "_start")

    def __init__(self, tracer: "Tracer", name: str, cat: str, lane: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.lane = lane
        self.args = args
        self._start = 0.0

    def annotate(self, **kw) -> None:
        self.args.update(kw)

    def __enter__(self) -> "_SpanHandle":
        self._start = self._tracer._clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        end = self._tracer._clock()
        if exc_type is not None:
            self.args["error"] = f"{exc_type.__name__}: {exc}"
        self._tracer.complete(
            self.name, self._start, end, cat=self.cat, lane=self.lane,
            args=self.args or None,
        )


class _NullSpan:
    """Zero-cost stand-in when the tracer is disabled: no clock reads."""

    __slots__ = ()

    def annotate(self, **kw) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe ring buffer of spans/instants with Chrome-trace export.

    >>> tr = Tracer(capacity=4096)
    >>> with tr.span("decode_chunk", lane="scheduler", ordinal=3):
    ...     ...
    >>> tr.complete("queued", t_submit, t_admit, lane="req:0")
    >>> json.dump(tr.to_chrome(), open("trace.json", "w"))
    """

    def __init__(
        self,
        capacity: int = 8192,
        clock: Callable[[], float] = time.monotonic,
        enabled: bool = True,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self._clock = clock
        self._events: deque = deque()
        self._lock = threading.Lock()
        self.evicted = 0
        self.recorded = 0

    # -- recording -------------------------------------------------------------

    def _append(self, ev: _Event) -> None:
        with self._lock:
            if len(self._events) >= self.capacity:
                self._events.popleft()
                self.evicted += 1
            self._events.append(ev)
            self.recorded += 1

    def span(self, name: str, *, cat: str = "", lane: str = "main", **args):
        """Live span context manager: reads the tracer clock at enter/exit.
        Disabled → a shared no-op handle (no clock reads, no allocation
        beyond the kwargs dict the caller already built)."""
        if not self.enabled:
            return _NULL_SPAN
        return _SpanHandle(self, name, cat, lane, args)

    def complete(self, name: str, start: float, end: float, *, cat: str = "",
                 lane: str = "main", args: Optional[dict] = None) -> None:
        """Record a span from timestamps the caller already holds (lifecycle
        records replay through here — zero extra clock readings)."""
        if not self.enabled:
            return
        self._append(("X", name, cat, lane, start, max(0.0, end - start), args))

    def instant(self, name: str, *, ts: Optional[float] = None, cat: str = "",
                lane: str = "main", args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._append(("i", name, cat, lane, self._clock() if ts is None else ts,
                      None, args))

    def now(self) -> float:
        """One tracer-clock reading (for callers composing complete())."""
        return self._clock()

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def stats(self) -> dict:
        with self._lock:
            return {
                "recorded": self.recorded,
                "buffered": len(self._events),
                "evicted": self.evicted,
                "capacity": self.capacity,
            }

    # -- export ----------------------------------------------------------------

    def events(self) -> List[_Event]:
        """The buffered raw event tuples, oldest first (a copy)."""
        with self._lock:
            return list(self._events)

    def chrome_events(self) -> List[dict]:
        """Render as Chrome trace-event dicts: ``ph:"X"`` complete events and
        ``ph:"i"`` instants, timestamps in µs relative to the earliest
        buffered event, one ``tid`` lane per distinct ``lane`` string (with
        ``M`` thread_name/thread_sort_index metadata so Perfetto labels and
        orders them)."""
        raw = self.events()
        if not raw:
            return []
        t0 = min(ev[4] for ev in raw)
        lanes: Dict[str, int] = {}
        out: List[dict] = []
        for ph, name, cat, lane, ts, dur, args in raw:
            tid = lanes.setdefault(lane, len(lanes) + 1)
            ev: dict = {
                "ph": ph,
                "name": name,
                "pid": 1,
                "tid": tid,
                "ts": round((ts - t0) * 1e6, 3),
            }
            if cat:
                ev["cat"] = cat
            if ph == "X":
                ev["dur"] = round((dur or 0.0) * 1e6, 3)
            else:
                ev["s"] = "t"  # thread-scoped instant
            if args:
                ev["args"] = args
            out.append(ev)
        meta: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
             "args": {"name": "repro.serve"}},
        ]
        for lane, tid in sorted(lanes.items(), key=lambda kv: kv[1]):
            meta.append({"ph": "M", "name": "thread_name", "pid": 1, "tid": tid,
                         "args": {"name": lane}})
            meta.append({"ph": "M", "name": "thread_sort_index", "pid": 1,
                         "tid": tid, "args": {"sort_index": tid}})
        return meta + out

    def to_chrome(self) -> dict:
        """The full Chrome trace object (JSON Object Format): load the dump
        in chrome://tracing or ui.perfetto.dev as-is."""
        return {
            "traceEvents": self.chrome_events(),
            "displayTimeUnit": "ms",
            "otherData": self.stats(),
        }


_VALID_PH = {"X", "i", "M"}


def validate_chrome_trace(trace) -> List[str]:
    """Schema check for the trace-event JSON we emit (and the subset of the
    format Perfetto requires). Accepts the dict or its JSON string; returns a
    list of problems — empty means valid. Checked shape:

    - top level: object with a ``traceEvents`` list;
    - every event: ``ph`` ∈ {X, i, M}, string ``name``, integer ``pid``/
      ``tid``, and for X/i a numeric non-negative ``ts`` (µs);
    - ``X`` events: numeric non-negative ``dur``;
    - ``i`` events: scope ``s`` ∈ {g, p, t};
    - ``M`` events: an ``args`` object (thread/process metadata payload).
    """
    problems: List[str] = []
    if isinstance(trace, (str, bytes)):
        try:
            trace = json.loads(trace)
        except json.JSONDecodeError as e:
            return [f"not valid JSON: {e}"]
    if not isinstance(trace, dict):
        return [f"top level must be an object, got {type(trace).__name__}"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["missing or non-list 'traceEvents'"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            problems.append(f"{where}: ph={ph!r} not in {sorted(_VALID_PH)}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: missing/empty 'name'")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                problems.append(f"{where}: '{key}' must be an int")
        if ph in ("X", "i"):
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                problems.append(f"{where}: 'ts' must be a number >= 0, got {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X event 'dur' must be >= 0, got {dur!r}")
        if ph == "i" and ev.get("s") not in ("g", "p", "t"):
            problems.append(f"{where}: instant scope 's' must be g/p/t")
        if ph == "M" and not isinstance(ev.get("args"), dict):
            problems.append(f"{where}: metadata event needs an 'args' object")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: 'args' must be an object")
    return problems


def request_lifecycles(trace) -> Dict[str, List[dict]]:
    """Group a Chrome trace's events by request lane (``req:<rid>``), each
    sorted by ts — the reconstruction primitive the acceptance test uses to
    prove every request's lifecycle is recoverable from the trace alone."""
    if isinstance(trace, (str, bytes)):
        trace = json.loads(trace)
    lane_names: Dict[int, str] = {}
    for ev in trace["traceEvents"]:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            lane_names[ev["tid"]] = ev["args"]["name"]
    out: Dict[str, List[dict]] = {}
    for ev in trace["traceEvents"]:
        if ev.get("ph") == "M":
            continue
        lane = lane_names.get(ev["tid"], str(ev["tid"]))
        if lane.startswith("req:"):
            out.setdefault(lane, []).append(ev)
    for evs in out.values():
        evs.sort(key=lambda e: e["ts"])
    return out


# -- CLI ------------------------------------------------------------------------
#
# `python -m repro.obs.trace` runs a short fault-injected serve and dumps a
# trace; `--smoke` additionally validates everything and exits non-zero on
# any problem (the CI obs job). Engine/scheduler imports happen inside the
# functions: the module itself must stay importable without jax
# (lint/obs-host-only checks module-level imports).


def demo_serve(gen: int = 6, n_requests: int = 6):
    """A deliberately disturbed serve run on a tiny reduced model: one
    client cancel, one NaN-poisoned row, one deadline shed — the same
    unhappy-path mix tests/test_lifecycle.py hardens. Returns
    ``(scheduler, tracer, registry)`` after the queue drains."""
    import jax  # noqa: PLC0415 — lazy: keep repro.obs importable without jax
    import numpy as np  # noqa: PLC0415

    from repro.configs import get_config  # noqa: PLC0415
    from repro.data import MarkovCorpus  # noqa: PLC0415
    from repro.infer import (  # noqa: PLC0415
        Engine,
        FaultPlan,
        Request,
        Scheduler,
        StepClock,
    )
    from repro.models import init_params, reduced  # noqa: PLC0415
    from repro.obs.metrics import MetricsRegistry  # noqa: PLC0415
    from repro.quant import QuantPolicy, quantize_params  # noqa: PLC0415

    # 128-dim linears: the smallest size the quantization policy accepts, so
    # the demo really serves BCQ (64-dim would silently fall back to dense)
    cfg = reduced(get_config("llama3.2-3b"), d_model=128, n_kv_heads=4, d_ff=256)
    params = quantize_params(
        init_params(jax.random.PRNGKey(0), cfg), QuantPolicy(q=3, g=32, iters=2)
    )
    tracer = Tracer(capacity=4096)
    registry = MetricsRegistry()
    engine = Engine(cfg, params, max_seq=64, tracer=tracer)

    corpus = MarkovCorpus(cfg.vocab, seed=3)
    reqs = []
    for i in range(n_requests):
        plen = 4 + (i % 3)
        prompt = corpus.sample(1, plen, seed=100 + i)[0, :plen].astype(np.int32)
        reqs.append(Request(prompt=prompt, max_new_tokens=gen,
                            temperature=[0.0, 0.7][i % 2], seed=10 + i))
    # request n-1 sheds in queue: its deadline expires while earlier requests
    # hold both slots (StepClock advances 0.05 s per reading)
    reqs[-1].deadline_s = 0.01
    clk = StepClock(dt=0.05)
    sched = Scheduler(
        engine, n_slots=2, chunk=3,
        faults=FaultPlan(nan_row={1: 2}),  # rids are assigned 0..n-1 in submit order
        clock=clk, sleep=clk.sleep,
        tracer=tracer, metrics=registry,
    )
    rids = [sched.submit(r) for r in reqs]
    sched.cancel(rids[2], "demo client cancel")
    sched.run()
    return sched, tracer, registry


def main(argv: Optional[List[str]] = None) -> int:
    import argparse  # noqa: PLC0415

    p = argparse.ArgumentParser(
        prog="python -m repro.obs.trace",
        description="Capture (or validate) a Chrome trace of a fault-injected "
                    "demo serve run.",
    )
    p.add_argument("--out", default="trace.json", help="trace output path")
    p.add_argument("--validate", metavar="FILE",
                   help="validate an existing trace JSON file and exit")
    p.add_argument("--smoke", action="store_true",
                   help="CI mode: run the demo, validate the trace, parse the "
                        "Prometheus export, check request accounting; exit "
                        "non-zero on any problem")
    args = p.parse_args(argv)

    if args.validate:
        with open(args.validate) as f:
            problems = validate_chrome_trace(f.read())
        for msg in problems:
            print(f"INVALID: {msg}")
        print(f"{args.validate}: {'OK' if not problems else f'{len(problems)} problem(s)'}")
        return 1 if problems else 0

    sched, tracer, registry = demo_serve()
    trace = tracer.to_chrome()
    with open(args.out, "w") as f:
        json.dump(trace, f)
    summary = sched.summary()
    states = summary["by_state"]
    print(f"wrote {args.out}: {len(trace['traceEvents'])} events "
          f"({tracer.stats()['evicted']} evicted)")
    print(f"requests: {states}")
    print("open in chrome://tracing or https://ui.perfetto.dev")

    if not args.smoke:
        return 0

    from repro.obs.metrics import (  # noqa: PLC0415
        counters_agree,
        parse_prometheus,
        prometheus_text,
    )

    failures: List[str] = []
    failures += [f"trace: {m}" for m in validate_chrome_trace(trace)]
    lanes = request_lifecycles(trace)
    for rid in sched.outcomes:
        if f"req:{rid}" not in lanes:
            failures.append(f"trace: request {rid} has no lane")
    try:
        samples = parse_prometheus(prometheus_text(registry))
    except ValueError as e:
        samples = {}
        failures.append(f"prometheus: {e}")
    submitted = sum(v for _, v in samples.get("serve_submitted_total", []))
    terminal = sum(
        sum(v for _, v in samples.get(f"serve_{k}_total", []))
        for k in ("finished", "cancelled", "timed_out", "shed", "failed",
                  "rejected_queue_full")
    )
    if submitted == 0 or submitted != terminal:
        failures.append(
            f"accounting: submitted={submitted} != terminal sum={terminal}"
        )
    failures += [f"counters: {m}" for m in counters_agree(registry, sched.counters)]
    for msg in failures:
        print(f"SMOKE FAIL: {msg}")
    print(f"smoke: {'OK' if not failures else f'{len(failures)} failure(s)'}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
