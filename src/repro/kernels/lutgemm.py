"""LUT-GEMM Pallas kernel — the paper-faithful lookup-table algorithm (§III.B-C).

Per k-block of the reduction dimension:

1. **LUT build** (paper Table II): all ``2^mu`` partial dot products of every
   length-``mu=8`` activation sub-vector against every sign pattern, computed as
   ONE small MXU matmul ``x_chunks (B·bk/8, 8) @ P^T (8, 256)`` — the TPU
   replacement for the GPU thread-block shared-memory fill. The LUT lives in
   VMEM (~16 MB/core — the paper's shared-memory capacity argument holds with
   ~2 orders of magnitude more headroom than a GPU SM's shared memory; the
   per-grid-step budget is machine-checked via ``vmem_bytes`` below and
   ``kernels/introspect.py``).
2. **Retrieve** — packed weight bytes are the LUT keys; a vectorised
   ``take_along_axis`` replaces per-thread gathers. NOTE: this lowers to a
   dynamic-gather on TPU, which is VPU-serviced (no MXU) — the reason the
   unpack-and-MXU variant (``bcq_mm.py``) usually wins on TPU; see the
   benchmark comparison and DESIGN.md §2.
3. **Scale & accumulate** — partial sums are reduced over ``g/8`` byte-chunks
   per scale group, multiplied by the group scales, summed over the q bit
   planes, and accumulated into a float32 VMEM scratch accumulator that lives
   across the sequential k steps (deterministic stand-in for the paper's
   atomicAdd); the HBM output block is written once, on the last k step
   (DESIGN.md §2). The o grid dimension is declared ``parallel``, k
   ``arbitrary``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
DEFAULT_BLOCK_O = 128
MU = 8


def vmem_bytes(*, B: int, block_k: int, block_o: int, q: int, g: int) -> int:
    """Per-grid-step VMEM estimate (``kernels/introspect.py``): the bcq_mm
    input/output pipeline plus this kernel's LUT table and the gathered
    per-plane partial products — the terms that cap ``block_k`` differently
    from the unpack kernel (the autotuner rationale)."""
    from repro.kernels.introspect import scales_block_rows

    C = block_k // MU
    groups = scales_block_rows(block_k, g)
    io = 2 * (
        B * block_k * 4  # x block, f32
        + q * C * block_o  # packed block (LUT keys), uint8
        + q * groups * block_o * 4  # scales block (<= f32)
        + B * block_o * 4  # out block, f32
    )
    body = (
        B * C * (1 << MU) * 4  # the LUT: all 2^mu partial dots per chunk
        + B * q * C * block_o * 4  # gathered partial products
        + B * block_o * 4  # acc scratch
    )
    return io + body


def _sign_patterns(dtype) -> jax.Array:
    """(256, 8) constant: patterns[key, j] = +1 if bit j (LSB-first) of key set."""
    keys = jax.lax.broadcasted_iota(jnp.int32, (1 << MU, MU), 0)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1 << MU, MU), 1)
    return (2 * ((keys >> shifts) & 1) - 1).astype(dtype)


def _lutgemm_kernel(x_ref, packed_ref, scales_ref, out_ref, acc_ref, *, g: int, bk: int):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    B = x_ref.shape[0]
    C = bk // MU  # byte-chunks in this k-block

    # 1. LUT build on the MXU: (B*C, mu) @ (mu, 256) → (B, C, 256)
    x = x_ref[...].astype(jnp.float32)
    lut = jnp.dot(
        x.reshape(B * C, MU), _sign_patterns(jnp.float32).T,
        preferred_element_type=jnp.float32,
    ).reshape(B, C, 1 << MU)

    # 2. retrieve partial products by byte key: (B, q, C, bo)
    keys = packed_ref[...].astype(jnp.int32)  # (q, C, bo)
    partial = jnp.take_along_axis(
        lut[:, None, :, :, None],  # (B, 1, C, 256, 1)
        keys[None, :, :, None, :],  # (1, q, C, 1,  bo)
        axis=3,
    )[:, :, :, 0, :]

    # 3. group-scale and reduce
    scales = scales_ref[...].astype(jnp.float32)  # (q, bk//g or 1, bo)
    q, _, bo = keys.shape
    if g <= bk:
        cpg = g // MU  # byte-chunks per scale group
        grouped = partial.reshape(B, q, C // cpg, cpg, bo).sum(axis=3)
        acc = jnp.einsum("bqGo,qGo->bo", grouped, scales)
    else:
        acc = jnp.einsum("bqco,qo->bo", partial, scales[:, 0, :])
    acc_ref[...] += acc

    @pl.when(ik == nk - 1)
    def _finalize():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("g", "block_k", "block_o", "interpret"))
def lutgemm(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int = DEFAULT_BLOCK_K,
    block_o: int = DEFAULT_BLOCK_O,
    interpret: bool = False,
) -> jax.Array:
    """Paper-faithful LUT-GEMM: x (B, k) @ BCQ weights → (B, o) f32.

    Same contract and constraints as :func:`repro.kernels.bcq_mm.bcq_mm`,
    plus ``g % 8 == 0`` (a scale group may not split a LUT key byte).
    """
    B, k = x.shape
    q, kc, o = packed.shape
    if kc * MU != k:
        raise ValueError(f"packed k dim {kc}*{MU} != x k dim {k}")
    if k % block_k or o % block_o:
        raise ValueError(f"(k={k}, o={o}) must be divisible by ({block_k}, {block_o})")
    if g % MU or not (block_k % g == 0 or g % block_k == 0):
        raise ValueError(f"g={g} incompatible with block_k={block_k}")

    grid = (o // block_o, k // block_k)
    if g <= block_k:
        scales_spec = pl.BlockSpec(
            (q, block_k // g, block_o), lambda io, ik: (0, ik, io)
        )
    else:
        scales_spec = pl.BlockSpec(
            (q, 1, block_o), lambda io, ik: (0, ik // (g // block_k), io)
        )

    kernel = functools.partial(_lutgemm_kernel, g=g, bk=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, block_k), lambda io, ik: (0, ik)),
            pl.BlockSpec((q, block_k // MU, block_o), lambda io, ik: (0, ik, io)),
            scales_spec,
        ],
        out_specs=pl.BlockSpec((B, block_o), lambda io, ik: (0, io)),
        out_shape=jax.ShapeDtypeStruct((B, o), jnp.float32),
        scratch_shapes=[pltpu.VMEM((B, block_o), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, packed, scales)


from repro.kernels.introspect import register_vmem_estimator  # noqa: E402

register_vmem_estimator("lutgemm", vmem_bytes)
