"""jit'd dispatch wrappers over the Pallas kernels.

``quantized_matmul`` is THE entry point the rest of the framework uses for
``x @ W`` against a :class:`~repro.core.qtensor.QuantizedTensor`:

- ``impl="ref"``      pure-jnp dequantize+dot (XLA-fusable). Used by models on
                      CPU and by the dry-run lowering — on a real TPU deployment
                      this HLO region is replaced by the Pallas kernels below.
- ``impl="bcq_mm"``   fused unpack→scale→MXU Pallas kernel (TPU-native variant).
- ``impl="lutgemm"``  paper-faithful LUT kernel.
- ``impl="auto"``     bcq_mm on TPU backends, ref elsewhere.

The wrapper normalises leading batch dims, pads B to the sublane width and the
output dim to the lane-block width, and slices the result back, so callers are
shape-agnostic.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qtensor import QuantizedTensor
from repro.kernels.bcq_mm import bcq_mm as _bcq_mm
from repro.kernels.lutgemm import lutgemm as _lutgemm
from repro.kernels.ref import bcq_mm_ref as _bcq_mm_ref

_SUBLANE = 8


def _pick_block(dim: int, candidates=(512, 256, 128, 64)) -> int:
    for c in candidates:
        if dim % c == 0:
            return c
    return 0  # caller pads


def quantized_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jax.Array:
    """``x (..., k) @ qt (k, o)`` → ``(..., o)``."""
    if impl == "auto":
        impl = "bcq_mm" if jax.default_backend() == "tpu" else "ref"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_dtype = out_dtype or x.dtype

    lead = x.shape[:-1]
    k = x.shape[-1]
    if k != qt.k:
        raise ValueError(f"x reduction dim {k} != weight k {qt.k}")
    xb = x.reshape(-1, k)
    B = xb.shape[0]

    if impl == "ref":
        # materialise the reconstruction in x's dtype: bf16 activations get a
        # bf16 dequant (serving path); f32 activations keep the f32 oracle
        w = qt.dequantize(dtype=x.dtype)
        y = jnp.dot(xb, w, preferred_element_type=jnp.float32)
        return y.reshape(*lead, qt.o).astype(out_dtype)

    # --- Pallas paths: pad B to sublane, o to a lane block ---
    block_k = _pick_block(qt.k)
    if block_k == 0:
        raise ValueError(f"k={qt.k} must be divisible by 64 for the Pallas path")
    packed, scales, o = qt.packed, qt.scales, qt.o
    block_o = _pick_block(o)
    if block_o == 0:
        block_o = 128
        pad_o = -o % block_o
        packed = jnp.pad(packed, ((0, 0), (0, 0), (0, pad_o)))
        scales = jnp.pad(scales, ((0, 0), (0, 0), (0, pad_o)))
        o = o + pad_o
    pad_b = -B % _SUBLANE
    if pad_b:
        xb = jnp.pad(xb, ((0, pad_b), (0, 0)))
    # a scale group must not be finer than the k-block constraint allows
    if qt.g <= block_k and block_k % qt.g:
        block_k = qt.g if qt.g in (64, 128, 256, 512) else _pick_block(qt.k, (qt.g,))
        if not block_k:
            raise ValueError(f"g={qt.g} incompatible with k={qt.k} Pallas tiling")

    fn = {"bcq_mm": _bcq_mm, "lutgemm": _lutgemm}[impl]
    y = fn(
        xb,
        packed,
        scales,
        g=qt.g,
        block_k=block_k,
        block_o=block_o,
        interpret=interpret,
    )
    y = y[:B, : qt.o]
    return y.reshape(*lead, qt.o).astype(out_dtype)


def linear(
    x: jax.Array,
    w,
    b: Optional[jax.Array] = None,
    *,
    impl: str = "auto",
    out_dtype=None,
) -> jax.Array:
    """Uniform linear layer: ``w`` is a dense (k, o) array OR a QuantizedTensor.

    Every linear in the model zoo routes through here — the paper's technique as
    a first-class, per-layer-switchable feature.
    """
    out_dtype = out_dtype or x.dtype
    if isinstance(w, QuantizedTensor):
        y = quantized_matmul(x, w, impl=impl, out_dtype=out_dtype)
    else:
        y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32).astype(
            out_dtype
        )
    if b is not None:
        y = y + b.astype(out_dtype)
    return y
