"""The one quantized-matmul dispatch the rest of the framework calls.

``qmatmul(fmt, x, qt, ...)`` is THE entry point for ``x @ W`` against a packed
:class:`~repro.core.qtensor.QuantizedTensor`: it resolves the registered
:class:`~repro.core.formats.QuantFormat` and routes to that format's kernel
entries (DESIGN.md §2.4). No other module branches on a concrete format.

- ``impl="ref"``     the format's dequantize+dot oracle (XLA-fusable). Used by
                     models on CPU and by the dry-run lowering — on a real TPU
                     deployment this HLO region is replaced by the Pallas
                     kernels below.
- ``impl="auto"``    the format's preferred Pallas kernel on TPU backends,
                     ``ref`` elsewhere.
- explicit impls     any of the format's registered kernels — for ``bcq``:
                     ``bcq_mm`` (fused unpack→scale→MXU, TPU-native) and
                     ``lutgemm`` (paper-faithful LUT); ``uniform``:
                     ``uniform_mm``; ``dequant``: ``dequant_mm`` (the explicit
                     dequantize-then-GEMM baseline).

Passing ``out_dims`` runs the *fused multi-projection* path: N projections of
the same activation (QKV, gate-up) whose packed weights were concatenated
along the output dim at weight-prep time (``repro.core.fuse_tensors``) run as
ONE kernel pass and return N outputs — one dispatch, one activation stream
(DESIGN.md §2.3).

Block sizes come from :mod:`repro.kernels.autotune` — measured winners per
``(B, k, o, q, g, impl, backend)``; the ``impl`` axis spans every registered
format's kernels, so per-format winners never collide.

The wrappers normalise leading batch dims, pad B to the sublane width and the
output dim to the lane-block width, and slice the result back, so callers are
shape-agnostic. ``quantized_matmul`` / ``quantized_matmul_fused`` remain as
the historical single-format entry points, now thin shims over ``qmatmul``.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.qtensor import QuantizedTensor
from repro.kernels.bcq_mm_fused import _split

# How ``impl="auto"`` resolves, overridable per-scope via :func:`impl_mode`:
#   None      — the format's own policy (Pallas on TPU, ref elsewhere);
#   "deploy"  — the format's preferred Pallas kernel on EVERY backend
#               (interpret-mode off-TPU). This is the program a TPU deployment
#               actually runs; ``repro.analysis.staticcheck`` traces under it
#               so the dtype-flow pass sees the real packed→kernel dataflow
#               instead of the CPU ref oracle's legitimate dequantize;
#   "ref"     — force the dequantize+dot oracle everywhere (numerics A/B).
_IMPL_MODE: Optional[str] = None


@contextlib.contextmanager
def impl_mode(mode: Optional[str]):
    """Scope an ``impl="auto"`` resolution override (``"deploy"``/``"ref"``).

    Affects only call sites that left ``impl`` at ``"auto"`` — explicit impl
    choices always win. Not thread-safe (module global), matching the
    trace-time usage it exists for.
    """
    global _IMPL_MODE
    if mode not in (None, "deploy", "ref"):
        raise ValueError(f"impl_mode {mode!r}: expected None, 'deploy' or 'ref'")
    prev = _IMPL_MODE
    _IMPL_MODE = mode
    try:
        yield
    finally:
        _IMPL_MODE = prev


def qmatmul(
    fmt,
    x: jax.Array,
    qt: QuantizedTensor,
    out_dims: Optional[Sequence[int]] = None,
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> Tuple[jax.Array, ...]:
    """``x (..., k)`` @ ``qt (k, o)`` through the registered format's kernels.

    ``out_dims=None`` → the single-projection case, returned as a 1-tuple;
    otherwise ``qt`` holds N output-fused projections (``sum(out_dims) ==
    qt.o``) and one kernel pass returns N ``(..., o_i)`` slices.

    ``fmt`` is a registry name or a :class:`~repro.core.formats.QuantFormat`
    instance (imported lazily — this module is the one seam below the format
    registry, so the import edge must point registry → kernels, not back).
    """
    from repro.core.formats import get_format

    f = get_format(fmt) if isinstance(fmt, str) else fmt
    out_dims = (qt.o,) if out_dims is None else tuple(out_dims)
    if sum(out_dims) != qt.o:
        raise ValueError(f"out_dims {out_dims} do not sum to fused o={qt.o}")
    if impl == "auto" and _IMPL_MODE is not None:
        if _IMPL_MODE == "ref":
            impl = "ref"
        else:  # "deploy": the format's preferred Pallas kernel — REQUIRED.
            # A format with no registered kernels must fail loudly here: the
            # old fallthrough left impl="auto", which resolve_impl silently
            # turned into the ref oracle off-TPU — a deploy trace that prices
            # the wrong program (staticcheck records this error as a named
            # skip instead).
            if not f.impls:
                raise ValueError(
                    f"impl_mode('deploy'): format {f.name!r} registers no "
                    "Pallas kernels (impls is empty) — deploy mode cannot "
                    "fall back to the ref oracle; register a kernel or trace "
                    "this format under impl_mode(None)/'ref'"
                )
            impl = f.impls[0]
            if interpret is None:
                interpret = jax.default_backend() != "tpu"
    impl, interpret = f.resolve_impl(impl, interpret)
    out_dtype = out_dtype or x.dtype

    # per-format dispatch accounting on the process-global registry. qmatmul
    # runs at TRACE time (call sites live inside jitted models), so these are
    # traced-kernel-call-site counts per compilation — a compile-time census
    # (which format/impl the program actually lowered) with zero runtime
    # overhead and no host callback in the compiled program. repro.obs is
    # pure stdlib, so kernels → obs adds no import cycle.
    from repro.obs.metrics import default_registry

    default_registry().counter(
        "qmatmul_dispatch_total",
        "qmatmul call sites traced, by format and kernel impl",
        fmt=f.name,
        impl=impl,
    ).inc()

    lead = x.shape[:-1]
    k = x.shape[-1]
    if k != qt.k:
        raise ValueError(f"x reduction dim {k} != weight k {qt.k}")
    xb = x.reshape(-1, k)

    if impl == "ref":
        # materialise the reconstruction in x's dtype: bf16 activations get a
        # bf16 dequant (serving path); f32 activations keep the f32 oracle
        y = f.matmul(xb, qt, dtype=x.dtype)
    else:
        y = f.matvec(xb, qt, impl=impl, interpret=interpret)[:, : qt.o]
    return tuple(
        part.reshape(*lead, d).astype(out_dtype)
        for part, d in zip(_split(y, out_dims), out_dims)
    )


def quantized_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jax.Array:
    """``x (..., k) @ qt (k, o)`` → ``(..., o)`` (single-projection shim)."""
    (y,) = qmatmul(
        qt.fmt, x, qt, impl=impl, interpret=interpret, out_dtype=out_dtype
    )
    return y


def quantized_matmul_fused(
    x: jax.Array,
    qt: QuantizedTensor,
    out_dims: Sequence[int],
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> Tuple[jax.Array, ...]:
    """``x (..., k)`` against N fused projections → N ``(..., o_i)`` outputs
    (fused-projection shim — the decode fast path for QKV and gate-up)."""
    return qmatmul(
        qt.fmt, x, qt, out_dims, impl=impl, interpret=interpret, out_dtype=out_dtype
    )


def linear(
    x: jax.Array,
    w,
    b: Optional[jax.Array] = None,
    *,
    impl: str = "auto",
    out_dtype=None,
) -> jax.Array:
    """Uniform linear layer: ``w`` is a dense (k, o) array OR a QuantizedTensor
    of any registered format.

    Every linear in the model zoo routes through here — the paper's technique as
    a first-class, per-layer-switchable feature.
    """
    out_dtype = out_dtype or x.dtype
    if isinstance(w, QuantizedTensor):
        (y,) = qmatmul(w.fmt, x, w, impl=impl, out_dtype=out_dtype)
    else:
        y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32).astype(
            out_dtype
        )
    if b is not None:
        y = y + b.astype(out_dtype)
    return y


def linear_fused(
    x: jax.Array,
    w,
    out_dims: Sequence[int],
    *,
    impl: str = "auto",
    out_dtype=None,
) -> Tuple[jax.Array, ...]:
    """N projections of one activation from output-fused weights.

    ``w`` is a fused QuantizedTensor (one kernel pass) or a dense
    ``(k, sum(out_dims))`` array (one XLA matmul) — either way the activation
    is read once for all N projections.
    """
    out_dtype = out_dtype or x.dtype
    if isinstance(w, QuantizedTensor):
        return qmatmul(w.fmt, x, w, out_dims, impl=impl, out_dtype=out_dtype)
    y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32).astype(
        out_dtype
    )
    return _split(y, tuple(out_dims))
