"""jit'd dispatch wrappers over the Pallas kernels.

``quantized_matmul`` is THE entry point the rest of the framework uses for
``x @ W`` against a :class:`~repro.core.qtensor.QuantizedTensor`:

- ``impl="ref"``      pure-jnp dequantize+dot (XLA-fusable). Used by models on
                      CPU and by the dry-run lowering — on a real TPU deployment
                      this HLO region is replaced by the Pallas kernels below.
- ``impl="bcq_mm"``   fused unpack→scale→MXU Pallas kernel (TPU-native variant).
- ``impl="lutgemm"``  paper-faithful LUT kernel.
- ``impl="auto"``     bcq_mm on TPU backends, ref elsewhere.

``quantized_matmul_fused`` is the decode fast path: N projections of the same
activation (QKV, gate-up) whose packed weights were concatenated along the
output dim at weight-prep time (``repro.core.fuse_tensors``) run as ONE kernel
pass and return N outputs — one dispatch, one activation stream (DESIGN.md
§2.3).

Block sizes come from :mod:`repro.kernels.autotune` — measured winners per
``(B, k, o, q, g, impl, backend)`` with a JSON-persisted table and the old
hardcoded preference order as the safe fallback (``REPRO_AUTOTUNE=0`` opts out
of measurement).

The wrappers normalise leading batch dims, pad B to the sublane width and the
output dim to the lane-block width, and slice the result back, so callers are
shape-agnostic.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.qtensor import QuantizedTensor
from repro.kernels import autotune
from repro.kernels.bcq_mm import bcq_mm as _bcq_mm
from repro.kernels.bcq_mm_fused import _split
from repro.kernels.lutgemm import lutgemm as _lutgemm
from repro.kernels.ref import bcq_mm_ref as _bcq_mm_ref

_SUBLANE = 8
_LANE = 128


def _resolve(impl: str, interpret: Optional[bool]) -> Tuple[str, bool]:
    if impl == "auto":
        impl = "bcq_mm" if jax.default_backend() == "tpu" else "ref"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return impl, interpret


def _pad_o(packed, scales, o: int):
    """Pad the output dim to the lane block when no candidate divides it."""
    if any(o % c == 0 for c in autotune._CANDIDATE_O):
        return packed, scales, o
    pad = -o % _LANE
    packed = jnp.pad(packed, ((0, 0), (0, 0), (0, pad)))
    scales = jnp.pad(scales, ((0, 0), (0, 0), (0, pad)))
    return packed, scales, o + pad


def _pallas_mm(xb, qt: QuantizedTensor, impl: str, interpret: bool) -> jax.Array:
    """Padded (B, k) @ qt → (B, o_padded) f32 through the chosen Pallas kernel."""
    packed, scales, o = _pad_o(qt.packed, qt.scales, qt.o)
    B = xb.shape[0]
    pad_b = -B % _SUBLANE
    if pad_b:
        xb = jnp.pad(xb, ((0, pad_b), (0, 0)))
    block_k, block_o = autotune.get_blocks(
        B=xb.shape[0], k=qt.k, o=o, q=qt.q, g=qt.g, impl=impl, interpret=interpret
    )
    if not block_k:
        raise ValueError(f"k={qt.k} has no valid Pallas tiling (g={qt.g})")
    if not block_o:
        raise ValueError(f"o={o} has no valid Pallas tiling")
    fn = {"bcq_mm": _bcq_mm, "lutgemm": _lutgemm}[impl]
    y = fn(
        xb,
        packed,
        scales,
        g=qt.g,
        block_k=block_k,
        block_o=block_o,
        interpret=interpret,
    )
    return y[:B]


def quantized_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jax.Array:
    """``x (..., k) @ qt (k, o)`` → ``(..., o)`` (the single-projection case
    of :func:`quantized_matmul_fused`)."""
    (y,) = quantized_matmul_fused(
        x, qt, (qt.o,), impl=impl, interpret=interpret, out_dtype=out_dtype
    )
    return y


def quantized_matmul_fused(
    x: jax.Array,
    qt: QuantizedTensor,
    out_dims: Sequence[int],
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> Tuple[jax.Array, ...]:
    """``x (..., k)`` against N fused projections → N ``(..., o_i)`` outputs.

    ``qt`` holds the projections concatenated along the output dim
    (:func:`repro.core.fuse_tensors`); ``sum(out_dims) == qt.o``. One kernel
    dispatch serves all N projections — the decode fast path for QKV and
    gate-up (DESIGN.md §2.3).
    """
    out_dims = tuple(out_dims)
    if sum(out_dims) != qt.o:
        raise ValueError(f"out_dims {out_dims} do not sum to fused o={qt.o}")
    impl, interpret = _resolve(impl, interpret)
    out_dtype = out_dtype or x.dtype

    lead = x.shape[:-1]
    k = x.shape[-1]
    if k != qt.k:
        raise ValueError(f"x reduction dim {k} != weight k {qt.k}")
    xb = x.reshape(-1, k)

    if impl == "ref":
        # materialise the reconstruction in x's dtype: bf16 activations get a
        # bf16 dequant (serving path); f32 activations keep the f32 oracle
        w = qt.dequantize(dtype=x.dtype)
        y = jnp.dot(xb, w, preferred_element_type=jnp.float32)
    else:
        y = _pallas_mm(xb, qt, impl, interpret)[:, : qt.o]
    return tuple(
        part.reshape(*lead, d).astype(out_dtype)
        for part, d in zip(_split(y, out_dims), out_dims)
    )


def linear(
    x: jax.Array,
    w,
    b: Optional[jax.Array] = None,
    *,
    impl: str = "auto",
    out_dtype=None,
) -> jax.Array:
    """Uniform linear layer: ``w`` is a dense (k, o) array OR a QuantizedTensor.

    Every linear in the model zoo routes through here — the paper's technique as
    a first-class, per-layer-switchable feature.
    """
    out_dtype = out_dtype or x.dtype
    if isinstance(w, QuantizedTensor):
        y = quantized_matmul(x, w, impl=impl, out_dtype=out_dtype)
    else:
        y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32).astype(
            out_dtype
        )
    if b is not None:
        y = y + b.astype(out_dtype)
    return y


def linear_fused(
    x: jax.Array,
    w,
    out_dims: Sequence[int],
    *,
    impl: str = "auto",
    out_dtype=None,
) -> Tuple[jax.Array, ...]:
    """N projections of one activation from output-fused weights.

    ``w`` is a fused QuantizedTensor (one kernel pass) or a dense
    ``(k, sum(out_dims))`` array (one XLA matmul) — either way the activation
    is read once for all N projections.
    """
    out_dtype = out_dtype or x.dtype
    if isinstance(w, QuantizedTensor):
        return quantized_matmul_fused(x, w, out_dims, impl=impl, out_dtype=out_dtype)
    y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32).astype(
        out_dtype
    )
    return _split(y, tuple(out_dims))
