"""Arbitrary-codebook matvec Pallas kernel (FLUTE-style LUT generalization).

``y = x @ Ŵ`` with ``Ŵ[r, c] = T[code[r, c], group(r), c]`` consumed
**directly in packed form**: ``code`` are unsigned ``q``-bit centroid indices
stored as ``q`` bit planes (the shared physical layout —
``core/packing.py::pack_codes``, identical bytes to the uniform int-q
planes) and ``T`` is a per-(group, column) table of ``2^q`` learned scalar
centroids (k-means, or the fixed NF4 grid). This is the paper's LUT
mechanism generalized exactly as FLUTE does: where ``lutgemm.py``'s VMEM
table holds the ``2^mu`` partial dots of activation chunks against *sign
patterns*, here the table is the codebook itself — the index planes are the
LUT keys, a vectorised ``take_along_axis`` is the retrieve, and the MXU
contracts the decoded block against the activations. The centroid table
rides the scales BlockSpec into VMEM (``2^q · groups · bo`` floats per grid
step — priced by ``vmem_bytes`` below and budget-gated by
``kernels/introspect.py``), so the dense weight never exists in HBM.

Grid, accumulator and dimension semantics mirror ``bcq_mm.py``: a float32
VMEM ``scratch_shapes`` accumulator persists across the sequential k steps,
the HBM output block is written once on the last k step, and the o dimension
is ``parallel`` while k is ``arbitrary`` (DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
DEFAULT_BLOCK_O = 128


def vmem_bytes(*, B: int, block_k: int, block_o: int, q: int, g: int) -> int:
    """Per-grid-step VMEM estimate (``kernels/introspect.py``): bcq_mm's
    input/output pipeline with the ``(2^q, groups, bo)`` centroid table in
    place of scale planes, plus the unpacked index planes, the reassembled
    codes and the gathered weight block the body materialises — the
    ``2^q``-proportional table term is what caps ``block_o`` differently
    from the sign-plane kernels (the autotuner rationale)."""
    from repro.kernels.introspect import scales_block_rows

    groups = scales_block_rows(block_k, g)
    io = 2 * (
        B * block_k * 4  # x block, f32
        + q * (block_k // 8) * block_o  # packed index planes, uint8
        + (1 << q) * groups * block_o * 4  # centroid table block (<= f32)
        + B * block_o * 4  # out block, f32
    )
    body = (
        q * block_k * block_o * 4  # unpacked index bit planes
        + block_k * block_o * 4  # reassembled int32 codes
        + block_k * block_o * 4  # gathered (decoded) weight block
        + B * block_o * 4  # acc scratch
    )
    return io + body


def _unpack_indices_block(packed: jax.Array) -> jax.Array:
    """uint8 (q, bk/8, bo) bit planes → int32 centroid indices (bk, bo)."""
    q, kc, bo = packed.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (1, 1, 8, 1), 2)
    bits = (packed[:, :, None, :] >> shifts) & jnp.uint8(1)  # (q, kc, 8, bo)
    planes = bits.reshape(q, kc * 8, bo).astype(jnp.int32)
    # q is static (<= 8): unroll the weighted plane sum with Python int
    # weights 2^i — Pallas kernels may not capture array constants
    codes = planes[0]
    for i in range(1, q):
        codes = codes + planes[i] * (1 << i)
    return codes  # (bk, bo)


def _codebook_mm_kernel(
    x_ref, packed_ref, scales_ref, out_ref, acc_ref, *, g: int, bk: int, compute_dtype
):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_indices_block(packed_ref[...])  # (bk, bo) int32
    table = scales_ref[...].astype(compute_dtype)  # (2^q, bk//g or 1, bo)
    n_cent, gb, bo = table.shape

    # LUT retrieve: per (group, column) codebook, per-element index — group
    # the code rows, move the centroid axis inboard, gather along it. gb is
    # the number of whole scale groups this k-block spans (>= 1: when
    # g > block_k the whole block lies inside one group).
    rows_per_group = bk // gb
    cent = jnp.swapaxes(table, 0, 1)  # (gb, 2^q, bo)
    idx = codes.reshape(gb, rows_per_group, bo)
    w_eff = jnp.take_along_axis(cent, idx, axis=1).reshape(bk, bo)

    x = x_ref[...].astype(compute_dtype)
    acc_ref[...] += jnp.dot(x, w_eff, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def codebook_mm_call(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int,
    block_o: int,
    interpret: bool,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Unjitted pallas_call core (fused multi-projection dispatch reuses it
    via ``ops.qmatmul`` — the fused layout is plain output-dim concatenation)."""
    from repro.kernels.bcq_mm import _validate_tiling

    B, k = x.shape
    q, kc, o = packed.shape
    n_cent = scales.shape[0]
    if n_cent != (1 << q):
        raise ValueError(
            f"codebook table carries {n_cent} centroids but the packed tensor "
            f"has q={q} index planes (expected {1 << q})"
        )
    _validate_tiling(k, o, kc, g, block_k, block_o)

    grid = (o // block_o, k // block_k)
    if g <= block_k:
        scales_spec = pl.BlockSpec(
            (n_cent, block_k // g, block_o), lambda io, ik: (0, ik, io)
        )
    else:
        scales_spec = pl.BlockSpec(
            (n_cent, 1, block_o), lambda io, ik: (0, ik // (g // block_k), io)
        )

    kernel = functools.partial(
        _codebook_mm_kernel, g=g, bk=block_k, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, block_k), lambda io, ik: (0, ik)),
            pl.BlockSpec((q, block_k // 8, block_o), lambda io, ik: (0, ik, io)),
            scales_spec,
        ],
        out_specs=pl.BlockSpec((B, block_o), lambda io, ik: (0, io)),
        out_shape=jax.ShapeDtypeStruct((B, o), jnp.float32),
        scratch_shapes=[pltpu.VMEM((B, block_o), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, packed, scales)


@functools.partial(
    jax.jit, static_argnames=("g", "block_k", "block_o", "interpret", "compute_dtype")
)
def codebook_mm(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int = DEFAULT_BLOCK_K,
    block_o: int = DEFAULT_BLOCK_O,
    interpret: bool = False,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """x (B, k) @ codebook[(q, k/8, o) index planes, (2^q, k/g, o) centroids] → (B, o) f32.

    Constraints are :func:`repro.kernels.bcq_mm.bcq_mm`'s: k % block_k == 0,
    o % block_o == 0, g % 8 == 0 and (block_k % g == 0 or g % block_k == 0).
    ``ops.qmatmul`` pads inputs so callers never see these.
    """
    return codebook_mm_call(
        x,
        packed,
        scales,
        g=g,
        block_k=block_k,
        block_o=block_o,
        interpret=interpret,
        compute_dtype=compute_dtype,
    )


from repro.kernels.introspect import register_vmem_estimator  # noqa: E402

register_vmem_estimator("codebook_mm", vmem_bytes)
