"""Pallas TPU kernels for LUT-GEMM (pl.pallas_call + BlockSpec VMEM tiling).

- ``lutgemm.py``      paper-faithful LUT-based quantized matvec/matmul
- ``bcq_mm.py``       fused unpack→MXU variant (TPU-native, beyond-paper)
- ``bcq_mm_fused.py`` multi-projection (QKV / gate-up) decode fast path
- ``uniform_mm.py``   group-wise uniform int-q matvec (FineQuant-style)
- ``dequant_mm.py``   dequantize-then-GEMM baseline (the paper's comparison)
- ``autotune.py``     measured (block_k, block_o) schedule table
- ``ops.py``          ``qmatmul`` format-registry dispatch (+ pure-JAX fallback)
- ``ref.py``          pure-jnp oracles
"""

from repro.kernels.bcq_mm import bcq_mm
from repro.kernels.bcq_mm_fused import bcq_mm_fused
from repro.kernels.dequant_mm import dequant_mm
from repro.kernels.flash_attn import flash_attention
from repro.kernels.lutgemm import lutgemm
from repro.kernels.ops import (
    linear,
    linear_fused,
    qmatmul,
    quantized_matmul,
    quantized_matmul_fused,
)
from repro.kernels.ref import bcq_mm_ref, lutgemm_tablewise_ref
from repro.kernels.uniform_mm import uniform_mm

__all__ = [
    "bcq_mm",
    "bcq_mm_fused",
    "bcq_mm_ref",
    "dequant_mm",
    "flash_attention",
    "linear",
    "linear_fused",
    "lutgemm",
    "lutgemm_tablewise_ref",
    "qmatmul",
    "quantized_matmul",
    "quantized_matmul_fused",
    "uniform_mm",
]
