"""Pallas TPU kernels for LUT-GEMM (pl.pallas_call + BlockSpec VMEM tiling).

- ``lutgemm.py``      paper-faithful LUT-based quantized matvec/matmul
- ``bcq_mm.py``       fused unpack→MXU variant (TPU-native, beyond-paper)
- ``bcq_mm_fused.py`` multi-projection (QKV / gate-up) decode fast path
- ``autotune.py``     measured (block_k, block_o) schedule table
- ``ops.py``          jit'd dispatch wrappers (+ pure-JAX fallback)
- ``ref.py``          pure-jnp oracles
"""

from repro.kernels.bcq_mm import bcq_mm
from repro.kernels.bcq_mm_fused import bcq_mm_fused
from repro.kernels.flash_attn import flash_attention
from repro.kernels.lutgemm import lutgemm
from repro.kernels.ops import linear, linear_fused, quantized_matmul, quantized_matmul_fused
from repro.kernels.ref import bcq_mm_ref, lutgemm_tablewise_ref

__all__ = [
    "bcq_mm",
    "bcq_mm_fused",
    "bcq_mm_ref",
    "flash_attention",
    "linear",
    "linear_fused",
    "lutgemm",
    "lutgemm_tablewise_ref",
    "quantized_matmul",
    "quantized_matmul_fused",
]
