"""Dequantize-then-matmul baseline kernel — the paper's comparison target.

LUT-GEMM's headline claim (paper §V, Table 3 / Fig. 9) is measured against
kernels that first *materialise* the dense weight from its quantized form and
then run a stock GEMM (the OPTQ/nuQmm serving recipe: dequant kernel +
cuBLAS). This module is that baseline as executable code, on the uniform
int-q packing (``core/formats.py::UniformFormat`` — same packed planes and
affine group scales, so any difference vs ``uniform_mm`` is *pipeline*, not
representation):

1. **dequantize** — a Pallas kernel streams the packed planes block-by-block
   through VMEM, reassembles codes, applies the group affine, and writes the
   dense ``(k, o)`` matrix **back to HBM** (this round trip is exactly the
   overhead the fused kernels avoid — the modeled cost in
   ``benchmarks/kernel_bench.py`` charges ``2·k·o·dtype`` extra HBM bytes);
2. **matmul** — a second dispatch runs the dense dot on the MXU (XLA's
   native GEMM; the cuBLAS analogue).

Two dispatches, one dense-weight HBM round trip, per-launch overhead twice:
strictly more memory traffic than the one-pass kernels at decode batch sizes,
which is the paper's argument reproduced in code.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.uniform_mm import _unpack_codes_block

DEFAULT_BLOCK_K = 512
DEFAULT_BLOCK_O = 256


def vmem_bytes(*, B: int, block_k: int, block_o: int, q: int, g: int) -> int:
    """Per-grid-step VMEM estimate for the *materialise* kernel (the GEMM half
    is a stock XLA dot — XLA owns its tiling). ``B`` does not enter this
    kernel's schedule; it stays in the signature so every estimator prices the
    same key tuple (``kernels/introspect.py``)."""
    from repro.kernels.introspect import scales_block_rows

    del B
    groups = scales_block_rows(block_k, g)
    io = 2 * (
        q * (block_k // 8) * block_o  # packed bit planes, uint8
        + 2 * groups * block_o * 4  # (scale, zero) block (<= f32)
        + block_k * block_o * 4  # dense out block, f32
    )
    body = (
        q * block_k * block_o * 4  # unpacked bit planes
        + 2 * block_k * block_o * 4  # reassembled codes + affine w
    )
    return io + body


def _dequant_kernel(packed_ref, scales_ref, out_ref, *, g: int, bk: int, out_dtype):
    codes = _unpack_codes_block(packed_ref[...], jnp.float32)  # (bk, bo)
    scales = scales_ref[...].astype(jnp.float32)  # (2, bk//g or 1, bo)
    s, z = scales[0], scales[1]
    bk_, bo = codes.shape
    if g <= bk:
        w = (codes.reshape(bk // g, g, bo) * s[:, None, :] + z[:, None, :]).reshape(
            bk, bo
        )
    else:
        w = codes * s + z
    out_ref[...] = w.astype(out_dtype)


def dequant_materialize(
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int = DEFAULT_BLOCK_K,
    block_o: int = DEFAULT_BLOCK_O,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Packed uniform planes → dense ``(k, o)`` weight, written to HBM.

    The grid tiles ``(k, o)``; every cell unpacks + scales its block in VMEM
    and stores the dense block — the standalone "dequant kernel" half of the
    baseline. Tiling constraints are the shared ones (``bcq_mm.py``).
    """
    from repro.kernels.bcq_mm import _validate_tiling

    q, kc, o = packed.shape
    k = kc * 8
    _validate_tiling(k, o, kc, g, block_k, block_o)

    if g <= block_k:
        scales_spec = pl.BlockSpec(
            (2, block_k // g, block_o), lambda ik, io: (0, ik, io)
        )
    else:
        scales_spec = pl.BlockSpec(
            (2, 1, block_o), lambda ik, io: (0, ik // (g // block_k), io)
        )
    kernel = functools.partial(
        _dequant_kernel, g=g, bk=block_k, out_dtype=out_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=(k // block_k, o // block_o),
        in_specs=[
            pl.BlockSpec((q, block_k // 8, block_o), lambda ik, io: (0, ik, io)),
            scales_spec,
        ],
        out_specs=pl.BlockSpec((block_k, block_o), lambda ik, io: (ik, io)),
        out_shape=jax.ShapeDtypeStruct((k, o), out_dtype),
        interpret=interpret,
    )(packed, scales)


@functools.partial(
    jax.jit, static_argnames=("g", "block_k", "block_o", "interpret")
)
def dequant_mm(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int = DEFAULT_BLOCK_K,
    block_o: int = DEFAULT_BLOCK_O,
    interpret: bool = False,
) -> jax.Array:
    """x (B, k) @ uniform-packed weights via dequantize-into-HBM + dense GEMM.

    Same contract as :func:`repro.kernels.uniform_mm.uniform_mm`; deliberately
    the slow way round (two dispatches, dense round trip) — this is the
    baseline side of the paper's kernel comparison, not a serving path.
    """
    w = dequant_materialize(
        packed, scales, g=g, block_k=block_k, block_o=block_o,
        interpret=interpret, out_dtype=jnp.float32,
    )
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


from repro.kernels.introspect import register_vmem_estimator  # noqa: E402

register_vmem_estimator("dequant_mm", vmem_bytes)
