"""Kernel BlockSpec introspection: per-grid-step VMEM estimates + budgets.

Every Pallas kernel module in this package exports a ``vmem_bytes`` hook that
prices ONE grid step of its own schedule from the same ``(B, block_k,
block_o, q, g)`` parameters its ``pl.pallas_call`` derives its BlockSpecs
from: the HBM→VMEM input/output blocks (counted twice — Mosaic
double-buffers the pipeline copies), the ``scratch_shapes`` accumulator, and
the dominant in-register intermediates the kernel body materialises (the
unpacked sign/code planes, the LUT and its gathered partials). The estimate
is deliberately a slight over-count: it is a *budget gate*, not a profiler —
``repro.analysis.staticcheck`` and ``kernels/autotune.py`` use it to reject
schedules that cannot fit before Mosaic ever sees them.

The budget constant is the TPU architecture number (VMEM ≈ 16 MB/core — the
on-chip vector memory that feeds the compute units; see the Pallas/TPU
memory-hierarchy table). ``VMEM_SLACK`` reserves headroom for Mosaic's own
spills/semaphores so "fits the estimate" implies "compiles and runs".
"""

from __future__ import annotations

from typing import Callable, Dict

# TPU VMEM is ~16 MB per core; keep a safety margin for Mosaic-managed
# buffers (semaphores, spills, the grid bookkeeping) on top of our estimate.
VMEM_BYTES = 16 * 1024 * 1024
VMEM_SLACK = 0.9  # usable fraction of VMEM_BYTES the estimate may claim
F32 = 4

# impl name -> vmem_bytes hook, lazily populated so importing this module
# never forces the kernel imports (mirrors autotune.register_measure_kernel).
_ESTIMATORS: Dict[str, Callable[..., int]] = {}


def scales_block_rows(block_k: int, g: int) -> int:
    """Rows of the per-grid-step scales/table block: ``max(block_k // g, 1)``.

    This is the SAME expression every kernel's scales BlockSpec uses
    (``block_k // g`` when ``g <= block_k``, else ``1`` — the whole k-block
    lies inside one group), factored out so the VMEM estimators and the
    kernels cannot drift: under the kernels' validated divisibility contract
    (``block_k % g == 0 or g % block_k == 0``) the floor IS the exact block
    row count, never an undercount of a ceil-sized block
    (tests/test_formats.py property-checks the agreement at ragged shapes)."""
    return max(block_k // g, 1)


def register_vmem_estimator(impl: str, fn: Callable[..., int]) -> None:
    """Register ``impl``'s per-grid-step VMEM estimator (kernel modules call
    this at import; ``fn(B=, block_k=, block_o=, q=, g=) -> bytes``)."""
    _ESTIMATORS[impl] = fn


def _ensure_loaded() -> None:
    # the six in-tree kernels self-register on import; new formats register
    # their own hooks from their kernel modules (DESIGN.md §10)
    import repro.kernels.bcq_mm  # noqa: F401
    import repro.kernels.codebook_mm  # noqa: F401
    import repro.kernels.dequant_mm  # noqa: F401
    import repro.kernels.lutgemm  # noqa: F401
    import repro.kernels.ternary_mm  # noqa: F401
    import repro.kernels.uniform_mm  # noqa: F401


def known_impls():
    _ensure_loaded()
    return tuple(sorted(_ESTIMATORS))


def vmem_bytes(impl: str, *, B: int, block_k: int, block_o: int, q: int, g: int) -> int:
    """Estimated per-grid-step VMEM bytes for ``impl``'s schedule.

    Raises ``KeyError`` for impls with no registered estimator (callers that
    merely *gate* — e.g. autotune table validation — treat unknown impls as
    unpriceable and skip the budget check rather than guessing)."""
    _ensure_loaded()
    n = _ESTIMATORS[impl](B=B, block_k=block_k, block_o=block_o, q=q, g=g)
    return int(n)


def vmem_budget() -> int:
    """Bytes one grid step may claim under the slack-adjusted VMEM budget."""
    return int(VMEM_BYTES * VMEM_SLACK)


def fits_budget(impl: str, *, B: int, block_k: int, block_o: int, q: int, g: int) -> bool:
    return vmem_bytes(impl, B=B, block_k=block_k, block_o=block_o, q=q, g=g) <= vmem_budget()
