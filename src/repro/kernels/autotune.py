"""Measured (block_k, block_o) autotuner for the BCQ Pallas kernels.

The first version of ``ops.quantized_matmul`` hardcoded ``(512, 256, 128, 64)``
preference-ordered block candidates — a single schedule for every shape, batch
and kernel variant. But the best tiling is shape-dependent: decode (B=1) wants
wide output blocks to amortise the activation fetch, GQA K/V projections have
small output dims, and the LUT kernel's VMEM budget (a ``(B, C, 256)`` table
per k-block) caps ``block_k`` differently from the unpack kernel. FLUTE
(Guo et al., 2024) makes the same point for GPU LUT kernels.

Resolution order for a ``(B, k, o, q, g, impl, backend)`` query:

1. **in-process cache** — one dict lookup after the first query;
2. **persisted JSON tables** — the checked-in defaults
   (``autotune_table.json`` next to this module, common decode/config shapes)
   and the user cache (``$REPRO_AUTOTUNE_CACHE``, default
   ``~/.cache/repro/autotune.json``);
3. **measurement** — unless ``REPRO_AUTOTUNE=0``, sweep the valid candidate
   grid with synthetic inputs, pick the fastest, persist the winner;
4. **heuristic fallback** — the old preference order (largest dividing block),
   so unknown shapes and opted-out runs behave exactly like the pre-autotuner
   dispatch. This is also the no-measurement answer for shapes the tables
   don't know.

Keys deliberately include the backend (``cpu``/``tpu``/… plus ``-interpret``)
so CPU interpret-mode timings can never masquerade as TPU schedules. The
``impl`` axis spans every registered quantization format's kernels
(``bcq_mm``/``lutgemm``/``uniform_mm``/… — formats register their kernels
for measurement via :func:`register_measure_kernel`, DESIGN.md §2.4), so
per-format winners never collide.

Reproducibility note: ``block_k`` partitions the f32 accumulation, so two
hosts that measure different winners can produce bitwise-different logits
(same math, different reduction split). For cross-host bit-reproducibility
set ``REPRO_AUTOTUNE=0`` — the heuristic/table path is fully deterministic —
or ship a pinned table via ``REPRO_AUTOTUNE_CACHE``. The test suite pins
``REPRO_AUTOTUNE=0`` for exactly this reason (tests/conftest.py).
"""

from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_CANDIDATE_K = (1024, 512, 256, 128, 64)
_CANDIDATE_O = (512, 256, 128, 64)
_PICK_ORDER = (512, 256, 128, 64)  # legacy heuristic preference order

_TABLE_PATH = os.path.join(os.path.dirname(__file__), "autotune_table.json")

# in-process winners: key -> (block_k, block_o)
_cache: Dict[str, Tuple[int, int]] = {}
_persisted_loaded = False


def _user_cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune.json"),
    )


def measurement_enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def make_key(B: int, k: int, o: int, q: int, g: int, impl: str, backend: str) -> str:
    return f"{impl}/{backend}/B{B}/k{k}/o{o}/q{q}/g{g}"


def backend_tag(interpret: bool) -> str:
    tag = jax.default_backend()
    return f"{tag}-interpret" if interpret else tag


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------


def parse_key(key: str) -> Tuple[str, str, int, int, int, int, int]:
    """``make_key`` output → ``(impl, backend, B, k, o, q, g)``.

    Raises ``ValueError`` naming the key on any malformed segment — table
    entries that cannot be parsed cannot be trusted to describe a schedule.
    """
    parts = key.split("/")
    if len(parts) != 7:
        raise ValueError(
            f"autotune key {key!r}: expected impl/backend/B*/k*/o*/q*/g*"
        )
    impl, backend = parts[0], parts[1]
    nums = []
    for tag, seg in zip("Bkoqg", parts[2:]):
        if not seg.startswith(tag) or not seg[len(tag):].isdigit():
            raise ValueError(
                f"autotune key {key!r}: segment {seg!r} is not {tag}<int>"
            )
        nums.append(int(seg[len(tag):]))
    if not impl or not backend:
        raise ValueError(f"autotune key {key!r}: empty impl/backend segment")
    return (impl, backend, *nums)


def validate_entry(key: str, blocks, *, path: str = "<in-memory>") -> Tuple[int, int]:
    """One table entry → validated ``(block_k, block_o)``; loud on any lie.

    Checks, in order: key parses; blocks is a pair of positive ints; the
    blocks satisfy the kernels' divisibility contract (``_valid_bk`` +
    ``o % block_o``); and — for real-hardware backends only (interpret-mode
    entries have no VMEM) — the impl's registered per-grid-step estimate
    fits :func:`repro.kernels.introspect.vmem_budget`. Impls with no
    registered estimator skip the budget check (unpriceable ≠ invalid).
    """
    impl, backend, B, k, o, q, g = parse_key(key)
    if (
        not isinstance(blocks, (list, tuple))
        or len(blocks) != 2
        or not all(isinstance(b, int) and b > 0 for b in blocks)
    ):
        raise ValueError(
            f"autotune table {path}: entry {key!r} blocks {blocks!r} "
            "must be a [block_k, block_o] pair of positive ints"
        )
    bk, bo = blocks
    if not _valid_bk(bk, k, g) or o % bo:
        raise ValueError(
            f"autotune table {path}: entry {key!r} blocks ({bk}, {bo}) violate "
            f"the tiling contract (k={k} % block_k == 0, o={o} % block_o == 0, "
            f"block_k % g == 0 or g % block_k == 0 with g={g})"
        )
    if not backend.endswith("-interpret"):
        from repro.kernels import introspect

        try:
            need = introspect.vmem_bytes(impl, B=B, block_k=bk, block_o=bo, q=q, g=g)
        except KeyError:
            return bk, bo
        budget = introspect.vmem_budget()
        if need > budget:
            raise ValueError(
                f"autotune table {path}: entry {key!r} blocks ({bk}, {bo}) "
                f"need ~{need} B of VMEM per grid step, over the "
                f"{budget} B budget ({introspect.VMEM_BYTES} B/core x "
                f"{introspect.VMEM_SLACK} slack) — re-measure with smaller blocks"
            )
    return bk, bo


def validate_table(table: Dict[str, Tuple[int, int]], *, path: str) -> None:
    for key, blocks in table.items():
        validate_entry(key, blocks, path=path)


def _load_table(path: str) -> Dict[str, Tuple[int, int]]:
    """Read one persisted table. Missing file → empty (tables are optional);
    unparseable JSON → loud ``ValueError`` naming the file (a corrupt table
    silently dropped would re-measure — or worse, heuristically guess —
    schedules the operator thinks are pinned)."""
    try:
        with open(path) as f:
            raw = json.load(f)
    except OSError:
        return {}
    except ValueError as e:
        raise ValueError(f"autotune table {path} is not valid JSON: {e}") from e
    if not isinstance(raw, dict):
        raise ValueError(f"autotune table {path}: top level must be an object")
    return {k: tuple(v) if isinstance(v, list) else v for k, v in raw.items()}


def _ensure_persisted_loaded() -> None:
    global _persisted_loaded
    if _persisted_loaded:
        return
    # user cache wins over checked-in defaults: it was measured on this host
    merged = _load_table(_TABLE_PATH)
    validate_table(merged, path=_TABLE_PATH)
    user = _load_table(_user_cache_path())
    validate_table(user, path=_user_cache_path())
    merged.update(user)
    for key, blocks in merged.items():
        _cache.setdefault(key, blocks)
    _persisted_loaded = True


def _persist(key: str, blocks: Tuple[int, int]) -> None:
    path = _user_cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        try:
            table = _load_table(path)
        except ValueError:
            table = {}  # corrupt user cache: start over rather than refuse to tune
        table[key] = blocks
        with open(path, "w") as f:
            json.dump({k: list(v) for k, v in sorted(table.items())}, f, indent=1)
    except OSError:
        pass  # read-only filesystem: in-process cache still holds the winner


def clear_cache() -> None:
    """Drop in-process state (tests; does not touch persisted files)."""
    global _persisted_loaded
    _cache.clear()
    _persisted_loaded = False


# ---------------------------------------------------------------------------
# candidates + heuristic
# ---------------------------------------------------------------------------


def _valid_bk(c: int, k: int, g: int) -> bool:
    return k % c == 0 and (c % g == 0 or g % c == 0)


def candidate_blocks(k: int, o: int, g: int) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Valid (block_k, block_o) candidate axes for a padded (k, o, g)."""
    bks = tuple(c for c in _CANDIDATE_K if _valid_bk(c, k, g))
    if not bks:
        # irregular group size (e.g. g=96): fall back to g-aligned blocks
        bks = tuple(m * g for m in (8, 4, 2, 1) if m * g <= k and k % (m * g) == 0)
    bos = tuple(c for c in _CANDIDATE_O if o % c == 0)
    return bks, bos


def heuristic_blocks(k: int, o: int, g: int) -> Tuple[int, int]:
    """The pre-autotuner choice: largest preference-ordered dividing block."""
    bk = next((c for c in _PICK_ORDER if k % c == 0 and _valid_bk(c, k, g)), 0)
    if not bk:
        bks, _ = candidate_blocks(k, o, g)
        bk = bks[0] if bks else 0
    bo = next((c for c in _PICK_ORDER if o % c == 0), 0)
    return bk, bo


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

# impl name -> (kernel loader, synthetic-scales maker). Formats register their
# Pallas kernels here (core/formats.py) so the measurement sweep covers every
# registered format's schedule space; the impl name is also the table-key axis
# that keeps per-format winners from colliding.
_MEASURE_KERNELS: Dict[str, tuple] = {}


def register_measure_kernel(impl: str, loader, make_scales) -> None:
    """Make ``impl`` measurable: ``loader()`` returns the kernel fn (lazy so
    registration never forces a kernel import); ``make_scales(rng, q, k, o, g)``
    returns that format's synthetic scales array."""
    _MEASURE_KERNELS[impl] = (loader, make_scales)


def _load_bcq_mm():
    from repro.kernels.bcq_mm import bcq_mm

    return bcq_mm


def _load_lutgemm():
    from repro.kernels.lutgemm import lutgemm

    return lutgemm


def _bcq_meas_scales(rng, q, k, o, g):
    return rng.standard_normal((q, k // g, o))


register_measure_kernel("bcq_mm", _load_bcq_mm, _bcq_meas_scales)
register_measure_kernel("lutgemm", _load_lutgemm, _bcq_meas_scales)


def _time_once(fn, *args) -> float:
    out = fn(*args)  # warmup: compile/trace
    jax.block_until_ready(out)  # staticcheck: host-sync(wall-clock timing sweep)
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)  # staticcheck: host-sync(wall-clock timing sweep)
        best = min(best, time.perf_counter() - t0)
    return best


def _measure(
    B: int, k: int, o: int, q: int, g: int, impl: str, interpret: bool
) -> Optional[Tuple[int, int]]:
    """Sweep the candidate grid on synthetic inputs; return the fastest pair.

    INVARIANT: the swept inputs are freshly-created *concrete* arrays (never
    the caller's, which may be tracers — get_blocks runs inside jit traces of
    the model). Concrete inputs keep the sweep executing eagerly on device at
    trace time: real wall-clock timings, nothing staged into the outer jaxpr
    (verified: outer computation stays at its 3-eqn dispatch regardless of
    sweep size). Do not thread caller arrays into here.
    """
    entry = _MEASURE_KERNELS.get(impl)
    if entry is None:
        return None  # unknown impl: caller falls through to the heuristic
    bks, bos = candidate_blocks(k, o, g)
    if not bks or not bos:
        return None
    # keep the sweep bounded: the 3 largest of each axis cover the useful range
    bks, bos = bks[:3], bos[:3]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((B, k)), jnp.float32)
    packed = jnp.asarray(rng.integers(0, 256, (q, k // 8, o)), jnp.uint8)
    scales = jnp.asarray(entry[1](rng, q, k, o, g), jnp.float32)
    fn = entry[0]()

    best, best_t = None, float("inf")
    for bk in bks:
        for bo in bos:
            try:
                t = _time_once(
                    functools.partial(
                        fn, g=g, block_k=bk, block_o=bo, interpret=interpret
                    ),
                    x,
                    packed,
                    scales,
                )
            except Exception:
                continue  # candidate doesn't compile/fit — skip it
            if t < best_t:
                best, best_t = (bk, bo), t
    return best


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def get_blocks(
    *,
    B: int,
    k: int,
    o: int,
    q: int,
    g: int,
    impl: str,
    interpret: bool,
    allow_measure: Optional[bool] = None,
) -> Tuple[int, int]:
    """Best known (block_k, block_o) for a padded kernel shape.

    Never raises on unknown shapes: resolution falls through cache → tables →
    measurement (when enabled) → the legacy heuristic. Returns ``(0, 0)`` only
    when no valid tiling exists at all (caller decides how to pad or fail).
    """
    _ensure_persisted_loaded()
    backend = backend_tag(interpret)
    key = make_key(B, k, o, q, g, impl, backend)
    hit = _cache.get(key)
    if hit is not None and _valid_bk(hit[0], k, g) and o % hit[1] == 0:
        return hit

    if allow_measure is None:
        allow_measure = measurement_enabled()
    if allow_measure:
        measured = _measure(B, k, o, q, g, impl, interpret)
        if measured is not None:
            _cache[key] = measured
            _persist(key, measured)
            return measured

    blocks = heuristic_blocks(k, o, g)
    if blocks[0] and blocks[1]:
        _cache[key] = blocks  # memoise so the divisibility scan runs once
    return blocks
