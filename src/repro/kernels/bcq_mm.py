"""Fused BCQ matmul Pallas kernel — the TPU-native LUT-GEMM variant.

``y = x @ Ŵ`` with ``Ŵ = Σ_i alpha_i ∘ b_i`` consumed **directly in packed
form**: each grid step unpacks a ``(q, bk/8, bo)`` byte block to ±1 signs with
VPU shift/mask ops, applies group scales in VMEM registers, and feeds the MXU —
the dequantized block never exists in HBM (paper's "no dequantization overhead"
requirement, §III).

Why this beats a literal LUT port on TPU (DESIGN.md §2): the paper's LUT
replaces *bit-level arithmetic* that GPUs do poorly; TPUs unpack bits for free
on the VPU while a per-byte LUT *gather* is the expensive part. Both are
implemented (see ``lutgemm.py``) and compared in benchmarks.

Grid: ``(o_blocks, k_blocks)`` with k fastest. Partial sums live in a float32
VMEM ``scratch_shapes`` accumulator that persists across the sequential k
steps; the HBM output block is written exactly once, on the last k step
(DESIGN.md §2 — the deterministic replacement for the paper's atomicAdd,
without the ``out_ref`` read-modify-write HBM round-trip per k step that the
first version paid). The o dimension is declared ``parallel`` so Mosaic may
split output blocks across cores; k is ``arbitrary`` (sequential, carries the
accumulator).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
DEFAULT_BLOCK_O = 256


def vmem_bytes(*, B: int, block_k: int, block_o: int, q: int, g: int) -> int:
    """Per-grid-step VMEM estimate for this kernel's BlockSpecs (see
    ``kernels/introspect.py``): double-buffered x/packed/scales/out blocks,
    the f32 accumulator scratch, and the unpacked sign planes + effective
    weight block the body materialises."""
    from repro.kernels.introspect import scales_block_rows

    groups = scales_block_rows(block_k, g)
    io = 2 * (
        B * block_k * 4  # x block, f32
        + q * (block_k // 8) * block_o  # packed block, uint8
        + q * groups * block_o * 4  # scales block (<= f32)
        + B * block_o * 4  # out block, f32
    )
    body = (
        q * block_k * block_o * 4  # unpacked ±1 signs
        + block_k * block_o * 4  # w_eff
        + B * block_o * 4  # acc scratch
    )
    return io + body


def _unpack_block(packed: jax.Array, compute_dtype) -> jax.Array:
    """uint8 (q, bk/8, bo) → ±1 (q, bk, bo) in compute_dtype (VPU shift/mask)."""
    q, kc, bo = packed.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (1, 1, 8, 1), 2)
    bits = (packed[:, :, None, :] >> shifts) & jnp.uint8(1)  # (q, kc, 8, bo)
    signs = 2.0 * bits.astype(compute_dtype) - 1.0
    return signs.reshape(q, kc * 8, bo)


def _bcq_mm_kernel(
    x_ref, packed_ref, scales_ref, out_ref, acc_ref, *, g: int, bk: int, compute_dtype
):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    signs = _unpack_block(packed_ref[...], compute_dtype)  # (q, bk, bo)
    scales = scales_ref[...].astype(compute_dtype)  # (q, bk//g or 1, bo)
    q, _, bo = signs.shape

    if g <= bk:
        # scales block carries bk//g groups — expand each over its g rows
        w = (signs.reshape(q, bk // g, g, bo) * scales[:, :, None, :]).sum(0)
        w_eff = w.reshape(bk, bo)
    else:
        # whole k-block lies inside one scale group: scales block is (q, 1, bo)
        w_eff = (signs * scales).sum(0)

    x = x_ref[...].astype(compute_dtype)
    acc_ref[...] += jnp.dot(x, w_eff, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _validate_tiling(k, o, kc, g, block_k, block_o, mu=8):
    """Shared tiling constraints for the BCQ Pallas kernels."""
    if kc * mu != k:
        raise ValueError(f"packed k dim {kc}*{mu} != x k dim {k}")
    if k % block_k or o % block_o:
        raise ValueError(f"(k={k}, o={o}) must be divisible by ({block_k}, {block_o})")
    if g % mu or not (block_k % g == 0 or g % block_k == 0):
        raise ValueError(f"g={g} incompatible with block_k={block_k}")


def bcq_mm_call(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int,
    block_o: int,
    interpret: bool,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Unjitted pallas_call core, shared with the fused multi-projection
    wrapper (``bcq_mm_fused.py``) so both dispatch the identical kernel."""
    B, k = x.shape
    q, kc, o = packed.shape
    _validate_tiling(k, o, kc, g, block_k, block_o)

    grid = (o // block_o, k // block_k)
    if g <= block_k:
        scales_spec = pl.BlockSpec(
            (q, block_k // g, block_o), lambda io, ik: (0, ik, io)
        )
    else:
        scales_spec = pl.BlockSpec(
            (q, 1, block_o), lambda io, ik: (0, ik // (g // block_k), io)
        )

    kernel = functools.partial(
        _bcq_mm_kernel, g=g, bk=block_k, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, block_k), lambda io, ik: (0, ik)),
            pl.BlockSpec((q, block_k // 8, block_o), lambda io, ik: (0, ik, io)),
            scales_spec,
        ],
        out_specs=pl.BlockSpec((B, block_o), lambda io, ik: (0, io)),
        out_shape=jax.ShapeDtypeStruct((B, o), jnp.float32),
        scratch_shapes=[pltpu.VMEM((B, block_o), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, packed, scales)


@functools.partial(
    jax.jit, static_argnames=("g", "block_k", "block_o", "interpret", "compute_dtype")
)
def bcq_mm(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int = DEFAULT_BLOCK_K,
    block_o: int = DEFAULT_BLOCK_O,
    interpret: bool = False,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """x (B, k) @ BCQ[(q, k/8, o) packed, (q, k/g, o) scales] → (B, o) f32.

    Constraints (enforced): k % block_k == 0, o % block_o == 0, g % 8 == 0 and
    (block_k % g == 0 or g % block_k == 0). ``ops.quantized_matmul`` pads inputs
    so callers never see these.
    """
    return bcq_mm_call(
        x,
        packed,
        scales,
        g=g,
        block_k=block_k,
        block_o=block_o,
        interpret=interpret,
        compute_dtype=compute_dtype,
    )


from repro.kernels.introspect import register_vmem_estimator  # noqa: E402

register_vmem_estimator("bcq_mm", vmem_bytes)
