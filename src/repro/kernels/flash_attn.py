"""Blocked causal flash attention (Pallas TPU) — the prefill memory lever.

The XLA fallback materialises (B, H, chunk, S) f32 logits and crosses fusion
boundaries ~5× per softmax (measured ~830 GB/chip on olmoe prefill_32k —
EXPERIMENTS.md §Perf cell B-iter 2). This kernel keeps the (block_q, block_k)
score tile in VMEM with the standard online-softmax recurrence
(Flash-Attention 2 schedule):

    grid = (B·H, n_q_blocks, n_k_blocks)   k innermost (sequential on TPU)
    carry (VMEM scratch): m (running max), l (running denom), acc (block_q, Dh)

Causality is handled per-tile: tiles entirely in the future are skipped via
``pl.when`` (no FLOPs counted on TPU — unlike the masked-dense fallback, which
does 2× the causal-useful work); the diagonal tile applies the triangular
mask. GQA is supported by mapping each of the B·H grid rows to its KV head.

Validated against the jnp oracle in interpret mode (tests/test_flash_attn.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = float(jnp.finfo(jnp.float32).min)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, scale):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip tiles strictly in the future (block-level causality)
    @pl.when(ik * bk <= iq * bq + bq - 1)
    def _compute():
        q = q_ref[0]  # (bq, dh)
        k = k_ref[0]  # (bk, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        # mask within the diagonal tile
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])  # (bq, bk)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Causal flash attention. q: (B, S, H, Dh); k, v: (B, S, Hkv, Dh), GQA.

    Returns (B, S, H, Dh) in q's dtype. S must divide by both block sizes
    (model seq lens are powers of two; callers pad otherwise).
    """
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    grp = h // hkv
    if s % block_q or s % block_k:
        raise ValueError(f"seq {s} must divide block sizes ({block_q},{block_k})")
    scale = 1.0 / (dh ** 0.5)

    # layout: fold batch×head into the leading grid dim
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, dh)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, dh)

    grid = (b * h, s // block_q, s // block_k)
    kernel = functools.partial(
        _flash_kernel, bq=block_q, bk=block_k, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec(
                (1, block_k, dh), lambda bh, iq, ik, g=grp: (bh // g, ik, 0)
            ),
            pl.BlockSpec(
                (1, block_k, dh), lambda bh, iq, ik, g=grp: (bh // g, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
