"""Fused ternary {-1, 0, +1} matvec Pallas kernel (T-MAC's ``tl2`` layout).

``y = x @ Ŵ`` with ``Ŵ = alpha ∘ t``, ``t ∈ {-1, 0, +1}``, consumed
**directly in packed form**: the ternary codes are stored as TWO bit planes —
plane 0 the *sign* bit (1 → +1), plane 1 the *mask* bit (1 → nonzero) — in
the shared physical layout (``core/packing.py``: 8 codes per byte along k,
LSB-first), plus ONE per-(group, column) magnitude plane ``alpha``. Each grid
step unpacks a ``(2, bk/8, bo)`` byte block with VPU shift/mask ops,
reconstructs ``t = (2·sign − 1) · mask`` in registers, applies the group
magnitudes, and feeds the MXU — the decoded block never exists in HBM (the
paper's "no dequantization overhead" requirement, §III, at 2 stored bits +
one scale per group: the sub-2-bit regime T-MAC serves BitNet-class models
in at memory-bandwidth speed).

Ternary is *masked BCQ*: ``t = 0.5·b1 + 0.5·b2`` with ``b1 = sign | ~mask``
and ``b2 = sign & mask`` — the equivalence ``core/formats.py::TernaryFormat``
exploits to hand self-speculation a nested 1-plane BCQ draft (``truncate``).
This kernel is the direct 2-plane decode; the drafts run through ``bcq_mm``.

Grid, accumulator and dimension semantics mirror ``bcq_mm.py``: a float32
VMEM ``scratch_shapes`` accumulator persists across the sequential k steps,
the HBM output block is written once on the last k step, and the o dimension
is ``parallel`` while k is ``arbitrary`` (DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
DEFAULT_BLOCK_O = 256
PLANES = 2  # sign + mask — fixed; the policy's q is not a free axis here


def vmem_bytes(*, B: int, block_k: int, block_o: int, q: int, g: int) -> int:
    """Per-grid-step VMEM estimate (``kernels/introspect.py``): bcq_mm's
    pipeline shape with 2 packed planes, the single alpha plane, and the
    unpacked sign/mask bits + decoded ternary block the body materialises.
    ``q`` is accepted for the estimator protocol but the layout pins it to 2
    packed planes / 1 scale plane."""
    from repro.kernels.introspect import scales_block_rows

    del q  # ternary stores exactly 2 planes regardless of the policy's q
    groups = scales_block_rows(block_k, g)
    io = 2 * (
        B * block_k * 4  # x block, f32
        + PLANES * (block_k // 8) * block_o  # packed sign+mask planes, uint8
        + 1 * groups * block_o * 4  # alpha block (<= f32)
        + B * block_o * 4  # out block, f32
    )
    body = (
        PLANES * block_k * block_o * 4  # unpacked sign/mask bits
        + 2 * block_k * block_o * 4  # decoded t + scaled w_eff
        + B * block_o * 4  # acc scratch
    )
    return io + body


def _decode_ternary_block(packed: jax.Array, compute_dtype) -> jax.Array:
    """uint8 (2, bk/8, bo) sign+mask planes → t ∈ {-1, 0, +1} (bk, bo)."""
    _, kc, bo = packed.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (1, 1, 8, 1), 2)
    bits = (packed[:, :, None, :] >> shifts) & jnp.uint8(1)  # (2, kc, 8, bo)
    planes = bits.reshape(PLANES, kc * 8, bo).astype(compute_dtype)
    sign = 2.0 * planes[0] - 1.0
    return sign * planes[1]  # mask=0 zeroes the code


def _ternary_mm_kernel(
    x_ref, packed_ref, scales_ref, out_ref, acc_ref, *, g: int, bk: int, compute_dtype
):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = _decode_ternary_block(packed_ref[...], compute_dtype)  # (bk, bo)
    alpha = scales_ref[...].astype(compute_dtype)[0]  # (bk//g or 1, bo)
    bk_, bo = t.shape

    if g <= bk:
        # alpha block carries bk//g groups — expand each over its g rows
        w_eff = (t.reshape(bk // g, g, bo) * alpha[:, None, :]).reshape(bk, bo)
    else:
        # whole k-block lies inside one scale group: alpha rows are (1, bo)
        w_eff = t * alpha

    x = x_ref[...].astype(compute_dtype)
    acc_ref[...] += jnp.dot(x, w_eff, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def ternary_mm_call(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int,
    block_o: int,
    interpret: bool,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Unjitted pallas_call core (fused multi-projection dispatch reuses it
    via ``ops.qmatmul`` — the fused layout is plain output-dim concatenation)."""
    from repro.kernels.bcq_mm import _validate_tiling

    B, k = x.shape
    planes, kc, o = packed.shape
    if planes != PLANES:
        raise ValueError(
            f"ternary packed tensor must carry exactly {PLANES} planes "
            f"(sign + mask), got {planes}"
        )
    _validate_tiling(k, o, kc, g, block_k, block_o)

    grid = (o // block_o, k // block_k)
    if g <= block_k:
        scales_spec = pl.BlockSpec(
            (1, block_k // g, block_o), lambda io, ik: (0, ik, io)
        )
    else:
        scales_spec = pl.BlockSpec(
            (1, 1, block_o), lambda io, ik: (0, ik // (g // block_k), io)
        )

    kernel = functools.partial(
        _ternary_mm_kernel, g=g, bk=block_k, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, block_k), lambda io, ik: (0, ik)),
            pl.BlockSpec((PLANES, block_k // 8, block_o), lambda io, ik: (0, ik, io)),
            scales_spec,
        ],
        out_specs=pl.BlockSpec((B, block_o), lambda io, ik: (0, io)),
        out_shape=jax.ShapeDtypeStruct((B, o), jnp.float32),
        scratch_shapes=[pltpu.VMEM((B, block_o), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, packed, scales)


@functools.partial(
    jax.jit, static_argnames=("g", "block_k", "block_o", "interpret", "compute_dtype")
)
def ternary_mm(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int = DEFAULT_BLOCK_K,
    block_o: int = DEFAULT_BLOCK_O,
    interpret: bool = False,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """x (B, k) @ ternary[(2, k/8, o) sign+mask planes, (1, k/g, o) alpha] → (B, o) f32.

    Constraints are :func:`repro.kernels.bcq_mm.bcq_mm`'s: k % block_k == 0,
    o % block_o == 0, g % 8 == 0 and (block_k % g == 0 or g % block_k == 0).
    ``ops.qmatmul`` pads inputs so callers never see these.
    """
    return ternary_mm_call(
        x,
        packed,
        scales,
        g=g,
        block_k=block_k,
        block_o=block_o,
        interpret=interpret,
        compute_dtype=compute_dtype,
    )


from repro.kernels.introspect import register_vmem_estimator  # noqa: E402

register_vmem_estimator("ternary_mm", vmem_bytes)
