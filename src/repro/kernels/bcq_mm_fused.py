"""Fused multi-projection BCQ matmul — QKV / gate-up in one kernel pass.

Decode is memory-bound: at batch 1 every projection of the same input reads
its packed weights once, but a *separate* kernel launch per projection also
re-streams the activation block HBM→VMEM per launch and pays per-launch grid
overhead N times. FLUTE (Guo et al., 2024) showed LUT/quantized kernels live
or die on tiling + fused multi-output layout; this module is that lesson for
the TPU mapping (DESIGN.md §2.3):

- the N projections' packed weights and group scales are **concatenated along
  the output dim ahead of time** (``fuse_tensors`` — a one-time weight-prep
  step, not a per-step copy), so they must share ``(k, q, g)`` — true for
  Q/K/V (same ``d_model`` input, same quant policy) and for gate/up;
- ONE ``pallas_call`` sweeps the union of output blocks: the activation block
  is loaded once per (o-block, k-block) grid cell of a single kernel instead
  of once per projection, the float32 VMEM scratch accumulator is shared, and
  there is a single dispatch;
- the kernel body is ``bcq_mm``'s (identical unpack→scale→MXU data path), so
  parity tests on the plain kernel cover the fused one's inner loop;
- outputs are returned as N slices of the fused ``(B, Σo_i)`` result — slicing
  is free under XLA (views fused into consumers).

``lutgemm`` dispatch reuses the same fused layout via ``ops.quantized_matmul_fused``.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.bcq_mm import DEFAULT_BLOCK_K, DEFAULT_BLOCK_O, bcq_mm_call


@functools.partial(
    jax.jit,
    static_argnames=("g", "out_dims", "block_k", "block_o", "interpret", "compute_dtype"),
)
def bcq_mm_fused(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    out_dims: Tuple[int, ...],
    block_k: int = DEFAULT_BLOCK_K,
    block_o: int = DEFAULT_BLOCK_O,
    interpret: bool = False,
    compute_dtype=jnp.float32,
) -> Tuple[jax.Array, ...]:
    """``x (B, k)`` against N fused projections → N ``(B, o_i)`` f32 outputs.

    ``packed (q, k/8, Σo_i)`` / ``scales (q, k/g, Σo_i)`` hold the projections
    concatenated along the output dim (see :func:`repro.core.fuse_tensors`).
    Tiling constraints are those of :func:`repro.kernels.bcq_mm.bcq_mm` on the
    fused output dim; the per-projection split offsets are unconstrained.
    """
    o = packed.shape[-1]
    if sum(out_dims) != o:
        raise ValueError(f"out_dims {out_dims} do not sum to fused o={o}")
    y = bcq_mm_call(
        x,
        packed,
        scales,
        g=g,
        block_k=block_k,
        block_o=block_o,
        interpret=interpret,
        compute_dtype=compute_dtype,
    )
    return _split(y, out_dims)


def _split(y: jax.Array, out_dims: Sequence[int]) -> Tuple[jax.Array, ...]:
    outs, start = [], 0
    for d in out_dims:
        outs.append(y[..., start : start + d])
        start += d
    return tuple(outs)
