"""Fused group-wise uniform int-q matmul Pallas kernel (FineQuant-style).

``y = x @ Ŵ`` with ``Ŵ = s ∘ C + z`` consumed **directly in packed form**:
``C`` are unsigned ``q``-bit magnitude codes stored as ``q`` bit planes (the
same physical layout as the BCQ sign planes — ``core/packing.py::pack_codes``)
and ``(s, z)`` are per-(group, column) affine scale/zero parameters. Each grid
step unpacks a ``(q, bk/8, bo)`` byte block to bits with VPU shift/mask ops,
reassembles the codes as ``Σ_i 2^i·bit_i``, applies the group affine in VMEM
registers, and feeds the MXU — the dequantized block never exists in HBM
(the same "no dequantization overhead" requirement the BCQ kernel satisfies,
paper §III; contrast ``kernels/dequant_mm.py``, the explicit baseline).

Grid, accumulator and dimension semantics mirror ``bcq_mm.py``: a float32
VMEM ``scratch_shapes`` accumulator persists across the sequential k steps,
the HBM output block is written once on the last k step, and the o dimension
is ``parallel`` while k is ``arbitrary`` (DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
DEFAULT_BLOCK_O = 256


def vmem_bytes(*, B: int, block_k: int, block_o: int, q: int, g: int) -> int:
    """Per-grid-step VMEM estimate (``kernels/introspect.py``): bcq_mm's
    pipeline shape with the (2, groups, bo) affine scale/zero block and the
    unpacked bit planes + reassembled code block the body materialises."""
    groups = max(block_k // g, 1)
    io = 2 * (
        B * block_k * 4  # x block, f32
        + q * (block_k // 8) * block_o  # packed bit planes, uint8
        + 2 * groups * block_o * 4  # (scale, zero) block (<= f32)
        + B * block_o * 4  # out block, f32
    )
    body = (
        q * block_k * block_o * 4  # unpacked bit planes
        + 2 * block_k * block_o * 4  # reassembled codes + affine w_eff
        + B * block_o * 4  # acc scratch
    )
    return io + body


def _unpack_codes_block(packed: jax.Array, compute_dtype) -> jax.Array:
    """uint8 (q, bk/8, bo) bit planes → codes (bk, bo) in compute_dtype."""
    q, kc, bo = packed.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (1, 1, 8, 1), 2)
    bits = (packed[:, :, None, :] >> shifts) & jnp.uint8(1)  # (q, kc, 8, bo)
    planes = bits.reshape(q, kc * 8, bo).astype(compute_dtype)
    # q is static (<= 8): unroll the weighted plane sum with Python scalar
    # weights 2^i — Pallas kernels may not capture array constants
    codes = planes[0]
    for i in range(1, q):
        codes = codes + planes[i] * (2.0**i)
    return codes  # (bk, bo)


def _uniform_mm_kernel(
    x_ref, packed_ref, scales_ref, out_ref, acc_ref, *, g: int, bk: int, compute_dtype
):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_codes_block(packed_ref[...], compute_dtype)  # (bk, bo)
    scales = scales_ref[...].astype(compute_dtype)  # (2, bk//g or 1, bo)
    s, z = scales[0], scales[1]
    bk_, bo = codes.shape

    if g <= bk:
        # scales block carries bk//g groups — expand each over its g rows
        w = codes.reshape(bk // g, g, bo) * s[:, None, :] + z[:, None, :]
        w_eff = w.reshape(bk, bo)
    else:
        # whole k-block lies inside one scale group: s/z rows are (1, bo)
        w_eff = codes * s + z

    x = x_ref[...].astype(compute_dtype)
    acc_ref[...] += jnp.dot(x, w_eff, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def uniform_mm_call(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int,
    block_o: int,
    interpret: bool,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Unjitted pallas_call core (fused multi-projection dispatch reuses it
    via ``ops.qmatmul`` — the fused layout is plain output-dim concatenation)."""
    from repro.kernels.bcq_mm import _validate_tiling

    B, k = x.shape
    q, kc, o = packed.shape
    _validate_tiling(k, o, kc, g, block_k, block_o)

    grid = (o // block_o, k // block_k)
    if g <= block_k:
        scales_spec = pl.BlockSpec(
            (2, block_k // g, block_o), lambda io, ik: (0, ik, io)
        )
    else:
        scales_spec = pl.BlockSpec(
            (2, 1, block_o), lambda io, ik: (0, ik // (g // block_k), io)
        )

    kernel = functools.partial(
        _uniform_mm_kernel, g=g, bk=block_k, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, block_k), lambda io, ik: (0, ik)),
            pl.BlockSpec((q, block_k // 8, block_o), lambda io, ik: (0, ik, io)),
            scales_spec,
        ],
        out_specs=pl.BlockSpec((B, block_o), lambda io, ik: (0, io)),
        out_shape=jax.ShapeDtypeStruct((B, o), jnp.float32),
        scratch_shapes=[pltpu.VMEM((B, block_o), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, packed, scales)


@functools.partial(
    jax.jit, static_argnames=("g", "block_k", "block_o", "interpret", "compute_dtype")
)
def uniform_mm(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    *,
    g: int,
    block_k: int = DEFAULT_BLOCK_K,
    block_o: int = DEFAULT_BLOCK_O,
    interpret: bool = False,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """x (B, k) @ uniform[(q, k/8, o) bit planes, (2, k/g, o) scale/zero] → (B, o) f32.

    Constraints are :func:`repro.kernels.bcq_mm.bcq_mm`'s: k % block_k == 0,
    o % block_o == 0, g % 8 == 0 and (block_k % g == 0 or g % block_k == 0).
    ``ops.qmatmul`` pads inputs so callers never see these.
    """
    return uniform_mm_call(
        x,
        packed,
        scales,
        g=g,
        block_k=block_k,
        block_o=block_o,
        interpret=interpret,
        compute_dtype=compute_dtype,
    )


from repro.kernels.introspect import register_vmem_estimator  # noqa: E402

register_vmem_estimator("uniform_mm", vmem_bytes)
