"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests ``assert_allclose`` against, and
also the CPU fallback path used by models during smoke tests (fast under XLA:CPU,
no interpret-mode overhead).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcq as bcq_lib
from repro.core import packing


def bcq_mm_ref(x: jax.Array, packed: jax.Array, scales: jax.Array, g: int) -> jax.Array:
    """Oracle for both ``bcq_mm`` and ``lutgemm``:  y = x @ dequantize(W).

    x: (B, k); packed: (q, k//8, o) uint8; scales: (q, k//g, o). Returns (B, o) f32.
    """
    signs = packing.unpack_signs(packed)  # (q, k, o) int8
    w = bcq_lib.dequantize(scales.astype(jnp.float32), signs, g)  # (k, o)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def lutgemm_tablewise_ref(
    x: np.ndarray, packed: np.ndarray, scales: np.ndarray, g: int
) -> np.ndarray:
    """Slow numpy emulation of the *actual LUT algorithm* (paper §III.B, Table II).

    Builds the 2^mu-entry table per mu-length activation sub-vector, retrieves
    partial sums by packed-byte key, applies group scales, accumulates. Used to
    unit-test that the LUT formulation computes the same function as the dense
    reconstruction (it is exact, up to fp associativity).
    """
    mu = packing.MU
    q, kc, o = packed.shape
    k = kc * mu
    b = x.shape[0]
    x = np.asarray(x, dtype=np.float64)  # staticcheck: host-sync(f64 oracle computes on host by design)
    scales = np.asarray(scales, dtype=np.float64)  # staticcheck: host-sync(f64 oracle computes on host by design)

    # all 2^mu sign patterns, LSB-first — pattern[key, j] = +1 if bit j of key set
    keys = np.arange(1 << mu)
    patterns = 2.0 * ((keys[:, None] >> np.arange(mu)[None, :]) & 1) - 1.0  # (256, mu)

    # LUT[b, c, key] = sum_j patterns[key, j] * x[b, mu*c + j]
    x_chunks = x.reshape(b, kc, mu)
    lut = np.einsum("pj,bcj->bcp", patterns, x_chunks)  # (b, kc, 256)

    # retrieve by key, scale per group, accumulate over q and groups
    out = np.zeros((b, o))
    cpg = g // mu  # byte-chunks per scale group
    for i in range(q):
        part = np.take_along_axis(
            lut[:, :, :, None], packed[i][None, :, None, :].astype(np.int64), axis=2
        )[:, :, 0, :]  # (b, kc, o)
        grouped = part.reshape(b, kc // cpg, cpg, o).sum(axis=2)  # (b, G, o)
        out += np.einsum("bGo,Go->bo", grouped, scales[i])
    return out
