"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the cell JSONs.

Usage: PYTHONPATH=src python -m repro.analysis.report [--dir experiments/dryrun]
Writes experiments/roofline_table.md (included by EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def weight_bytes_per_chip(arch: str, quant_q: int, serve: bool = True) -> float:
    """Per-chip weight bytes under the serving sharding (TP-16, no FSDP).

    Used to derive the fused-kernel memory term for quantized serve cells:
    ``adjusted(q) = measured_bytes(dense cell) − w_dense_pc + w_packed_pc`` —
    the Pallas kernel path reads packed bytes where the dense path reads bf16,
    everything else (caches, activations) identical.
    """
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.qtensor import QuantizedTensor
    from repro.models import init_params
    from repro.parallel import param_specs, single_pod_axes
    from repro.quant import QuantPolicy, quantized_structs

    cfg = get_config(arch)
    ax = single_pod_axes()
    if serve:
        ax = dataclasses.replace(ax, fsdp=None)
    structs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if quant_q:
        structs = quantized_structs(structs, QuantPolicy(q=quant_q, g=128))
    specs = param_specs(cfg, ax)

    total = 0.0

    def visit(struct, spec):
        nonlocal total
        if isinstance(struct, QuantizedTensor):
            from repro.parallel.sharding import qt_specs_like

            qspec = qt_specs_like(spec, struct, ax)
            for leaf, sp in ((struct.packed, qspec.packed), (struct.scales, qspec.scales)):
                shards = 1
                for axis in tuple(sp):
                    if axis is not None:
                        shards *= ax.size(axis)
                total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize / shards
            return
        shards = 1
        for axis in tuple(spec):
            if axis is not None:
                shards *= ax.size(axis)
        total += int(np.prod(struct.shape)) * struct.dtype.itemsize / shards

    jax.tree.map(
        visit, structs, specs,
        is_leaf=lambda x: isinstance(x, QuantizedTensor),
    )
    return total


def kernel_adjusted_memory(cells) -> dict:
    """{(arch, shape, mesh, q): adjusted_memory_s} for quantized serve cells,
    by differencing the measured dense sibling."""
    import functools

    by_key = {(c["arch"], c["shape"], c["mesh"], c["quant_q"]): c for c in cells}
    wpc = functools.lru_cache(maxsize=None)(weight_bytes_per_chip)
    out = {}
    for c in cells:
        q = c["quant_q"]
        if not q or c["meta"]["kind"] not in ("decode", "prefill"):
            continue
        dense = by_key.get((c["arch"], c["shape"], c["mesh"], 0))
        if dense is None:
            continue
        uses = c["meta"].get("weight_uses", 1)
        adj_bytes = (
            dense["roofline"]["bytes_per_chip"]
            - uses * wpc(c["arch"], 0)
            + uses * wpc(c["arch"], q)
        )
        out[(c["arch"], c["shape"], c["mesh"], q)] = max(adj_bytes, 0.0) / 819e9
    return out


def load_cells(dir_: str):
    cells = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def _fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(cells, mesh: str = "single") -> str:
    adj = kernel_adjusted_memory(cells)
    rows = [
        "| arch | shape | q | compute | memory | mem (TPU kernel) | collective "
        "| dominant | MFU-bound | useful-FLOPs | bytes/chip | coll-wire/chip |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    sel = [c for c in cells if c["mesh"] == mesh]
    sel.sort(key=lambda c: (c["arch"], order.get(c["shape"], 9), c["quant_q"]))
    for c in sel:
        r = c["roofline"]
        mfu = r.get("mfu_bound")
        ufr = r.get("useful_flops_ratio")
        a = adj.get((c["arch"], c["shape"], c["mesh"], c["quant_q"]))
        rows.append(
            "| {arch} | {shape} | {q} | {c} | {m} | {a} | {co} | **{dom}** | {mfu} | {ufr} | {b} | {w} |".format(
                arch=c["arch"],
                shape=c["shape"],
                q=c["quant_q"] or "bf16",
                c=_fmt_s(r["compute_s"]),
                m=_fmt_s(r["memory_s"]),
                a=_fmt_s(a) if a is not None else "–",
                co=_fmt_s(r["collective_s"]),
                dom=r["dominant"],
                mfu=f"{mfu:.1%}" if mfu else "–",
                ufr=f"{ufr:.2f}" if ufr else "–",
                b=_fmt_b(r["bytes_per_chip"]),
                w=_fmt_b(r["coll_bytes_per_chip"]),
            )
        )
    return "\n".join(rows)


def dryrun_table(cells) -> str:
    rows = [
        "| arch | shape | mesh | q | chips | args/chip | temp/chip | compile | "
        "AR | AG | RS | A2A | CP |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    sel = sorted(
        cells, key=lambda c: (c["arch"], order.get(c["shape"], 9), c["mesh"], c["quant_q"])
    )
    for c in sel:
        m = c["memory_analysis"]
        coll = c["trip_aware"]["collectives"]
        rows.append(
            "| {arch} | {shape} | {mesh} | {q} | {chips} | {a} | {t} | {cs:.0f}s "
            "| {ar} | {ag} | {rs} | {a2a} | {cp} |".format(
                arch=c["arch"], shape=c["shape"], mesh=c["mesh"],
                q=c["quant_q"] or "bf16", chips=c["chips"],
                a=_fmt_b(m["argument_size"] or 0),
                t=_fmt_b(m["temp_size"] or 0),
                cs=c["compile_s"],
                ar=_fmt_b(coll["all-reduce"]["bytes"]),
                ag=_fmt_b(coll["all-gather"]["bytes"]),
                rs=_fmt_b(coll["reduce-scatter"]["bytes"]),
                a2a=_fmt_b(coll["all-to-all"]["bytes"]),
                cp=_fmt_b(coll["collective-permute"]["bytes"]),
            )
        )
    return "\n".join(rows)


def bottleneck_summary(cells) -> str:
    """One line per single-pod cell: what would move the dominant term down."""
    hints = {
        ("collective", "train"): "sequence-parallel TP + bf16 grad reduce-scatter",
        ("collective", "prefill"): "sequence-parallel TP (RS+AG instead of AR of full activations)",
        ("collective", "decode"): "kill weight re-gathers; duplicate-free TP layout",
        ("memory", "train"): "larger microbatch / fused attention to cut activation traffic",
        ("memory", "prefill"): "flash-attention Pallas kernel (no S×S logits materialisation)",
        ("memory", "decode"): "lower q bits / larger g (paper Eq. 3); fused BCQ kernel path",
        ("memory", "long"): "lower q bits; recurrent-state layout",
        ("compute", "train"): "reduce remat recompute (policy dots_saveable)",
        ("compute", "prefill"): "causal-only attention FLOPs (flash kernel)",
        ("compute", "decode"): "already compute-light; batch more requests",
    }
    out = []
    for c in cells:
        if c["mesh"] != "single":
            continue
        r = c["roofline"]
        kind = c["meta"]["kind"]
        hint = hints.get((r["dominant"], kind), "—")
        out.append(
            f"- **{c['arch']} × {c['shape']} (q={c['quant_q'] or 'bf16'})**: "
            f"{r['dominant']}-bound at {_fmt_s(r['bound_s'])}; ↓ via {hint}."
        )
    return "\n".join(sorted(out))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline_table.md")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    parts = [
        "# Roofline tables (generated by repro.analysis.report)\n",
        "## Single-pod (16×16 = 256 chips), v5e constants "
        "(197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link)\n",
        roofline_table(cells, "single"),
        "\n## Multi-pod (2×16×16 = 512 chips)\n",
        roofline_table(cells, "multi"),
        "\n## Dry-run record (memory analysis + collective schedule)\n",
        dryrun_table(cells),
        "\n## Per-cell bottleneck → what moves it down\n",
        bottleneck_summary(cells),
        "",
    ]
    with open(args.out, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
