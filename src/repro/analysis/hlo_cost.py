"""Trip-count-aware cost model over post-SPMD HLO text.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` returns) counts a
``while`` body ONCE — but our models scan over layers (and recurrent cells scan
over time), so FLOPs/bytes/collective traffic inside loops are undercounted by
the trip count (verified: llama3.2-3b train showed ~1-layer + lm-head flops).

This module parses the compiled module text and computes:

- ``flops``: 2·M·N·K for every ``dot`` (incl. inside fusions), scaled by the
  product of enclosing while-loop trip counts;
- ``bytes``: operand+result bytes of every *memory-moving* instruction
  (fusion boundaries, dots, copies, collectives, dynamic-slice/update) —
  a fusion is one kernel, so its interior is free, its boundary is traffic;
- ``collectives``: per-kind counts/bytes/wire-bytes, trip-scaled.

Trip counts come from each while-condition's ``compare(iter, constant(N))``
pattern (how lax.scan lowers); unparseable loops fall back to 1 with a note.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*(?P<op>[\w\-]+)\((?P<args>.*?)\)(?P<rest>.*)$"
)
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_GROUPS_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

# instructions whose operands/results move HBM bytes (fusion interiors are free)
_MEMORY_OPS = {
    "fusion", "dot", "convolution", "copy", "transpose", "reshape", "broadcast",
    "dynamic-slice", "dynamic-update-slice", "slice", "concatenate", "gather",
    "scatter", "reduce", "sort", "iota", "pad", "reverse", "convert",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "select-and-scatter", "reduce-window", "rng", "cholesky", "triangular-solve",
}
# pure control/bookkeeping — no HBM traffic of their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "while", "call",
    "conditional", "bitcast", "after-all", "custom-call", "partition-id",
    "replica-id", "domain", "optimization-barrier", "get-dimension-size",
    "all-reduce-done", "all-gather-done", "copy-start", "copy-done",
    "async-start", "async-update", "async-done", "send", "recv", "infeed",
    "outfeed",
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    args: List[str]
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            stripped = line.strip()
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HDR_RE.match(stripped)
                if m:
                    cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            args = [
                a.strip().lstrip("%")
                for a in _split_args(m.group("args"))
            ]
            ins = Instr(
                m.group("name"), m.group("shape"), m.group("op"), args, m.group("rest")
            )
            cur.instrs.append(ins)
            cur.by_name[ins.name] = ins
    return comps


def _split_args(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [a.split(" ")[-1] if " " in a.strip() else a for a in out]


def _trip_count(cond: Computation) -> Optional[int]:
    """lax.scan while-condition: ROOT compare(gte(iter), constant(N)) LT."""
    const_vals = []
    for ins in cond.instrs:
        if ins.op == "constant" and ins.args:
            try:
                const_vals.append(int(ins.args[0]))
            except ValueError:
                pass
    if not const_vals:
        return None
    # the loop bound is the largest plausible constant in the condition
    bound = max(const_vals)
    return bound if bound > 0 else None


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 · prod(result dims) · prod(contracting dims of lhs)."""
    out_elems = 1
    for d in _shape_dims(ins.shape):
        out_elems *= d
    lhs = comp.by_name.get(ins.args[0]) if ins.args else None
    contract = 1
    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    if lhs is not None and mdims:
        ldims = _shape_dims(lhs.shape)
        for idx in mdims.group(1).split(","):
            if idx and int(idx) < len(ldims):
                contract *= ldims[int(idx)]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, dict] = dataclasses.field(
        default_factory=lambda: {
            k: {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0}
            for k in COLLECTIVE_KINDS
        }
    )
    unparsed_loops: int = 0

    def add(self, other: "Cost", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.unparsed_loops += other.unparsed_loops
        for k in COLLECTIVE_KINDS:
            for f in ("count", "bytes", "wire_bytes"):
                self.coll[k][f] += other.coll[k][f] * scale

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.coll.values())

    @property
    def collective_wire_bytes(self) -> float:
        return sum(v["wire_bytes"] for v in self.coll.values())


def _group_size(rest: str) -> int:
    m = _GROUPS_ITOA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(rest)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    return 1


def _instr_operand_bytes(ins: Instr, comp: Computation) -> int:
    total = 0
    for a in ins.args:
        op = comp.by_name.get(a)
        if op is not None:
            total += _shape_bytes(op.shape)
    return total


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}
_TRANSPARENT = {"convert", "bitcast", "copy"}  # layout/dtype plumbing inside fusions

# A fusion made only of these produces a VIEW/cast of existing data. On the
# TPU target these never materialise (dots read bf16 operands natively and
# slices are fused address arithmetic); XLA:CPU materialises f32 copies of
# whole weight stacks instead (measured 3–4× decode over-read on MoE cells).
# Consumers charge their own operand reads, so charging the view is double
# counting — TPU-faithful cost = 0.
_VIEW_OPS = {
    "parameter", "constant", "convert", "bitcast", "copy", "reshape",
    "dynamic-slice", "slice", "get-tuple-element", "tuple", "broadcast",
}


def _resolve_through(inner: Computation, ins: Instr) -> Instr:
    """Walk backwards through transparent ops to the producing instruction."""
    seen = 0
    while ins.op in _TRANSPARENT and ins.args and seen < 16:
        nxt = inner.by_name.get(ins.args[0])
        if nxt is None:
            break
        ins = nxt
        seen += 1
    return ins


def _terminal_uses(inner: Computation, name: str, depth: int = 0) -> List[Instr]:
    """All non-transparent consumers of `name`, looking through transparent ops."""
    out: List[Instr] = []
    if depth > 8:
        return out
    for u in inner.instrs:
        if name in u.args:
            if u.op in _TRANSPARENT:
                out.extend(_terminal_uses(inner, u.name, depth + 1))
            else:
                out.append(u)
    return out


def _dot_bytes(ins: Instr, comp: Computation) -> float:
    """Dot traffic, TPU-faithful: result at its real width (f32 accums are
    real), operands at ≤ bf16/elem. XLA:CPU legalises bf16 dots by converting
    operands to f32; the MXU reads bf16 natively, so charging the f32 width
    would bake a 2× CPU artifact into the roofline."""
    total = float(_shape_bytes(ins.shape))
    for a in ins.args:
        opnd = comp.by_name.get(a)
        if opnd is None:
            continue
        b = _shape_bytes(opnd.shape)
        elems = 1
        for d in _shape_dims(opnd.shape):
            elems *= d
        total += min(b, 2 * elems)
    return total


def _instr_bytes(ins: Instr, comp: Computation, comps: Dict[str, Computation]) -> float:
    """HBM traffic attributed to one instruction.

    - (dynamic-)slice / gather read only the sliced region: 2 × result bytes
      (a KV-cache *read* is the full cache though — gathers of whole buffers
      still show as big results, which is what we want).
    - dynamic-update-slice writes only the update region: 2 × update bytes
      (without this, every 1-token KV-cache write would be charged the whole
      multi-GB cache).
    - fusion: result + effective operand reads; a fused interior is one kernel.
      Parameters consumed only via slices are charged at slice-result size;
      a DUS-rooted fusion is charged at update size (in-place cache write).
    """
    op = ins.op
    if op in _SLICE_OPS:
        return 2.0 * _shape_bytes(ins.shape)
    if op == "dynamic-update-slice":
        upd = comp.by_name.get(ins.args[1]) if len(ins.args) > 1 else None
        upd_b = _shape_bytes(upd.shape) if upd else _shape_bytes(ins.shape)
        return 2.0 * upd_b
    if op == "scatter":
        # in-place: read+write the update region (+ indices); base is aliased
        upd = comp.by_name.get(ins.args[2]) if len(ins.args) > 2 else None
        idx = comp.by_name.get(ins.args[1]) if len(ins.args) > 1 else None
        upd_b = _shape_bytes(upd.shape) if upd else 0
        idx_b = _shape_bytes(idx.shape) if idx else 0
        return 2.0 * upd_b + idx_b
    if op == "fusion":
        m = _CALLS_RE.search(ins.rest)
        inner = comps.get(m.group(1)) if m else None
        if inner is None:
            return _shape_bytes(ins.shape) + _instr_operand_bytes(ins, comp)
        # pure view/cast fusions are free on the TPU target (see _VIEW_OPS)
        if all(i.op in _VIEW_OPS for i in inner.instrs):
            return 0.0
        # result side: DUS/scatter-rooted fusions write only the update region
        root = _resolve_through(inner, inner.instrs[-1]) if inner.instrs else None
        root_write = None  # name of the in-place base param chain, if any
        if root is not None and root.op in ("dynamic-update-slice", "scatter"):
            upd_arg = 1 if root.op == "dynamic-update-slice" else 2
            upd = inner.by_name.get(root.args[upd_arg]) if len(root.args) > upd_arg else None
            out_b = 2.0 * (_shape_bytes(upd.shape) if upd else 0)
            base = inner.by_name.get(root.args[0]) if root.args else None
            if base is not None:
                root_write = _resolve_through(inner, base).name
        else:
            out_b = float(_shape_bytes(ins.shape))
        # operand side: params used only through slices charge slice results;
        # the in-place base of a DUS/scatter root charges nothing.
        params = [i for i in inner.instrs if i.op == "parameter"]
        read_b = 0.0
        for pins in params:
            if root_write is not None and pins.name == root_write:
                continue
            uses = _terminal_uses(inner, pins.name)
            if uses and all(u.op in _SLICE_OPS for u in uses):
                read_b += sum(_shape_bytes(u.shape) for u in uses)
            else:
                # pair the fusion operand by the parameter's declared number
                try:
                    pnum = int(pins.args[0])
                except (ValueError, IndexError):
                    pnum = -1
                if 0 <= pnum < len(ins.args):
                    operand = comp.by_name.get(ins.args[pnum])
                    if operand is not None:
                        read_b += _shape_bytes(operand.shape)
                    else:
                        read_b += _shape_bytes(pins.shape)
                else:
                    read_b += _shape_bytes(pins.shape)
        return out_b + read_b
    return float(_shape_bytes(ins.shape) + _instr_operand_bytes(ins, comp))


def cost_computation(
    comp: Computation, comps: Dict[str, Computation], memo: Dict[str, Cost]
) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    total = Cost()
    memo[comp.name] = total  # guard (HLO computations are acyclic)
    for ins in comp.instrs:
        op = ins.op
        if op == "while":
            body_m = _CALLS_RE.search(ins.rest)
            cond_m = _COND_RE.search(ins.rest)
            trips = None
            if cond_m and cond_m.group(1) in comps:
                trips = _trip_count(comps[cond_m.group(1)])
            if trips is None:
                trips = 1
                total.unparsed_loops += 1
            if body_m and body_m.group(1) in comps:
                total.add(cost_computation(comps[body_m.group(1)], comps, memo), trips)
            continue
        if op in ("call", "conditional", "custom-call"):
            for m in _CALLS_RE.finditer(ins.rest):
                if m.group(1) in comps:
                    total.add(cost_computation(comps[m.group(1)], comps, memo))
            continue
        if op == "fusion":
            m = _CALLS_RE.search(ins.rest)
            if m and m.group(1) in comps:
                inner = cost_computation(comps[m.group(1)], comps, memo)
                # flops & collectives count; interior bytes do not (one kernel)
                total.flops += inner.flops
                for k in COLLECTIVE_KINDS:
                    for f in ("count", "bytes", "wire_bytes"):
                        total.coll[k][f] += inner.coll[k][f]
            total.bytes += _instr_bytes(ins, comp, comps)
            continue
        base_kind = op[:-6] if op.endswith("-start") else op
        if base_kind in COLLECTIVE_KINDS:
            b = _shape_bytes(ins.shape)
            if op.endswith("-start"):
                # async result tuple repeats operand+result; halve
                b = b // 2 if b else _shape_bytes(ins.shape)
            n = _group_size(ins.rest)
            factor = {
                "all-reduce": 2.0 * (n - 1) / max(n, 1),
                "all-gather": (n - 1) / max(n, 1),
                "reduce-scatter": (n - 1) / max(n, 1),
                "all-to-all": (n - 1) / max(n, 1),
                "collective-permute": 1.0,
            }[base_kind]
            total.coll[base_kind]["count"] += 1
            total.coll[base_kind]["bytes"] += b
            total.coll[base_kind]["wire_bytes"] += b * factor
            total.bytes += b + _instr_operand_bytes(ins, comp)
            continue
        if op == "dot":
            total.flops += _dot_flops(ins, comp)
            total.bytes += _shape_bytes(ins.shape) + _instr_operand_bytes(ins, comp)
            continue
        if op in _MEMORY_OPS:
            total.bytes += _shape_bytes(ins.shape) + _instr_operand_bytes(ins, comp)
            continue
        # everything else (unfused elementwise in unoptimised dumps, etc.)
        if op not in _FREE_OPS:
            total.bytes += _shape_bytes(ins.shape) + _instr_operand_bytes(ins, comp)
    memo[comp.name] = total
    return total


def attribute(hlo_text: str, top: int = 20) -> List[Tuple[float, float, str]]:
    """Per-instruction (bytes, flops, label) attribution, trip-scaled, using the
    same accounting rules as :func:`analyze`. For perf-iteration diagnosis."""
    comps = parse_module(hlo_text)
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo_text)
    entry = m.group(1) if m else next(iter(comps))
    agg: Dict[str, List[float]] = {}

    def walk(comp: Computation, scale: float) -> None:
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                body_m = _CALLS_RE.search(ins.rest)
                cond_m = _COND_RE.search(ins.rest)
                trips = (
                    _trip_count(comps[cond_m.group(1)])
                    if cond_m and cond_m.group(1) in comps
                    else None
                ) or 1
                if body_m and body_m.group(1) in comps:
                    walk(comps[body_m.group(1)], scale * trips)
                continue
            if op in ("call", "conditional", "custom-call"):
                for mm in _CALLS_RE.finditer(ins.rest):
                    if mm.group(1) in comps:
                        walk(comps[mm.group(1)], scale)
                continue
            if op in _FREE_OPS:
                continue
            flops = 0.0
            if op == "dot":
                flops = _dot_flops(ins, comp) * scale
            if op == "fusion":
                mm = _CALLS_RE.search(ins.rest)
                if mm and mm.group(1) in comps:
                    memo: Dict[str, Cost] = {}
                    flops = cost_computation(comps[mm.group(1)], comps, memo).flops * scale
            b = _instr_bytes(ins, comp, comps) * scale
            meta_m = re.search(r'op_name="([^"]+)"', ins.rest)
            shape_head = ins.shape.split(" ")[0][:44]
            label = f"{op} {shape_head} | {(meta_m.group(1)[-72:] if meta_m else '?')}"
            cur = agg.setdefault(label, [0.0, 0.0])
            cur[0] += b
            cur[1] += flops

    walk(comps[entry], 1.0)
    rows = sorted(((v[0], v[1], k) for k, v in agg.items()), reverse=True)
    return rows[:top]


def normalize_cost_analysis(cost) -> Dict[str, float]:
    """Flatten ``compiled.cost_analysis()`` across JAX versions.

    New JAX returns one flat ``{property: value}`` dict; older releases return
    a *list* of per-executable-program dicts (one entry for an unpartitioned
    module). Indexing the raw result with a string therefore TypeErrors on old
    versions — every consumer goes through here first. Multiple program entries
    are summed (properties are additive totals: flops, bytes accessed, ...).
    """
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
    if isinstance(cost, (list, tuple)):
        merged: Dict[str, float] = {}
        for entry in cost:
            for k, v in dict(entry).items():
                if isinstance(v, (int, float)):
                    merged[k] = merged.get(k, 0.0) + float(v)
        return merged
    raise TypeError(f"unrecognised cost_analysis result type {type(cost)!r}")


def analyze(hlo_text: str, entry: Optional[str] = None) -> Cost:
    """Full-module trip-count-aware cost. Entry = module's ENTRY computation."""
    comps = parse_module(hlo_text)
    if not comps:
        return Cost()
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo_text)
        entry = m.group(1) if m else next(iter(comps))
    # called computations must not be double counted at top level: cost only entry
    memo: Dict[str, Cost] = {}
    if entry in comps:
        return cost_computation(comps[entry], comps, memo)
    return cost_computation(next(iter(comps.values())), comps, memo)
