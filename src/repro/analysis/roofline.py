"""Three-term roofline model for TPU v5e (targets; container is CPU-only).

    compute term    = HLO_FLOPs    / (chips × 197e12 FLOP/s bf16)
    memory term     = HLO_bytes    / (chips × 819e9  B/s HBM)
    collective term = coll_bytes   / (chips × 50e9   B/s per ICI link)

``cost_analysis()`` of a GSPMD-partitioned module reports the **per-device**
program, so the per-chip terms divide by one chip's peak (dividing total work
by total peak is the same number). The dominant term approximates step latency
if compute/memory/communication overlapped perfectly; their max→sum range
brackets reality.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 FLOP/s per chip (TPU v5e)
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link


V5E = HW()


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops: Optional[float] = None  # 6·N·D (active N for MoE), whole step
    chips: int = 1

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPS — remat/dequant/redundancy overhead lens."""
        if self.model_flops is None or self.flops_per_chip <= 0:
            return None
        return self.model_flops / (self.flops_per_chip * self.chips)

    @property
    def mfu_bound(self) -> Optional[float]:
        """Model-FLOPs utilisation if the step ran exactly at the roofline."""
        if self.model_flops is None or self.bound_s <= 0:
            return None
        hw_flops = self.chips * V5E.peak_flops * self.bound_s
        return self.model_flops / hw_flops

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "chips": self.chips,
        }


def roofline(
    flops_per_chip: float,
    bytes_per_chip: float,
    coll_bytes_per_chip: float,
    *,
    chips: int,
    model_flops: Optional[float] = None,
    hw: HW = V5E,
) -> Roofline:
    return Roofline(
        compute_s=flops_per_chip / hw.peak_flops,
        memory_s=bytes_per_chip / hw.hbm_bw,
        collective_s=coll_bytes_per_chip / hw.ici_bw,
        flops_per_chip=flops_per_chip,
        bytes_per_chip=bytes_per_chip,
        coll_bytes_per_chip=coll_bytes_per_chip,
        model_flops=model_flops,
        chips=chips,
    )


def model_flops_estimate(n_params_active: int, tokens: int, training: bool) -> float:
    """6·N·D for a train step (fwd+bwd); 2·N·D for inference-only steps."""
    per_tok = 6 if training else 2
    return float(per_tok) * n_params_active * tokens
