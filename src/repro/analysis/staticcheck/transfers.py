"""Transfer/sync pass: the jitted decode programs never talk to the host.

``infer/engine.py`` claims "the host syncs once for the whole sequence":
all sampling happens on device inside the decode scan, and the only host
round trips are the prompt upload and the final token fetch. Two things
would silently break that:

1. a **host callback staged into the jitted program** (``jax.debug.print``
   left over from debugging, a ``pure_callback`` smuggled in by a helper) —
   every decode step would stall on the host. Checked on the traced step
   jaxpr: none of the callback/infeed primitives may appear anywhere in it.
2. a **retrace per call** (an unhashable static, a Python-object leaf that
   fails pytree equality, a shape that changes when it shouldn't) — every
   ``generate`` would pay tracing + compilation again, the classic "why is
   serving 100x slower than the benchmark" bug. Checked by executing two
   generations on a reduced real engine and asserting the jitted entries'
   compile-cache size is exactly 1.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.analysis.staticcheck import PassResult, Violation
from repro.analysis.staticcheck.harness import TraceCell
from repro.analysis.staticcheck.jaxpr_walk import walk

# primitives that move control or data to the host mid-program
TRANSFER_PRIMS = frozenset(
    {"debug_callback", "pure_callback", "io_callback", "callback",
     "debug_print", "outside_call", "infeed", "outfeed"}
)


def transfer_violations(cell: TraceCell) -> List[Violation]:
    out = []
    for site in walk(cell.closed):
        if site.prim in TRANSFER_PRIMS:
            out.append(
                Violation(
                    "transfers", cell.cell_id,
                    f"host-transfer primitive {site.describe()} inside the "
                    "jitted decode step — every step would sync with the host",
                )
            )
    return out


# -- trace-once harness ------------------------------------------------------


def _reduced_engine(fmt: str):
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.infer.engine import Engine
    from repro.models import init_params, reduced
    from repro.quant.quantize import QuantPolicy, quantize_params

    cfg = reduced(get_config("llama3.2-3b"), d_model=128, n_kv_heads=4, d_ff=256)
    params = init_params(jax.random.PRNGKey(0), cfg)
    if fmt != "dense":
        params = quantize_params(params, QuantPolicy(3, g=32, iters=2, fmt=fmt))
    return Engine(cfg, params, max_seq=64), np


def trace_once_check(fmts: Sequence[str] = ("dense", "bcq")) -> Tuple[int, List[Violation]]:
    """Two identical-shape generations per format; every jitted decode entry
    must have compiled exactly once. Returns (engines checked, violations)."""
    violations: List[Violation] = []
    for fmt in fmts:
        eng, np = _reduced_engine(fmt)
        prompt = np.zeros((1, 4), np.int32)
        eng.generate(prompt, 4)
        eng.generate(np.ones((1, 4), np.int32), 4)
        for name, jitted in (
            ("_prefill", eng._prefill),
            ("_decode", eng._decode),  # untraced under scan=True: size 0 is fine
            ("_scan_decode", eng._scan_decode),
        ):
            size = jitted._cache_size()
            if size > 1:
                violations.append(
                    Violation(
                        "transfers/trace-once", f"engine[{fmt}].{name}",
                        f"compile cache holds {size} entries after two "
                        "identical-shape generations — something retraces "
                        "per call (unhashable static? non-canonical pytree?)",
                    )
                )
    return len(fmts), violations


def chunked_prefill_trace_check() -> Tuple[int, List[Violation]]:
    """Chunked prefill compiles once per chunk *bucket*, never per prompt
    length (DESIGN.md §12). The historical bug this pins down: whole-shot
    admission retraces ``_prefill`` for every distinct prompt length, so a
    serving mix of lengths pays compile on nearly every admission. Bucketed
    chunk padding is the fix — this check drives admissions over many
    distinct prompt lengths through one chunked-prefill scheduler and
    asserts ``_prefill_chunk``'s compile cache stays bounded by the bucket
    set actually touched (start positions/lengths ride as traced scalars)."""
    import numpy as np

    from repro.infer.prefix_cache import PrefixCache
    from repro.infer.scheduler import Request, Scheduler

    eng, _ = _reduced_engine("dense")
    eng.prefix_cache = PrefixCache(block_tokens=8, max_bytes=32 << 20)
    eng.prefix_cache.bind("trace-once-harness")
    sched = Scheduler(eng, n_slots=2, chunk=2, prefill_chunk=8)
    rng = np.random.default_rng(0)
    # 9 distinct prompt lengths spanning several buckets — whole-shot
    # admission would compile 9 prefill entries for these. Most share a
    # 16-token prefix so the warm install path (row buckets) exercises too.
    shared = rng.integers(0, eng.cfg.vocab, size=16).astype(np.int32)
    tails = [3, 5, 7, 9, 12, 17, 23]
    prompts = [np.array([1, 2, 3], np.int32), np.array([4, 5, 6, 7, 8], np.int32)]
    prompts += [
        np.concatenate(
            [shared, rng.integers(0, eng.cfg.vocab, size=t).astype(np.int32)]
        )
        for t in tails
    ]
    for i, prompt in enumerate(prompts):
        sched.submit(Request(prompt=prompt, max_new_tokens=2, seed=i))
    sched.run()
    violations: List[Violation] = []
    # buckets a <=8-token chunk can pad to: {8} plus exact tail lengths only
    # when the bucket would overrun max_seq (never here: 39 + 8 <= 64)
    budget = 1
    size = eng._prefill_chunk._cache_size()
    if size > budget:
        violations.append(
            Violation(
                "transfers/chunked-prefill-trace",
                "engine[dense]._prefill_chunk",
                f"compile cache holds {size} entries after admissions over "
                f"{len(prompts)} distinct prompt lengths with prefill_chunk=8 "
                f"— expected <= {budget} (one per touched bucket): chunk "
                "padding is leaking a per-length shape or a non-weak static",
            )
        )
    # the row-install path buckets the same way (prefix hits pad to the
    # match bucket); with block_tokens=8 and these lengths only the 8- and
    # 16-row buckets can appear
    isize = eng._install_rows._cache_size()
    if isize > 2:
        violations.append(
            Violation(
                "transfers/chunked-prefill-trace",
                "engine[dense]._install_rows",
                f"prefix-row install compiled {isize} entries — expected <= 2 "
                "(row buckets 8 and 16): pad_rows is not bucketing",
            )
        )
    return 1, violations


def run(cells: Sequence[TraceCell], *, trace_once: bool = True) -> PassResult:
    result = PassResult("transfers", checked=len(cells))
    for cell in cells:
        result.violations.extend(transfer_violations(cell))
    if trace_once:
        n, vs = trace_once_check()
        result.checked += n
        result.violations.extend(vs)
        n2, vs2 = chunked_prefill_trace_check()
        result.checked += n2
        result.violations.extend(vs2)
    else:
        result.skipped.append("trace-once: disabled by caller")
    return result
