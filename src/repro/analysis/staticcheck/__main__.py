"""CLI: ``python -m repro.analysis.staticcheck`` — run every pass, exit
nonzero on any violation. This is the CI gate (.github/workflows/ci.yml,
``staticcheck`` job) and the local pre-push check.

``--self-test`` additionally builds the deliberately broken decode step
(harness.build_injected_cell: a weight-sized all_gather inside the TP step)
and verifies the census pass CATCHES it — a checker that cannot fail its
known-bad fixture is reporting nothing.
"""

from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.staticcheck",
        description="static contract checker: jaxpr/AST serving invariants",
    )
    parser.add_argument(
        "--archs", nargs="*", default=None,
        help="configs to check (default: every registered arch)",
    )
    parser.add_argument(
        "--fmts", nargs="*", default=None,
        help="quant formats (default: dense bcq uniform dequant codebook ternary)",
    )
    parser.add_argument(
        "--tps", nargs="*", type=int, default=[1, 2, 4],
        help="tensor-parallel degrees (default: 1 2 4)",
    )
    parser.add_argument(
        "--no-trace-once", action="store_true",
        help="skip the (slower) executing compile-cache check",
    )
    parser.add_argument(
        "--self-test", action="store_true",
        help="also verify the census catches the injected weight-gather fixture",
    )
    parser.add_argument(
        "-v", "--verbose", action="store_true", help="list skipped cells"
    )
    args = parser.parse_args(argv)

    # environment BEFORE the first jax import: the TP cells need >= 4 host
    # devices, and the vmem sweep must resolve schedules without measuring
    os.environ.setdefault("REPRO_AUTOTUNE", "0")
    from repro.launch._hostdev import force_host_devices

    force_host_devices(max(args.tps) if args.tps else 4)

    from repro.analysis.staticcheck import run_all

    results = run_all(
        archs=args.archs, fmts=args.fmts, tps=tuple(args.tps),
        trace_once=not args.no_trace_once,
    )

    failed = False
    for res in results:
        print(res.summary())
        if args.verbose:
            for skip in res.skipped:
                print(f"  skip: {skip}")
        for v in res.violations:
            failed = True
            print(f"  FAIL {v}")

    if args.self_test:
        from repro.analysis.staticcheck.census import census_cell
        from repro.analysis.staticcheck.harness import build_injected_cell

        cell = build_injected_cell()
        caught = [
            v for v in census_cell(cell) if "weight/cache-shaped" in v.message
        ]
        if caught:
            print(f"self-test: ok — census caught the injected gather:")
            print(f"  {caught[0]}")
        else:
            failed = True
            print("self-test: FAIL — injected weight all_gather was NOT caught")

    print("staticcheck:", "FAILED" if failed else "passed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
