"""Recursive jaxpr traversal shared by every trace-level pass.

``jax.make_jaxpr`` of the decode step yields a *static* program: the L-layer
stack is one ``scan`` eqn whose body appears once, TP regions are one
``shard_map`` eqn, Pallas kernels are opaque ``pallas_call`` eqns. The
walker flattens that nesting into a stream of :class:`EqnSite` records that
carry (a) the call-stack of enclosing higher-order primitives, (b) the
*dynamic repeat count* — the product of enclosing ``scan`` lengths — so a
census over the static program can assert dynamic counts (a psum inside the
L-step layer scan counts L times), and (c) user-source provenance for error
messages.

``pallas_call`` sub-jaxprs are NOT descended by default: the kernel body is
a different machine model (refs, grids) and its eqns would pollute
whole-program invariants like "no float cast of a packed operand" — the
kernel is exactly where integer planes legitimately become floats.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
from jax._src import source_info_util

# higher-order primitive params that hold sub-jaxprs to descend into
_SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr", "fun_jaxpr")


@dataclasses.dataclass(frozen=True)
class EqnSite:
    """One equation plus where it sits in the traced program."""

    eqn: jax.core.JaxprEqn
    stack: Tuple[str, ...]  # enclosing higher-order prims, outermost first
    repeats: int  # product of enclosing scan lengths (dynamic multiplier)

    @property
    def prim(self) -> str:
        return self.eqn.primitive.name

    def source(self) -> str:
        """``file:line`` of the user frame that staged this eqn (or '?')."""
        frame = source_info_util.user_frame(self.eqn.source_info)
        if frame is None:
            return "?"
        return f"{frame.file_name}:{frame.start_line}"

    def describe(self) -> str:
        ctx = ">".join(self.stack) or "<top>"
        return f"{self.prim} at {self.source()} (in {ctx}, x{self.repeats})"


def _as_jaxpr(obj):
    """Raw ``Jaxpr`` from a sub-jaxpr param (raw or Closed), else None."""
    if isinstance(obj, jax.core.ClosedJaxpr):
        return obj.jaxpr
    if isinstance(obj, jax.core.Jaxpr):
        return obj
    return None


def walk(
    jaxpr, *, descend_pallas: bool = False, _stack: Tuple[str, ...] = (), _repeats: int = 1
) -> Iterator[EqnSite]:
    """Yield an :class:`EqnSite` for every eqn, recursing into sub-jaxprs.

    ``scan`` descent multiplies ``repeats`` by the scan ``length`` param;
    ``while`` bodies keep repeats unchanged (trip counts are dynamic — any
    per-iteration invariant must already hold for the body once).
    """
    inner = _as_jaxpr(jaxpr)
    if inner is None:
        raise TypeError(f"walk expects a (Closed)Jaxpr, got {type(jaxpr)!r}")
    for eqn in inner.eqns:
        yield EqnSite(eqn, _stack, _repeats)
        name = eqn.primitive.name
        if name == "pallas_call" and not descend_pallas:
            continue
        mult = _repeats
        if name == "scan":
            length = eqn.params.get("length")
            if isinstance(length, int):
                mult = _repeats * length
        for key in _SUBJAXPR_KEYS:
            sub = _as_jaxpr(eqn.params.get(key))
            if sub is not None:
                yield from walk(
                    sub, descend_pallas=descend_pallas,
                    _stack=_stack + (name,), _repeats=mult,
                )
        branches = eqn.params.get("branches")
        if branches:
            for br in branches:
                sub = _as_jaxpr(br)
                if sub is not None:
                    yield from walk(
                        sub, descend_pallas=descend_pallas,
                        _stack=_stack + (name,), _repeats=mult,
                    )


def aval_shape_dtype(var) -> Optional[Tuple[Tuple[int, ...], str]]:
    """(shape, dtype-name) of a jaxpr atom's aval, or None for literals
    without array avals."""
    aval = getattr(var, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return None
    return tuple(shape), str(dtype)
