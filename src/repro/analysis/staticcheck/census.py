"""Collective census: prove the TP decode step's communication contract.

``parallel/tp.py`` documents the whole point of the sharding layout: per
decode step exactly one ``psum`` per attention block (after ``wo``), one per
MLP (after ``w_down``), and one ``all_gather`` of the vocab-sharded logits —
2L+1 collectives, every one of them activation-sized, **never a weight or
cache gather**. A refactor that resharded a weight inside the step (the
classic "all_gather the shard then compute dense" regression) would still
produce correct tokens, only 10-100x slower — invisible to every numeric
test. This pass pins the claim on the traced program:

1. **count** — collectives in the step jaxpr, scan-aware (a psum inside the
   L-iteration layer scan counts L times), must equal the cell's documented
   ``2L+1``;
2. **operand size** — no collective operand may have the shape of any
   weight/cache leaf (global or per-device-local), as indexed by the
   harness. Violations name the matching leaf and the eqn's source line.
"""

from __future__ import annotations

from collections import Counter
from typing import List, Optional, Sequence

from repro.analysis.staticcheck import PassResult, Violation
from repro.analysis.staticcheck.harness import TraceCell
from repro.analysis.staticcheck.jaxpr_walk import aval_shape_dtype, walk

# cross-device communication primitives as they appear in jaxprs
COLLECTIVE_PRIMS = frozenset(
    {"psum", "all_gather", "all_to_all", "ppermute", "reduce_scatter",
     "pmax", "pmin", "pbroadcast"}
)


def census_cell(cell: TraceCell) -> List[Violation]:
    violations: List[Violation] = []
    counts: Counter = Counter()
    for site in walk(cell.closed):
        if site.prim not in COLLECTIVE_PRIMS:
            continue
        counts[site.prim] += site.repeats
        for invar in site.eqn.invars:
            sd = aval_shape_dtype(invar)
            if sd is None:
                continue
            shape, _ = sd
            leaf = cell.shape_index.get(shape)
            if leaf is not None:
                violations.append(
                    Violation(
                        "census", cell.cell_id,
                        f"{site.prim} at {site.source()} operates on a "
                        f"weight/cache-shaped operand {shape} matching leaf "
                        f"{leaf} — TP must compute on shards, never "
                        "re-assemble them",
                    )
                )
    total = sum(counts.values())
    if total != cell.expected_collectives:
        breakdown = ", ".join(f"{k}x{v}" for k, v in sorted(counts.items()))
        violations.append(
            Violation(
                "census", cell.cell_id,
                f"collective count {total} ({breakdown or 'none'}) != the "
                f"documented 2L+1 = {cell.expected_collectives} "
                "(parallel/tp.py module docs; update BOTH if the topology "
                "legitimately changed)",
            )
        )
    return violations


def run(
    cells: Sequence[TraceCell], *, skipped: Optional[Sequence[str]] = None
) -> PassResult:
    result = PassResult("census", checked=len(cells), skipped=list(skipped or []))
    for cell in cells:
        result.violations.extend(census_cell(cell))
    return result
