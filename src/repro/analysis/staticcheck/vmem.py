"""Pallas/VMEM budget pass: every schedule this repo can resolve must fit.

Two scopes:

1. **persisted autotune tables** — every entry of the checked-in
   ``autotune_table.json`` (and the user cache, if present) re-validated
   through ``kernels/autotune.py::validate_entry``: parseable key, positive
   block pair, the kernels' divisibility contract, and — for real-hardware
   backends — the impl's registered per-grid-step VMEM estimate under the
   ``kernels/introspect.py`` budget. (``autotune.py`` also enforces this at
   load time; the pass exists so CI fails on a bad *checked-in* table even
   if no code path loads it.)
2. **config sweep** — for every (registered config × quantized format × tp)
   cell, every QuantizedTensor leaf's matmul shape (global and per-device
   local), padded exactly as ``core/formats.py::_pallas_matvec`` pads it
   (B→sublane, o→lane block), resolved through ``autotune.get_blocks``
   with measurement off — i.e. the schedule serving would actually pick on
   a table miss — then priced against the budget for each of the format's
   kernels. This is the "would the real model's shapes compile on TPU"
   gate that no CPU test exercises.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax

from repro.analysis.staticcheck import PassResult, Violation
from repro.core.qtensor import QuantizedTensor
from repro.kernels import autotune, introspect

_SUBLANE, _LANE = 8, 128
_DECODE_B = 1  # decode batch before sublane padding


def validate_tables() -> Tuple[int, List[Violation]]:
    checked, violations = 0, []
    paths = [autotune._TABLE_PATH, autotune._user_cache_path()]
    for path in paths:
        try:
            table = autotune._load_table(path)
        except ValueError as e:
            violations.append(Violation("vmem/table", path, str(e)))
            continue
        for key, blocks in table.items():
            checked += 1
            try:
                autotune.validate_entry(key, blocks, path=path)
            except ValueError as e:
                violations.append(Violation("vmem/table", path, str(e)))
    return checked, violations


def _padded_o(o: int) -> int:
    if any(o % c == 0 for c in autotune._CANDIDATE_O):
        return o
    return o + (-o % _LANE)


def _leaf_shapes(arch: str, fmt: str, tp: int):
    """(k, o, q, g, leaf path) for every quantized matmul the cell runs,
    global and — for sharded leaves — per-device local."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.parallel.sharding import MeshAxes
    from repro.parallel.tp import _COLUMN_PARALLEL, _ROW_PARALLEL
    from repro.quant.quantize import QuantPolicy, quantized_structs

    cfg = get_config(arch)
    structs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    structs = quantized_structs(structs, QuantPolicy(3, g=128, fmt=fmt))
    is_qt = lambda x: isinstance(x, QuantizedTensor)
    flat, _ = jax.tree_util.tree_flatten_with_path(structs, is_leaf=is_qt)
    out = []
    for path, leaf in flat:
        if not isinstance(leaf, QuantizedTensor):
            continue
        name = str(getattr(path[-1], "key", path[-1]))
        where = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((leaf.k, leaf.o, leaf.q, leaf.g, where))
        if tp > 1 and name in _COLUMN_PARALLEL and leaf.o % tp == 0:
            out.append((leaf.k, leaf.o // tp, leaf.q, leaf.g, f"{where} (local)"))
        if tp > 1 and name in _ROW_PARALLEL and leaf.k % (leaf.g * tp) == 0:
            out.append((leaf.k // tp, leaf.o, leaf.q, leaf.g, f"{where} (local)"))
    return out


def sweep_configs(
    *,
    archs: Optional[Sequence[str]] = None,
    fmts: Optional[Sequence[str]] = None,
    tps: Sequence[int] = (1, 2, 4),
) -> Tuple[int, List[Violation], List[str]]:
    from repro.configs import ARCH_IDS
    from repro.core.formats import get_format

    checked, violations, skips = 0, [], []
    budget = introspect.vmem_budget()
    fmts = [
        f
        for f in (fmts or ("bcq", "uniform", "dequant", "codebook", "ternary"))
        if f != "dense"
    ]
    for arch in archs or ARCH_IDS:
        for fmt in fmts:
            impls = get_format(fmt).impls
            for tp in tps:
                cell = f"{arch}/{fmt}/tp{tp}"
                try:
                    shapes = _leaf_shapes(arch, fmt, tp)
                except (NotImplementedError, ValueError) as e:
                    skips.append(f"{cell}: {str(e).splitlines()[0]}")
                    continue
                seen = set()
                for k, o, q, g, where in shapes:
                    o_pad = _padded_o(o)
                    B = _DECODE_B + (-_DECODE_B % _SUBLANE)
                    sig = (k, o_pad, q, g)
                    if sig in seen:
                        continue
                    seen.add(sig)
                    for impl in impls:
                        checked += 1
                        bk, bo = autotune.get_blocks(
                            B=B, k=k, o=o_pad, q=q, g=g, impl=impl,
                            interpret=False, allow_measure=False,
                        )
                        if not bk or not bo:
                            violations.append(
                                Violation(
                                    "vmem/sweep", cell,
                                    f"{impl} has no valid tiling for leaf "
                                    f"{where} (k={k}, o={o_pad}, g={g})",
                                )
                            )
                            continue
                        try:
                            need = introspect.vmem_bytes(
                                impl, B=B, block_k=bk, block_o=bo, q=q, g=g
                            )
                        except KeyError:
                            violations.append(
                                Violation(
                                    "vmem/sweep", cell,
                                    f"{impl} has no registered VMEM estimator "
                                    "(kernels/introspect.py) — its schedules "
                                    "cannot be budget-checked",
                                )
                            )
                            continue
                        if need > budget:
                            violations.append(
                                Violation(
                                    "vmem/sweep", cell,
                                    f"{impl} blocks ({bk}, {bo}) for leaf {where} "
                                    f"(k={k}, o={o_pad}, q={q}, g={g}) need "
                                    f"~{need} B VMEM/grid-step, over the "
                                    f"{budget} B budget",
                                )
                            )
    return checked, violations, skips


def run(
    *,
    archs: Optional[Sequence[str]] = None,
    fmts: Optional[Sequence[str]] = None,
    tps: Sequence[int] = (1, 2, 4),
) -> PassResult:
    n_table, v_table = validate_tables()
    n_sweep, v_sweep, skips = sweep_configs(archs=archs, fmts=fmts, tps=tps)
    result = PassResult("vmem", checked=n_table + n_sweep, skipped=skips)
    result.violations.extend(v_table)
    result.violations.extend(v_sweep)
    return result
