"""Static contract checker: jaxpr/AST passes proving serving invariants.

The repo's docs make *performance claims* that are really *program-structure
claims* — "2L+1 small collectives per TP decode step, never a weight gather"
(parallel/tp.py), "the host syncs once for the whole sequence"
(infer/engine.py), "packed planes are consumed directly, the dequantized
block never exists in HBM" (kernels/*), "every autotuned schedule fits
VMEM" (kernels/autotune.py). Each of those is checkable *before* any code
runs, by inspecting the traced jaxpr or the source AST. This package is the
checker; ``python -m repro.analysis.staticcheck`` runs every pass and exits
nonzero on the first regression, and CI runs it on every push (DESIGN.md
§10 has the claim → pass → CI-job table).

Passes (one module each):

- :mod:`~repro.analysis.staticcheck.census`    — collective census of the TP
  decode step: exactly the documented count, and no collective ever touches
  a weight- or cache-shaped operand;
- :mod:`~repro.analysis.staticcheck.transfers` — no host callbacks/transfers
  inside the jitted decode programs, and the decode scan traces exactly once
  per (config, fmt, tp);
- :mod:`~repro.analysis.staticcheck.dtypeflow` — packed integer planes stay
  integer-typed from QuantizedTensor leaves to Pallas kernel entry;
- :mod:`~repro.analysis.staticcheck.vmem`      — every autotune-table entry
  and every schedule the registered configs resolve fits the per-core VMEM
  budget (``kernels/introspect.py``);
- :mod:`~repro.analysis.staticcheck.lint`      — AST rules for the host/device
  boundary (``.item()``, undeclared host syncs, raw ``shard_map`` imports,
  bare ``jax.jit``, and the ``repro.obs`` host-only import rule).

All jaxpr passes trace on :class:`jax.ShapeDtypeStruct` trees — full-size
registered configs check in seconds with zero weight memory.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken invariant, with enough provenance to fix it."""

    passname: str  # which pass found it ("census", "lint/host-sync", ...)
    where: str  # cell id ("census:llama3.2-3b/bcq/tp2") or file:line
    message: str  # what is wrong, naming the offending leaf/eqn/entry

    def __str__(self) -> str:
        return f"[{self.passname}] {self.where}: {self.message}"


@dataclasses.dataclass
class PassResult:
    """One pass over one scope: what was checked and what failed."""

    passname: str
    checked: int  # units inspected (cells, eqns, files, entries)
    violations: List[Violation] = dataclasses.field(default_factory=list)
    skipped: List[str] = dataclasses.field(default_factory=list)  # cell: reason

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        state = "ok" if self.ok else f"{len(self.violations)} violation(s)"
        skip = f", {len(self.skipped)} skipped" if self.skipped else ""
        return f"{self.passname}: {self.checked} checked{skip} — {state}"


def run_all(
    *,
    archs: Optional[Sequence[str]] = None,
    fmts: Optional[Sequence[str]] = None,
    tps: Sequence[int] = (1, 2, 4),
    lint_root: Optional[str] = None,
    trace_once: bool = True,
) -> List[PassResult]:
    """Every pass over the registered config × format × tp grid.

    The CLI (``__main__.py``) and the CI gate call this; tests call the
    individual pass modules directly with injected fixtures."""
    from repro.analysis.staticcheck import census, dtypeflow, lint, transfers, vmem
    from repro.analysis.staticcheck.harness import build_cells

    cells, skips = build_cells(archs=archs, fmts=fmts, tps=tps)
    results = [
        census.run(cells, skipped=skips),
        transfers.run(cells, trace_once=trace_once),
        dtypeflow.run(cells),
        vmem.run(archs=archs, fmts=fmts, tps=tps),
        lint.run(root=lint_root),
    ]
    return results


__all__ = ["PassResult", "Violation", "run_all"]
