"""Trace-cell builder: the programs the jaxpr passes inspect.

One :class:`TraceCell` = one (config, quant format, tp degree) combination,
holding the jaxpr of the **TP decode step** — the exact program
``infer/engine.py`` jits on a mesh (``TPContext.forward`` on a decode-shaped
token/cache) — plus the cell's documented collective count and a shape index
of every weight/cache leaf (global AND per-device-local shapes) so passes
can recognise "a collective touched a weight" by operand shape.

Everything traces on :class:`jax.ShapeDtypeStruct` trees (the
``launch/dryrun.py`` technique): ``jax.eval_shape`` materialises the param
and cache *structures* of full-size registered configs with zero weight
memory, ``quant.quantized_structs`` rewrites them to packed form, and
``jax.make_jaxpr`` stages the step. Quantized cells trace under
``kernels.ops.impl_mode("deploy")`` so the jaxpr is the Pallas deployment
program, not the CPU ref oracle (whose dequantize is legitimate and would
drown the dtype-flow pass in false positives).

Configs whose block set the TP path refuses (MoE, recurrent — see
``parallel/tp.py::_TP_BLOCKS``) and policy/shape combinations the strict
spec derivation rejects are reported as *skips with the raising message*,
never silently dropped.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.core.qtensor import QuantizedTensor
from repro.kernels.ops import impl_mode
from repro.models import init_cache, init_params
from repro.quant.quantize import QuantPolicy, quantized_structs

# the default grid: every registered arch, dense + every registered format
DEFAULT_FMTS = ("dense", "bcq", "uniform", "dequant", "codebook", "ternary")
DEFAULT_TPS = (1, 2, 4)
# struct-trace policy: q/g that divide every registered config's matmul dims
TRACE_Q, TRACE_G = 3, 128
_B, _SEQ = 1, 128  # decode-shaped: batch 1, modest cache length


@dataclasses.dataclass
class TraceCell:
    cell_id: str  # "llama3.2-3b/bcq/tp2"
    arch: str
    fmt: str
    tp: int
    closed: jax.core.ClosedJaxpr  # the TP decode step
    expected_collectives: int  # the documented 2L+1 for this config
    shape_index: Dict[Tuple[int, ...], str]  # weight/cache shape -> leaf path


def expected_collectives(cfg) -> int:
    """The documented TP decective count: one psum after ``wo`` + one after
    ``w_down`` per block, plus the final vocab-shard ``all_gather`` — 2L+1
    (parallel/tp.py module docs; pinned by tests/test_staticcheck.py)."""
    total_blocks = sum(len(pattern) * repeat for pattern, repeat in cfg.stages)
    return 2 * total_blocks + 1


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
        for p in path
    )


def _local_shape(shape: Tuple[int, ...], spec, axis: str, tp: int) -> Tuple[int, ...]:
    parts = tuple(spec) if spec is not None else ()
    out = list(shape)
    for i, name in enumerate(parts):
        if name == axis and i < len(out):
            out[i] = out[i] // tp
    return tuple(out)


def _index_tree(index, structs, specs, axis: str, tp: int, prefix: str) -> None:
    """Record every array leaf's global and device-local shape → path."""
    is_qt = lambda x: isinstance(x, QuantizedTensor)
    flat, _ = jax.tree_util.tree_flatten_with_path(structs, is_leaf=is_qt)
    sflat = jax.tree_util.tree_leaves(specs, is_leaf=is_qt)
    for (path, leaf), spec in zip(flat, sflat):
        where = f"{prefix}{_path_str(path)}"
        if isinstance(leaf, QuantizedTensor):
            planes = [
                (leaf.packed.shape, spec.packed, f"{where}.packed"),
                (leaf.scales.shape, spec.scales, f"{where}.scales"),
            ]
        else:
            planes = [(tuple(leaf.shape), spec, where)]
        for shape, pspec, name in planes:
            index.setdefault(tuple(shape), name)
            index.setdefault(_local_shape(shape, pspec, axis, tp), f"{name} (local shard)")


def _token_struct(cfg):
    if cfg.input_kind == "tokens":
        return jax.ShapeDtypeStruct((_B, 1), jnp.int32)
    return jax.ShapeDtypeStruct((_B, 1, cfg.d_model), cfg.cdtype)


def _build_tp_pieces(arch: str, fmt: str, tp: int):
    """(cfg, tpc, param structs, cache structs, tok struct, pos struct).

    Raises whatever the TP stack raises for unsupported combinations — the
    caller converts that into a skip entry."""
    from repro.parallel.tp import TPContext, make_tp_mesh, tp_param_specs

    cfg = get_config(arch)
    mesh = make_tp_mesh(tp)
    tpc = TPContext(cfg, mesh)
    structs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if fmt != "dense":
        structs = quantized_structs(
            structs, QuantPolicy(TRACE_Q, g=TRACE_G, fmt=fmt)
        )
    tpc.param_spec_tree = tp_param_specs(cfg, structs, tpc.ax)
    cache = jax.eval_shape(lambda: init_cache(cfg, _B, _SEQ))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cfg, tpc, structs, cache, _token_struct(cfg), pos


def _step_fn(cfg, tpc):
    tok_kw = "tokens" if cfg.input_kind == "tokens" else "embeddings"

    def step(params, cache, tok, pos):
        kw = {tok_kw: tok}
        if cfg.family == "vlm":
            kw["image_emb"] = None
        logits, cache, _ = tpc.forward(
            params, **kw, cache=cache, pos=pos, logits_mode="last"
        )
        return logits, cache

    return step


def build_cell(arch: str, fmt: str, tp: int) -> TraceCell:
    cfg, tpc, structs, cache, tok, pos = _build_tp_pieces(arch, fmt, tp)
    with impl_mode("deploy"):
        closed = jax.make_jaxpr(_step_fn(cfg, tpc))(structs, cache, tok, pos)
    index: Dict[Tuple[int, ...], str] = {}
    _index_tree(index, structs, tpc.param_spec_tree, tpc.axis_name, tp, "params/")
    _index_tree(
        index, cache, tpc.cache_spec_tree(cache), tpc.axis_name, tp, "cache/"
    )
    return TraceCell(
        cell_id=f"{arch}/{fmt}/tp{tp}",
        arch=arch, fmt=fmt, tp=tp,
        closed=closed,
        expected_collectives=expected_collectives(cfg),
        shape_index=index,
    )


def build_cells(
    *,
    archs: Optional[Sequence[str]] = None,
    fmts: Optional[Sequence[str]] = None,
    tps: Sequence[int] = DEFAULT_TPS,
) -> Tuple[List[TraceCell], List[str]]:
    """The full grid → (cells, skip descriptions). Never raises for
    unsupported combinations; every absence is named."""
    cells: List[TraceCell] = []
    skips: List[str] = []
    for arch in archs or ARCH_IDS:
        for fmt in fmts or DEFAULT_FMTS:
            for tp in tps:
                try:
                    cells.append(build_cell(arch, fmt, tp))
                except (NotImplementedError, ValueError) as e:
                    first = str(e).splitlines()[0]
                    skips.append(f"{arch}/{fmt}/tp{tp}: {first}")
    return cells, skips


def build_injected_cell(
    arch: str = "llama3.2-3b", fmt: str = "bcq", tp: int = 2
) -> TraceCell:
    """A deliberately broken decode step: the normal forward PLUS a
    weight-sized ``all_gather`` of the first sharded packed plane — the
    anti-pattern the collective census exists to catch (a TP implementation
    that re-assembles a weight instead of computing on shards). Used by the
    CLI self-test and tests/test_staticcheck.py; never by serving code."""
    from repro.parallel.compat import shard_map

    cfg, tpc, structs, cache, tok, pos = _build_tp_pieces(arch, fmt, tp)
    axis = tpc.axis_name

    # first QuantizedTensor (or dense) weight leaf with a model-sharded plane
    is_qt = lambda x: isinstance(x, QuantizedTensor)
    flat, _ = jax.tree_util.tree_flatten_with_path(structs, is_leaf=is_qt)
    sflat = jax.tree_util.tree_leaves(tpc.param_spec_tree, is_leaf=is_qt)
    target = None
    for (path, leaf), spec in zip(flat, sflat):
        if isinstance(leaf, QuantizedTensor):
            if axis in tuple(spec.packed):
                target = (path, leaf.packed, spec.packed)
                break
        elif spec is not None and axis in tuple(spec):
            target = (path, leaf, spec)
            break
    if target is None:
        raise RuntimeError(f"no sharded weight leaf in {arch}/{fmt}/tp{tp}")
    path, plane, pspec = target
    shard_dim = tuple(pspec).index(axis)

    def gather_weight(p):
        return jax.lax.all_gather(p, axis, axis=shard_dim, tiled=True)

    gather = shard_map(
        gather_weight,
        mesh=tpc.mesh,
        in_specs=(pspec,),
        out_specs=P(*([None] * len(plane.shape))),
        check_vma=False,
    )
    base = _step_fn(cfg, tpc)

    def pluck(tree):
        node = tree
        for pp in path:
            node = node[getattr(pp, "key", getattr(pp, "idx", pp))]
        return node

    def bad_step(params, cache, tok, pos):
        logits, cache = base(params, cache, tok, pos)
        qt = pluck(params)
        p = qt.packed if isinstance(qt, QuantizedTensor) else qt
        gathered = gather(p)  # the injected weight re-assembly
        return logits, cache, gathered.sum()

    with impl_mode("deploy"):
        closed = jax.make_jaxpr(bad_step)(structs, cache, tok, pos)
    index: Dict[Tuple[int, ...], str] = {}
    _index_tree(index, structs, tpc.param_spec_tree, axis, tp, "params/")
    _index_tree(index, cache, tpc.cache_spec_tree(cache), axis, tp, "cache/")
    return TraceCell(
        cell_id=f"{arch}/{fmt}/tp{tp}+injected-weight-gather",
        arch=arch, fmt=fmt, tp=tp,
        closed=closed,
        expected_collectives=expected_collectives(cfg),
        shape_index=index,
    )
