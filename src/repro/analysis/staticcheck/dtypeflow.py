"""Dtype-flow pass: packed planes stay integer until the Pallas kernel.

The paper's central requirement (§III, "no dequantization overhead") is a
dataflow property of the program: the ``uint8`` packed bit planes must flow
from the ``QuantizedTensor`` leaves into ``pallas_call`` **still integer-
typed**. A ``convert_element_type`` to f32/bf16 on a packed operand outside
a kernel means some code path materialised (part of) the dense weight in
HBM — numerically identical, memory-traffic catastrophic.

The check is classic forward taint propagation over the decode-step jaxpr
(traced under ``impl_mode("deploy")`` so the program under test is the
Pallas deployment, not the CPU ref oracle whose dequantize is the point):

- **sources** — top-level invars with ``uint8`` avals (the packed planes
  are this repo's only uint8 leaves; caches are int8, tokens int32);
- **propagation** — any eqn with a tainted operand taints its
  integer-dtype outputs; higher-order prims (pjit/scan/while/cond/
  shard_map/remat/custom_*) map taint positionally through their
  sub-jaxprs, scan/while carries to a fixpoint;
- **sinks** — ``pallas_call`` consumes taint (its outputs are activations;
  inside the kernel integer→float is exactly the fused dequant-in-VMEM the
  design prescribes);
- **violations** — a tainted operand reaching any eqn with a floating
  output outside a kernel, reported with the eqn, its source line, and the
  originating leaf (recovered from the harness shape index).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax._src import source_info_util

from repro.analysis.staticcheck import PassResult, Violation
from repro.analysis.staticcheck.harness import TraceCell

_SINK_PRIMS = frozenset({"pallas_call"})


def _is_float(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and jnp.issubdtype(dtype, jnp.floating)


def _is_int(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and jnp.issubdtype(dtype, jnp.integer)


def _src(eqn) -> str:
    frame = source_info_util.user_frame(eqn.source_info)
    return f"{frame.file_name}:{frame.start_line}" if frame else "?"


@dataclasses.dataclass
class _Analysis:
    where: str
    violations: List[Violation]

    def flag(self, eqn, origin: str) -> None:
        out_dtypes = sorted(
            {str(v.aval.dtype) for v in eqn.outvars if _is_float(v.aval)}
        )
        self.violations.append(
            Violation(
                "dtypeflow", self.where,
                f"packed plane from {origin} reaches floating "
                f"({'/'.join(out_dtypes)}) output via {eqn.primitive.name} "
                f"at {_src(eqn)} outside any Pallas kernel — the dense "
                "weight is being materialised in HBM",
            )
        )


def _sub_jaxpr(obj):
    if isinstance(obj, jax.core.ClosedJaxpr):
        return obj.jaxpr
    if isinstance(obj, jax.core.Jaxpr):
        return obj
    return None


def _propagate(jaxpr, taint_in: List[Optional[str]], an: _Analysis) -> List[Optional[str]]:
    """Run taint (origin-name or None per var) through one jaxpr's eqns;
    returns per-outvar taint. ``taint_in`` aligns with ``jaxpr.invars``."""
    taint: Dict[object, str] = {}
    for var, t in zip(jaxpr.invars, taint_in):
        if t is not None:
            taint[var] = t

    def tget(atom) -> Optional[str]:
        if isinstance(atom, jax.core.Literal):
            return None  # constants are never packed planes
        return taint.get(atom)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        in_taints = [tget(v) for v in eqn.invars]
        origin = next((t for t in in_taints if t is not None), None)

        if name in _SINK_PRIMS:
            continue  # kernel entry: taint consumed, outputs are activations

        sub = None
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            sub = _sub_jaxpr(eqn.params.get(key))
            if sub is not None:
                break

        if name in ("scan", "while"):
            out_taints = _loop_taint(eqn, in_taints, an)
        elif name == "cond":
            out_taints = [None] * len(eqn.outvars)
            for br in eqn.params.get("branches", ()):
                sj = _sub_jaxpr(br)
                if sj is None:
                    continue
                br_out = _propagate(sj, in_taints[1:], an)
                out_taints = [a or b for a, b in zip(out_taints, br_out)]
        elif sub is not None and len(sub.invars) == len(eqn.invars):
            out_taints = _propagate(sub, in_taints, an)
            if len(out_taints) != len(eqn.outvars):
                out_taints = [origin] * len(eqn.outvars)
        elif origin is None:
            continue
        else:
            # first-order eqn with a tainted operand: integer outputs stay
            # tainted; a floating output is the violation this pass exists for
            out_taints = []
            flagged = False
            for outvar in eqn.outvars:
                if _is_float(outvar.aval):
                    if not flagged:
                        an.flag(eqn, origin)
                        flagged = True
                    out_taints.append(None)
                elif _is_int(outvar.aval):
                    out_taints.append(origin)
                else:
                    out_taints.append(None)  # bool/etc: comparisons launder

        for outvar, t in zip(eqn.outvars, out_taints):
            if t is not None:
                taint[outvar] = t
    return [tget(v) for v in jaxpr.outvars]


def _loop_taint(eqn, in_taints: List[Optional[str]], an: _Analysis) -> List[Optional[str]]:
    """Fixpoint taint for scan/while carries (a carry slot tainted on any
    iteration is tainted on all)."""
    name = eqn.primitive.name
    if name == "scan":
        body = _sub_jaxpr(eqn.params["jaxpr"])
        nc = eqn.params.get("num_consts", 0)
        ncar = eqn.params.get("num_carry", 0)
        consts, carry, xs = (
            in_taints[:nc], in_taints[nc : nc + ncar], in_taints[nc + ncar :]
        )
        quiet = _Analysis(an.where, [])  # only the converged pass reports
        for _ in range(len(carry) + 1):
            body_out = _propagate(body, consts + carry + xs, quiet)
            new_carry = [a or b for a, b in zip(carry, body_out[:ncar])]
            if new_carry == carry:
                break
            carry = new_carry
        body_out = _propagate(body, consts + carry + xs, an)
        return body_out[:ncar] + body_out[ncar:]
    # while: invars = cond_consts + body_consts + carry
    cond_j = _sub_jaxpr(eqn.params["cond_jaxpr"])
    body_j = _sub_jaxpr(eqn.params["body_jaxpr"])
    cn = eqn.params.get("cond_nconsts", 0)
    bn = eqn.params.get("body_nconsts", 0)
    cconsts = in_taints[:cn]
    bconsts = in_taints[cn : cn + bn]
    carry = in_taints[cn + bn :]
    quiet = _Analysis(an.where, [])
    for _ in range(len(carry) + 1):
        body_out = _propagate(body_j, bconsts + carry, quiet)
        new_carry = [a or b for a, b in zip(carry, body_out)]
        if new_carry == carry:
            break
        carry = new_carry
    _propagate(cond_j, cconsts + carry, an)
    return _propagate(body_j, bconsts + carry, an)


def analyze(closed: jax.core.ClosedJaxpr, cell_id: str, shape_index=None) -> List[Violation]:
    """Taint-check one traced program. Sources = uint8 top-level invars;
    origins are named via the harness shape index when available."""
    jaxpr = closed.jaxpr
    shape_index = shape_index or {}
    taint_in: List[Optional[str]] = []
    for var in jaxpr.invars:
        aval = var.aval
        if getattr(aval, "dtype", None) is not None and str(aval.dtype) == "uint8":
            shape = tuple(aval.shape)
            taint_in.append(
                shape_index.get(shape, f"uint8 leaf {shape}")
            )
        else:
            taint_in.append(None)
    an = _Analysis(cell_id, [])
    _propagate(jaxpr, taint_in, an)
    # de-duplicate: the same offending eqn inside a scanned layer body would
    # otherwise repeat per origin leaf
    seen, unique = set(), []
    for v in an.violations:
        key = (v.where, v.message)
        if key not in seen:
            seen.add(key)
            unique.append(v)
    return unique


def run(cells: Sequence[TraceCell]) -> PassResult:
    result = PassResult("dtypeflow", checked=0)
    for cell in cells:
        if cell.fmt == "dense":
            continue  # no packed planes to track
        result.checked += 1
        result.violations.extend(analyze(cell.closed, cell.cell_id, cell.shape_index))
    return result
