"""AST lint: repo-specific host/device-boundary rules.

The jaxpr passes prove what happens *inside* jit; these rules police the
Python that surrounds it. Each rule encodes a lesson this codebase already
paid for (DESIGN.md §10):

- ``no-item``       — ``.item()`` on a device value blocks the dispatch
  queue per element; there is no legitimate hot-path use, so no pragma
  escape exists for this rule.
- ``host-sync``     — ``np.asarray``/``np.array``/``jax.device_get``/
  ``jax.block_until_ready``/``float(f(...))``/``int(f(...))`` force a
  device→host sync. They are sometimes exactly right (fetching final
  tokens, the scheduler's chunk-boundary guard) — so the rule demands each
  site *declare itself* with ``# staticcheck: host-sync(reason)`` on the
  same line. Undeclared syncs are violations; the pragma inventory is the
  audit trail.
- ``raw-shard-map`` — ``jax.experimental.shard_map`` may be imported ONLY
  by ``parallel/compat.py`` (the version-compat seam); everyone else goes
  through it so a JAX upgrade is a one-file change.
- ``bare-jit``      — ``jax.jit(f)`` with zero keywords in hot-path
  modules: nearly every jit here needs ``static_argnames`` or
  ``donate_argnums``; a bare one is usually an unconsidered default.
  Intentional ones declare ``# staticcheck: jit-ok(reason)``.
- ``obs-host-only`` — ``repro/obs`` is the host-side observability layer
  (DESIGN.md §11): its modules may not import jax or the jitted
  kernel/model packages at module level. The dependency edge must point
  instrumented-code → obs, never back — otherwise the tracer could reach
  device state and the "instrumentation is bit-identical and adds no
  compile-cache entries" guarantee (tests/test_obs.py) stops being
  structural. Function-local imports (the CLI demo building an Engine)
  are allowed: they run only when a demo/CLI entry point is invoked.

Scope: ``infer/``, ``kernels/``, ``models/``, ``parallel/`` under
``src/repro`` (the serving hot path); ``raw-shard-map`` scans all of
``src/repro``; ``obs-host-only`` scans ``obs/``. Tests/benchmarks/launch
scripts are host programs and out of scope by design.
"""

from __future__ import annotations

import ast
import os
import re
from typing import List, Optional, Sequence, Tuple

from repro.analysis.staticcheck import PassResult, Violation

HOT_DIRS = ("infer", "kernels", "models", "parallel")
_PRAGMA = re.compile(r"#\s*staticcheck:\s*([a-z-]+)\(([^)]*)\)")

_NP_NAMES = {"np", "numpy"}
_NP_SYNC_ATTRS = {"asarray", "array"}
_JAX_SYNC_ATTRS = {"device_get", "block_until_ready"}


def _pragmas_by_line(source: str):
    out = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA.search(line)
        if m:
            out.setdefault(i, set()).add(m.group(1))
    return out


def _dotted(node) -> Optional[str]:
    """'jax.jit' / 'np.asarray' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def lint_source(source: str, relpath: str) -> List[Violation]:
    """All rule hits for one file. ``relpath`` is repo-relative for messages
    and for the compat-seam allowance."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Violation("lint", f"{relpath}:{e.lineno}", f"unparseable: {e.msg}")]
    pragmas = _pragmas_by_line(source)
    in_hot = any(f"/{d}/" in f"/{relpath}" or relpath.startswith(f"{d}/") for d in HOT_DIRS)
    in_obs = "/obs/" in f"/{relpath}" or relpath.startswith("obs/")
    is_compat_seam = relpath.endswith("parallel/compat.py") or relpath == "parallel/compat.py"
    out: List[Violation] = []

    def has(line: int, tag: str) -> bool:
        return tag in pragmas.get(line, ())

    if in_obs:
        # obs-host-only: only MODULE-LEVEL imports (tree.body, plus
        # module-level try/if blocks) — function-local imports are the
        # sanctioned lazy pattern for CLI demos
        out.extend(_obs_host_only(tree, relpath))

    for node in ast.walk(tree):
        # raw-shard-map: applies everywhere except the compat seam
        if isinstance(node, ast.ImportFrom) and not is_compat_seam:
            mod = node.module or ""
            if mod == "jax.experimental.shard_map" or (
                mod == "jax.experimental"
                and any(a.name == "shard_map" for a in node.names)
            ):
                out.append(
                    Violation(
                        "lint/raw-shard-map", f"{relpath}:{node.lineno}",
                        "import shard_map from repro.parallel.compat, not "
                        "jax.experimental (version-compat seam)",
                    )
                )
        if isinstance(node, ast.Import) and not is_compat_seam:
            for a in node.names:
                if a.name.startswith("jax.experimental.shard_map"):
                    out.append(
                        Violation(
                            "lint/raw-shard-map", f"{relpath}:{node.lineno}",
                            "import shard_map from repro.parallel.compat, not "
                            "jax.experimental (version-compat seam)",
                        )
                    )

        if not in_hot or not isinstance(node, ast.Call):
            continue
        line = node.lineno
        name = _dotted(node.func)

        # no-item: .item() call on anything — no pragma escape
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item":
            out.append(
                Violation(
                    "lint/no-item", f"{relpath}:{line}",
                    ".item() blocks the dispatch queue per element; fetch "
                    "whole arrays once (np.asarray + host-sync pragma) or "
                    "keep the value on device",
                )
            )
            continue

        # host-sync: device→host fetches must declare themselves
        sync = False
        if name is not None:
            head, _, tail = name.rpartition(".")
            if head in _NP_NAMES and tail in _NP_SYNC_ATTRS:
                sync = True
            if head == "jax" and tail in _JAX_SYNC_ATTRS:
                sync = True
        if name in ("float", "int") and node.args and isinstance(node.args[0], ast.Call):
            sync = True  # float(f(...)): classic silent sync on a device value
        if sync and not has(line, "host-sync"):
            out.append(
                Violation(
                    "lint/host-sync", f"{relpath}:{line}",
                    f"{name}(...) forces a device→host sync; if intentional, "
                    "declare it: `# staticcheck: host-sync(reason)`",
                )
            )

        # bare-jit: jax.jit with zero keywords in hot paths
        if name == "jax.jit" and not node.keywords and not has(line, "jit-ok"):
            out.append(
                Violation(
                    "lint/bare-jit", f"{relpath}:{line}",
                    "bare jax.jit in a hot path: consider static_argnames/"
                    "donate_argnums, or declare `# staticcheck: jit-ok(reason)`",
                )
            )
    return out


# import roots forbidden at module level inside repro/obs: jax itself and
# every package whose modules import jax at module level (the jitted stack)
_OBS_FORBIDDEN = (
    "jax",
    "repro.kernels",
    "repro.models",
    "repro.parallel",
    "repro.infer",
    "repro.quant",
    "repro.core",
)


def _module_level_nodes(tree: ast.Module):
    """Module-scope statements, descending through module-level try/if/with
    blocks (the optional-dependency idiom) but never into function or class
    bodies — imports there execute lazily and are allowed."""
    stack = list(tree.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.Try, ast.If, ast.With)):
            for field in ("body", "orelse", "finalbody", "handlers"):
                for child in getattr(node, field, []):
                    stack.append(
                        child.body if isinstance(child, ast.ExceptHandler) else child
                    )
        # flatten except-handler bodies pushed as lists above
        if stack and isinstance(stack[-1], list):
            stack.extend(stack.pop())


def _obs_host_only(tree: ast.Module, relpath: str) -> List[Violation]:
    out: List[Violation] = []

    def check(modname: Optional[str], lineno: int) -> None:
        if modname is None:
            return
        if any(
            modname == root or modname.startswith(root + ".")
            for root in _OBS_FORBIDDEN
        ):
            out.append(
                Violation(
                    "lint/obs-host-only", f"{relpath}:{lineno}",
                    f"repro.obs is host-side-only: module-level import of "
                    f"{modname!r} pulls the jitted stack (or jax) into the "
                    f"observability layer — import it inside the function "
                    f"that needs it (CLI/demo entry points only)",
                )
            )

    for node in _module_level_nodes(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                check(a.name, node.lineno)
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            check(node.module, node.lineno)
    return out


def repo_root() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.normpath(os.path.join(here, "..", ".."))  # .../src/repro


def iter_files(root: Optional[str] = None):
    root = root or repo_root()
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                full = os.path.join(dirpath, fn)
                yield full, os.path.relpath(full, root)


def run(root: Optional[str] = None, *, rules: Optional[Sequence[str]] = None) -> PassResult:
    result = PassResult("lint", checked=0)
    for full, rel in iter_files(root):
        result.checked += 1
        with open(full) as f:
            source = f.read()
        hits = lint_source(source, rel)
        if rules is not None:
            hits = [v for v in hits if v.passname.split("/", 1)[-1] in rules]
        result.violations.extend(hits)
    return result
