"""Compiled-artifact analysis: HLO collective parsing + roofline model."""

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import HW, Roofline, roofline

__all__ = ["HW", "Roofline", "collective_bytes", "roofline"]
