"""Parse collective-communication bytes out of post-SPMD HLO text.

``cost_analysis()`` does not expose collective traffic, so we sweep the
compiled module for every ``all-gather`` / ``all-reduce`` / ``reduce-scatter``
/ ``all-to-all`` / ``collective-permute`` (sync and ``-start`` async forms) and
sum their result-shape bytes. Per-op wire-byte multipliers for ring algorithms
are applied separately in the roofline (see ``roofline.py``).
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# result of an HLO op: `%name = <shape-or-tuple> op-name(...)`
_LINE_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(?P<op>" + "|".join(COLLECTIVE_KINDS) + r")(?P<suffix>-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, .]+?)[\}\]]")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    """Participants per replica group (for ring wire-byte factors)."""
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split(",")
        return max(1, len([x for x in first if x.strip() != ""]))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, dict]:
    """→ {kind: {"count", "bytes", "wire_bytes"}} from post-SPMD HLO.

    ``bytes`` sums result-shape bytes (the assignment's collective_bytes).
    ``wire_bytes`` applies ring-algorithm factors per op:
      all-reduce 2(n-1)/n · b, all-gather/reduce-scatter (n-1)/n · b,
      all-to-all (n-1)/n · b, collective-permute 1 · b.
    """
    out: Dict[str, dict] = {
        k: {"count": 0, "bytes": 0, "wire_bytes": 0.0} for k in COLLECTIVE_KINDS
    }
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue  # count async ops once, at -start
        kind = m.group("op")
        b = _shape_bytes(m.group("shapes"))
        n = _group_size(line)
        factor = {
            "all-reduce": 2.0 * (n - 1) / max(n, 1),
            "all-gather": (n - 1) / max(n, 1),
            "reduce-scatter": (n - 1) / max(n, 1),
            "all-to-all": (n - 1) / max(n, 1),
            "collective-permute": 1.0,
        }[kind]
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
        out[kind]["wire_bytes"] += b * factor
    return out


def total_collective_bytes(stats: Dict[str, dict], wire: bool = False) -> float:
    key = "wire_bytes" if wire else "bytes"
    return float(sum(v[key] for v in stats.values()))
