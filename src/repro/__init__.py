"""repro: LUT-GEMM / nuQmm — group-wise BCQ quantized inference framework in JAX.

Implements the paper "LUT-GEMM: Quantized Matrix Multiplication based on LUTs
for Efficient Inference in Large-Scale Generative Language Models"
(a.k.a. nuQmm, arXiv:2206.09557) as a production-grade multi-pod framework:

- ``repro.core``     group-wise binary-coding quantization (BCQ) math
- ``repro.kernels``  Pallas TPU kernels (LUT-GEMM + variants) with jnp oracles
- ``repro.models``   decoder-model zoo (dense / MoE / VLM / audio / hybrid / sLSTM)
- ``repro.quant``    model-level quantization + mixed precision policies
- ``repro.parallel`` mesh + sharding rules (DP/FSDP/TP/EP/SP, multi-pod)
- ``repro.train``    optimizer, train loop, checkpointing, fault tolerance
- ``repro.infer``    prefill/decode split engine (paper Fig. 13)
- ``repro.analysis`` HLO collective parsing + roofline model
- ``repro.configs``  assigned architecture configs
- ``repro.launch``   mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
