"""Model-level quantization: per-layer BCQ policies + mixed precision."""

from repro.quant.quantize import (
    QuantPolicy,
    quantize_params,
    quantized_structs,
    quantized_bytes,
    truncate_params,
)

__all__ = [
    "QuantPolicy",
    "quantize_params",
    "quantized_structs",
    "quantized_bytes",
    "truncate_params",
]
