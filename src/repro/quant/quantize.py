"""Walk a model param pytree and quantize its linear weights.

``QuantPolicy`` expresses the paper's search space plus the format registry:
one global ``(q, g, fmt)`` or a *mixed* assignment per sublayer type
(attention vs FFN vs LM head — paper §V.A / Fig. 12, "all matrices of the
same sub-layer type share a (q,g) configuration"; per-path entries may also
pick a different registered format, e.g. BCQ attention + uniform FFN, for
mixed-format models — DESIGN.md §2.4).

``quantize_params`` produces real packed weights; ``quantized_structs``
produces the same pytree with ShapeDtypeStruct leaves (for dry-run lowering of
multi-hundred-GB models without allocating them).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import get_format
from repro.core.qtensor import QuantizedTensor

# leaves eligible for quantization (2D (k,o) matmul weights, possibly
# layer/expert-stacked)
_QUANT_NAMES = frozenset(
    {
        "wq", "wk", "wv", "wo",  # attention
        "w_gate", "w_up", "w_down",  # (shared-)MLP and MoE experts
        "w_x", "w_y", "w_a", "w_i", "w_out",  # RG-LRU block linears
        "w_z", "w_f", "w_o",  # sLSTM / mLSTM gate projections
        "lm_head",
    }
)
_MIN_DIM = 128  # skip tiny projections (e.g. mLSTM per-head gate (inner, 4))


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """(q, g[, fmt]) per sublayer type. ``None`` → use defaults; g adapts to
    each k. Per-path entries are 2-tuples ``(q, g)`` (inheriting ``fmt``) or
    3-tuples ``(q, g, fmt)`` for mixed-format models."""

    q: int = 4
    g: int = 128
    attn: Optional[Tuple] = None  # (q, g[, fmt]) for attention projections
    ffn: Optional[Tuple] = None  # (q, g[, fmt]) for MLP/MoE/recurrent linears
    lm_head: Optional[Tuple] = None
    skip_lm_head: bool = False
    method: str = "alternating"  # "alternating" | "greedy" (BCQ solvers)
    iters: int = 8
    scale_dtype: str = "bfloat16"
    fmt: str = "bcq"  # default registered format (core/formats.py)

    def resolve(self, path_keys: Tuple[str, ...]) -> Optional[Tuple]:
        """The raw per-path entry (2- or 3-tuple), or the (q, g) defaults."""
        name = path_keys[-1]
        if name not in _QUANT_NAMES:
            return None
        if name == "lm_head":
            if self.skip_lm_head:
                return None
            return self.lm_head or (self.q, self.g)
        if "attn" in path_keys:
            return self.attn or (self.q, self.g)
        return self.ffn or (self.q, self.g)

    def resolve_fmt(self, path_keys: Tuple[str, ...]) -> Optional[Tuple[int, int, str]]:
        """Fully-resolved ``(q, g, fmt)`` for a leaf path (None → ineligible)."""
        qg = self.resolve(path_keys)
        if qg is None:
            return None
        if len(qg) == 2:
            return (qg[0], qg[1], self.fmt)
        return (qg[0], qg[1], qg[2])


def _effective_g(k: int, g: int) -> int:
    """Largest group size <= g that divides k and is a multiple of 8."""
    g = min(g, k)
    while g >= 8:
        if k % g == 0 and g % 8 == 0:
            return g
        g -= 8
    return 0


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return tuple(out)


def _eligible(leaf, qg) -> bool:
    return (
        qg is not None
        and hasattr(leaf, "ndim")
        and leaf.ndim >= 2
        and leaf.shape[-1] >= _MIN_DIM
        and leaf.shape[-2] >= _MIN_DIM
        and leaf.shape[-2] % 8 == 0
    )


def _quantize_leaf(
    leaf: jax.Array, q: int, g: int, fmt: str, policy: QuantPolicy
) -> QuantizedTensor:
    *lead, k, o = leaf.shape
    g_eff = _effective_g(k, g)
    if not g_eff:
        raise ValueError(f"no valid group size for k={k} (requested g={g})")
    fobj = get_format(fmt)
    flat = leaf.reshape(-1, k, o).astype(jnp.float32)

    def one(w):
        qt = fobj.quantize(
            w,
            q=q,
            g=g_eff,
            scale_dtype=jnp.dtype(policy.scale_dtype),
            method=policy.method,
            iters=policy.iters,
        )
        return qt.packed, qt.scales

    packed, scales = jax.lax.map(one, flat)
    packed = packed.reshape(*lead, *packed.shape[1:])
    scales = scales.reshape(*lead, *scales.shape[1:])
    return QuantizedTensor(
        packed=packed, scales=scales, g=g_eff, k=k, o=o, fmt=fmt
    )


def quantize_params(params, policy: QuantPolicy):
    """Replace every eligible dense leaf with a packed QuantizedTensor."""

    def visit(path, leaf):
        qgf = policy.resolve_fmt(_path_names(path))
        if not _eligible(leaf, qgf):
            return leaf
        return _quantize_leaf(leaf, qgf[0], qgf[1], qgf[2], policy)

    return jax.tree_util.tree_map_with_path(visit, params)


def truncate_params(params, q_draft: int):
    """Truncate every QuantizedTensor leaf to its nested ``q_draft``-bit view.

    The cheap-draft side of self-speculative decoding (infer/speculative.py):
    packed planes and scales are sliced to the first ``min(q_draft, q)``
    (the format's ``truncate`` capability — BCQ's planes are successive
    residual refinements, so the prefix is itself a valid lower-bit model).
    Every other leaf — norms, embeddings, dense (unquantized) linears — is
    returned *as is*, shared by reference with the full-precision tree: the
    draft costs no extra weight memory beyond what the slices materialise.

    Works on fused decode trees too (truncation slices the q axis, which
    fusion never touches), so the engine truncates its post-`fuse` params.

    Raises a ``ValueError`` naming the format when any quantized leaf's
    format lacks the truncate capability (uniform/dequant codes are not
    residual-nested — there is no valid draft hiding inside them).
    """
    if q_draft < 1:
        raise ValueError(f"q_draft must be >= 1, got {q_draft}")

    def visit(leaf):
        if isinstance(leaf, QuantizedTensor):
            return get_format(leaf.fmt).truncate(leaf, min(q_draft, leaf.q))
        return leaf

    return jax.tree.map(
        visit, params, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )


def quantized_structs(param_structs, policy: QuantPolicy):
    """Same tree surgery, but on ShapeDtypeStructs (no data, no compute)."""

    def visit(path, leaf):
        qgf = policy.resolve_fmt(_path_names(path))
        if not _eligible(leaf, qgf):
            return leaf
        *lead, k, o = leaf.shape
        q, g, fmt = qgf
        g_eff = _effective_g(k, g)
        return get_format(fmt).struct(
            tuple(lead), k, o, q, g_eff, jnp.dtype(policy.scale_dtype)
        )

    return jax.tree_util.tree_map_with_path(visit, param_structs)


def quantized_bytes(tree) -> int:
    """Total parameter bytes (packed where quantized)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total
