"""Walk a model param pytree and quantize its linear weights to BCQ.

``QuantPolicy`` expresses the paper's search space: one global ``(q, g)`` or a
*mixed-precision* assignment per sublayer type (attention vs FFN vs LM head —
paper §V.A / Fig. 12, "all matrices of the same sub-layer type share a (q,g)
configuration").

``quantize_params`` produces real packed weights; ``quantized_structs``
produces the same pytree with ShapeDtypeStruct leaves (for dry-run lowering of
multi-hundred-GB models without allocating them).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bcq import quantize_bcq, quantize_bcq_greedy
from repro.core.packing import pack_signs
from repro.core.qtensor import QuantizedTensor

# leaves eligible for BCQ (2D (k,o) matmul weights, possibly layer/expert-stacked)
_QUANT_NAMES = frozenset(
    {
        "wq", "wk", "wv", "wo",  # attention
        "w_gate", "w_up", "w_down",  # (shared-)MLP and MoE experts
        "w_x", "w_y", "w_a", "w_i", "w_out",  # RG-LRU block linears
        "w_z", "w_f", "w_o",  # sLSTM / mLSTM gate projections
        "lm_head",
    }
)
_MIN_DIM = 128  # skip tiny projections (e.g. mLSTM per-head gate (inner, 4))


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """(q, g) per sublayer type. ``None`` → use default; g adapts to each k."""

    q: int = 4
    g: int = 128
    attn: Optional[Tuple[int, int]] = None  # (q, g) for attention projections
    ffn: Optional[Tuple[int, int]] = None  # (q, g) for MLP/MoE/recurrent linears
    lm_head: Optional[Tuple[int, int]] = None
    skip_lm_head: bool = False
    method: str = "alternating"  # "alternating" | "greedy"
    iters: int = 8
    scale_dtype: str = "bfloat16"

    def resolve(self, path_keys: Tuple[str, ...]) -> Optional[Tuple[int, int]]:
        name = path_keys[-1]
        if name not in _QUANT_NAMES:
            return None
        if name == "lm_head":
            if self.skip_lm_head:
                return None
            return self.lm_head or (self.q, self.g)
        if "attn" in path_keys:
            return self.attn or (self.q, self.g)
        return self.ffn or (self.q, self.g)


def _effective_g(k: int, g: int) -> int:
    """Largest group size <= g that divides k and is a multiple of 8."""
    g = min(g, k)
    while g >= 8:
        if k % g == 0 and g % 8 == 0:
            return g
        g -= 8
    return 0


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return tuple(out)


def _eligible(leaf, qg) -> bool:
    return (
        qg is not None
        and hasattr(leaf, "ndim")
        and leaf.ndim >= 2
        and leaf.shape[-1] >= _MIN_DIM
        and leaf.shape[-2] >= _MIN_DIM
        and leaf.shape[-2] % 8 == 0
    )


def _quantize_leaf(leaf: jax.Array, q: int, g: int, policy: QuantPolicy) -> QuantizedTensor:
    *lead, k, o = leaf.shape
    g_eff = _effective_g(k, g)
    if not g_eff:
        raise ValueError(f"no valid group size for k={k} (requested g={g})")
    flat = leaf.reshape(-1, k, o).astype(jnp.float32)

    def one(w):
        if policy.method == "alternating":
            scales, binary = quantize_bcq(w, q=q, g=g_eff, iters=policy.iters)
        else:
            scales, binary = quantize_bcq_greedy(w, q=q, g=g_eff)
        return pack_signs(binary), scales.astype(jnp.dtype(policy.scale_dtype))

    packed, scales = jax.lax.map(one, flat)
    packed = packed.reshape(*lead, q, k // 8, o)
    scales = scales.reshape(*lead, q, k // g_eff, o)
    return QuantizedTensor(packed=packed, scales=scales, g=g_eff, k=k, o=o)


def quantize_params(params, policy: QuantPolicy):
    """Replace every eligible dense leaf with a packed QuantizedTensor."""

    def visit(path, leaf):
        qg = policy.resolve(_path_names(path))
        if not _eligible(leaf, qg):
            return leaf
        return _quantize_leaf(leaf, qg[0], qg[1], policy)

    return jax.tree_util.tree_map_with_path(visit, params)


def truncate_params(params, q_draft: int):
    """Truncate every QuantizedTensor leaf to its nested ``q_draft``-bit view.

    The cheap-draft side of self-speculative decoding (infer/speculative.py):
    packed planes and scales are sliced to the first ``min(q_draft, q)``
    (:meth:`QuantizedTensor.truncate` — BCQ's planes are successive residual
    refinements, so the prefix is itself a valid lower-bit model). Every other
    leaf — norms, embeddings, dense (unquantized) linears — is returned *as
    is*, shared by reference with the full-precision tree: the draft costs no
    extra weight memory beyond what the slices materialise.

    Works on fused decode trees too (truncation slices the q axis, which
    fusion never touches), so the engine truncates its post-`fuse` params.
    """
    if q_draft < 1:
        raise ValueError(f"q_draft must be >= 1, got {q_draft}")

    def visit(leaf):
        if isinstance(leaf, QuantizedTensor):
            return leaf.truncate(min(q_draft, leaf.q))
        return leaf

    return jax.tree.map(
        visit, params, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )


def quantized_structs(param_structs, policy: QuantPolicy):
    """Same tree surgery, but on ShapeDtypeStructs (no data, no compute)."""

    def visit(path, leaf):
        qg = policy.resolve(_path_names(path))
        if not _eligible(leaf, qg):
            return leaf
        *lead, k, o = leaf.shape
        q, g = qg
        g_eff = _effective_g(k, g)
        return QuantizedTensor(
            packed=jax.ShapeDtypeStruct((*lead, q, k // 8, o), jnp.uint8),
            scales=jax.ShapeDtypeStruct(
                (*lead, q, k // g_eff, o), jnp.dtype(policy.scale_dtype)
            ),
            g=g_eff,
            k=k,
            o=o,
        )

    return jax.tree_util.tree_map_with_path(visit, param_structs)


def quantized_bytes(tree) -> int:
    """Total parameter bytes (packed where quantized)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total
