"""QuantizedTensor — the packed group-wise BCQ weight container.

This is the on-device format the whole framework moves around: packed binary
codes + group scales, registered as a JAX pytree so it shards under pjit,
checkpoints, and passes through ``jax.jit`` boundaries like any array.

Memory per weight (paper Eq. 3): ``q·(1 + scale_bits/g)`` bits vs 16 (bf16).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import bcq as bcq_lib
from repro.core import packing


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Group-wise BCQ representation of a ``(k, o)`` weight matrix.

    Attributes
    ----------
    packed : uint8 ``(q, k // 8, o)`` — binary codes, 8 per byte (LSB-first),
        byte index = LUT key (paper Table II).
    scales : ``(q, k // g, o)`` — per-group scaling factors (bf16 by default).
    g      : static group size.
    k, o   : static logical shape (``y = x @ W``; ``k`` is the reduction dim).
    """

    packed: jax.Array
    scales: jax.Array
    g: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    o: int = dataclasses.field(metadata=dict(static=True))

    @property
    def q(self) -> int:
        return self.packed.shape[-3]  # robust to leading layer/expert stacking

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.k, self.o)

    @property
    def dtype(self):
        return self.scales.dtype

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        """Reconstruct the dense ``(…, k, o)`` matrix (prefill path, Fig. 13).

        ``dtype`` controls the materialised precision: serving paths pass the
        compute dtype (bf16) — halves the dequant HBM round-trip vs f32 and
        matches what the fused TPU kernel computes in registers.
        """
        signs = packing.unpack_signs(self.packed)  # (…, q, k, o) int8
        w = bcq_lib.dequantize(self.scales.astype(jnp.float32), signs, self.g)
        return w.astype(dtype)

    def nbytes(self) -> int:
        """Packed size in bytes (binary + scales)."""
        return int(self.packed.size) + int(self.scales.size) * self.scales.dtype.itemsize

    def truncate(self, q_new: int) -> "QuantizedTensor":
        """The nested ``q_new``-bit approximation living inside this tensor.

        BCQ is nested by construction (paper §III.A): the greedy solver builds
        plane ``i`` as a refinement of the residual left by planes ``< i``, so
        ``packed[:q_new], scales[:q_new]`` is itself a valid ``q_new``-bit BCQ
        of the same weight — bit-identical to what the greedy solver would
        emit at ``q=q_new``. This is what makes every quantized model a free
        family of cheaper draft models (infer/speculative.py).

        The slice is a view at trace time (no repacking, no re-solve); ``g``,
        ``k``, ``o`` and any leading layer/expert stacking are preserved.
        """
        if not 1 <= q_new <= self.q:
            raise ValueError(f"cannot truncate q={self.q} tensor to q'={q_new}")
        if q_new == self.q:
            return self
        return QuantizedTensor(
            packed=self.packed[..., :q_new, :, :],
            scales=self.scales[..., :q_new, :, :],
            g=self.g,
            k=self.k,
            o=self.o,
        )


def fuse_tensors(qts: Sequence[QuantizedTensor]) -> QuantizedTensor:
    """Concatenate N quantized projections along the output dim (DESIGN.md §2.3).

    One-time weight-prep for the fused multi-projection kernel: the result's
    ``x @ W`` equals the per-tensor products side by side, so a single kernel
    pass serves all N projections. Requires identical ``(k, q, g)`` and scale
    dtype — true for Q/K/V and gate/up under any per-sublayer-type policy.
    """
    first = qts[0]
    for t in qts[1:]:
        if (t.k, t.q, t.g) != (first.k, first.q, first.g):
            raise ValueError(
                f"cannot fuse: (k, q, g) mismatch {(t.k, t.q, t.g)} vs "
                f"{(first.k, first.q, first.g)}"
            )
        if t.scales.dtype != first.scales.dtype:
            raise ValueError("cannot fuse: scale dtype mismatch")
        if t.packed.shape[:-1] != first.packed.shape[:-1]:
            raise ValueError("cannot fuse: leading (layer/expert) dims differ")
    return QuantizedTensor(
        packed=jnp.concatenate([t.packed for t in qts], axis=-1),
        scales=jnp.concatenate([t.scales for t in qts], axis=-1),
        g=first.g,
        k=first.k,
        o=sum(t.o for t in qts),
    )


def quantize_tensor(
    w: jax.Array,
    q: int,
    g: int,
    iters: int = 10,
    scale_dtype=jnp.bfloat16,
    method: str = "alternating",
) -> QuantizedTensor:
    """Quantize a dense ``(k, o)`` weight to a :class:`QuantizedTensor`.

    ``method``: ``"alternating"`` (paper's PTQ solver, Xu et al. [20]) or
    ``"greedy"`` (init only; much faster, used for huge layers and tests).
    """
    k, o = w.shape
    if method == "alternating":
        scales, binary = bcq_lib.quantize_bcq(w, q=q, g=g, iters=iters)
    elif method == "greedy":
        scales, binary = bcq_lib.quantize_bcq_greedy(w, q=q, g=g)
    else:
        raise ValueError(f"unknown method {method!r}")
    return QuantizedTensor(
        packed=packing.pack_signs(binary),
        scales=scales.astype(scale_dtype),
        g=g,
        k=k,
        o=o,
    )
