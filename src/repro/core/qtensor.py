"""QuantizedTensor — the packed quantized-weight container, tagged by format.

This is the on-device representation the whole framework moves around: packed
codes + group scales, registered as a JAX pytree so it shards under pjit,
checkpoints, and passes through ``jax.jit`` boundaries like any array.

The container itself is format-agnostic (DESIGN.md §2.4): ``fmt`` names a
registered :class:`~repro.core.formats.QuantFormat` that owns the semantics —
how ``packed``/``scales`` encode the weight, which kernels consume them, how
they shard under tensor parallelism, and which capabilities (nested
truncation, output-dim fusion) apply. All formats share the physical layout

    packed : uint8 ``(…, P, k // 8, o)`` — P bit planes, 8 codes per byte
             (LSB-first along k; a byte is directly a LUT key for BCQ)
    scales : ``(…, S, k // g, o)``       — per-group affine parameters

so sharding/fusion/stacking machinery works uniformly; only P, S and the
reconstruction rule differ per format (BCQ: P = q sign planes, S = q scale
planes; uniform/dequant: P = q magnitude bit planes, S = 2 (scale, zero)).

Memory per weight (paper Eq. 3 for BCQ): ``q·(1 + scale_bits/g)`` bits vs 16.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Format-tagged group-wise quantization of a ``(k, o)`` weight matrix.

    Attributes
    ----------
    packed : uint8 ``(P, k // 8, o)`` — packed code planes (see module doc).
    scales : ``(S, k // g, o)`` — per-group scaling factors (bf16 by default).
    g      : static group size.
    k, o   : static logical shape (``y = x @ W``; ``k`` is the reduction dim).
    fmt    : static format tag — a :mod:`repro.core.formats` registry name.
    """

    packed: jax.Array
    scales: jax.Array
    g: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    o: int = dataclasses.field(metadata=dict(static=True))
    fmt: str = dataclasses.field(default="bcq", metadata=dict(static=True))

    @property
    def q(self) -> int:
        """Code planes (BCQ: bit planes = q; uniform: magnitude bits).
        Read from the shape so it is robust to leading layer/expert stacking."""
        return self.packed.shape[-3]

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.k, self.o)

    @property
    def dtype(self):
        return self.scales.dtype

    def format(self):
        """The registered :class:`~repro.core.formats.QuantFormat` object."""
        from repro.core.formats import get_format

        return get_format(self.fmt)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        """Reconstruct the dense ``(…, k, o)`` matrix (prefill path, Fig. 13).

        ``dtype`` controls the materialised precision: serving paths pass the
        compute dtype (bf16) — halves the dequant HBM round-trip vs f32 and
        matches what the fused TPU kernel computes in registers.
        """
        return self.format().dequantize(self, dtype=dtype)

    def nbytes(self) -> int:
        """Packed size in bytes (code planes + scales)."""
        return self.format().nbytes(self)

    def truncate(self, q_new: int) -> "QuantizedTensor":
        """The nested ``q_new``-bit approximation living inside this tensor.

        A format *capability*: BCQ is nested by construction (paper §III.A —
        ``packed[:q_new], scales[:q_new]`` is itself a valid ``q_new``-bit BCQ
        of the same weight), which is what makes every BCQ model a free family
        of cheaper draft models (infer/speculative.py). Formats without the
        capability raise a ``ValueError`` naming themselves.
        """
        return self.format().truncate(self, q_new)


def fuse_tensors(qts: Sequence[QuantizedTensor]) -> QuantizedTensor:
    """Concatenate N quantized projections along the output dim (DESIGN.md §2.3).

    One-time weight-prep for the fused multi-projection kernel: the result's
    ``x @ W`` equals the per-tensor products side by side, so a single kernel
    pass serves all N projections. Delegates to the shared format's ``fuse``
    capability — requires identical format, ``(k, q, g)`` and scale dtype
    (true for Q/K/V and gate/up under any per-sublayer-type policy).
    """
    first = qts[0]
    for t in qts[1:]:
        if t.fmt != first.fmt:
            raise ValueError(
                f"cannot fuse: format mismatch {t.fmt!r} vs {first.fmt!r}"
            )
    return first.format().fuse(qts)


def quantize_tensor(
    w: jax.Array,
    q: int,
    g: int,
    iters: int = 10,
    scale_dtype=jnp.bfloat16,
    method: str = "alternating",
    fmt: str = "bcq",
) -> QuantizedTensor:
    """Quantize a dense ``(k, o)`` weight to a :class:`QuantizedTensor`.

    ``fmt`` picks the registered format (``"bcq"`` default). For BCQ,
    ``method`` is ``"alternating"`` (paper's PTQ solver, Xu et al. [20]) or
    ``"greedy"`` (init only; much faster, used for huge layers and tests);
    uniform formats are closed-form and ignore ``method``/``iters``.
    """
    from repro.core.formats import get_format

    return get_format(fmt).quantize(
        w, q=q, g=g, iters=iters, scale_dtype=scale_dtype, method=method
    )
