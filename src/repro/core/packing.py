"""Bit-packing of BCQ binary codes.

The LUT-GEMM kernel consumes binary matrices as packed bytes: each uint8 holds
``μ = 8`` consecutive {-1,+1} codes along the reduction dimension (LSB-first),
so a byte is directly a LUT *key* (paper Table II / §III.B).

Layout: codes ``(q, k, o)`` → packed ``(q, k // 8, o)`` uint8, keeping the
output dimension minor so TPU lanes (128-wide) vectorise over output columns.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

MU = 8  # paper's practical LUT sub-vector length (§III.B: "μ = 8 is used")


def pack_signs(binary: jax.Array) -> jax.Array:
    """Pack {-1,+1} int8 codes ``(..., k, o)`` → uint8 ``(..., k//8, o)``.

    Bit ``j`` of byte ``c`` is 1 iff ``binary[..., 8*c + j, :] == +1`` (LSB-first).
    """
    *lead, k, o = binary.shape
    if k % MU != 0:
        raise ValueError(f"reduction dim {k} must be a multiple of {MU}")
    bits = (binary > 0).astype(jnp.uint8).reshape(*lead, k // MU, MU, o)
    weights = (jnp.uint8(1) << jnp.arange(MU, dtype=jnp.uint8))  # LSB-first
    return jnp.sum(bits * weights[:, None], axis=-2, dtype=jnp.uint8)


def unpack_signs(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_signs`: uint8 ``(..., k//8, o)`` → int8 ``(..., k, o)``."""
    *lead, kc, o = packed.shape
    shifts = jnp.arange(MU, dtype=jnp.uint8)
    bits = (packed[..., :, None, :] >> shifts[:, None]) & jnp.uint8(1)
    signs = (2 * bits.astype(jnp.int8) - 1).reshape(*lead, kc * MU, o)
    return signs


def pack_codes(codes: jax.Array, q: int) -> jax.Array:
    """Pack unsigned ``q``-bit codes ``(..., k, o)`` → uint8 ``(..., q, k//8, o)``.

    Plane ``i`` holds bit ``i`` of every code, packed 8-per-byte along ``k``
    exactly like :func:`pack_signs` (LSB-first) — uniform int-quant codes get
    the same physical layout as BCQ sign planes, so sharding/fusion machinery
    treats both formats identically (``core/formats.py``).
    """
    *lead, k, o = codes.shape
    if k % MU != 0:
        raise ValueError(f"reduction dim {k} must be a multiple of {MU}")
    plane_shift = jnp.arange(q, dtype=jnp.uint8)[:, None, None]
    planes = (codes.astype(jnp.uint8)[..., None, :, :] >> plane_shift) & jnp.uint8(1)
    bits = planes.reshape(*lead, q, k // MU, MU, o)
    weights = (jnp.uint8(1) << jnp.arange(MU, dtype=jnp.uint8))  # LSB-first
    return jnp.sum(bits * weights[:, None], axis=-2, dtype=jnp.uint8)


def unpack_codes(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_codes`: ``(..., q, k//8, o)`` → int32 ``(..., k, o)``.

    Reassembles the magnitude from the ``q`` bit planes (``Σ_i 2^i · bit_i``).
    """
    *lead, q, kc, o = packed.shape
    shifts = jnp.arange(MU, dtype=jnp.uint8)
    bits = (packed[..., :, :, None, :] >> shifts[:, None]) & jnp.uint8(1)
    planes = bits.reshape(*lead, q, kc * MU, o).astype(jnp.int32)
    weights = (1 << jnp.arange(q, dtype=jnp.int32))[:, None, None]
    return jnp.sum(planes * weights, axis=-3)
