"""Core quantization math + the pluggable format registry (DESIGN.md §2.4)."""

from repro.core.bcq import (
    bcq_error,
    compression_ratio,
    dequantize,
    quantize_bcq,
    quantize_bcq_greedy,
)
from repro.core.formats import (
    QuantFormat,
    format_names,
    get_format,
    register_format,
)
from repro.core.packing import pack_codes, pack_signs, unpack_codes, unpack_signs
from repro.core.qtensor import QuantizedTensor, fuse_tensors, quantize_tensor

__all__ = [
    "QuantFormat",
    "QuantizedTensor",
    "bcq_error",
    "compression_ratio",
    "dequantize",
    "format_names",
    "fuse_tensors",
    "get_format",
    "pack_codes",
    "pack_signs",
    "quantize_bcq",
    "quantize_bcq_greedy",
    "quantize_tensor",
    "register_format",
    "unpack_codes",
    "unpack_signs",
]
