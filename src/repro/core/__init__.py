"""Core group-wise binary-coding quantization (BCQ) math."""

from repro.core.bcq import (
    bcq_error,
    compression_ratio,
    dequantize,
    quantize_bcq,
    quantize_bcq_greedy,
)
from repro.core.packing import pack_signs, unpack_signs
from repro.core.qtensor import QuantizedTensor, fuse_tensors, quantize_tensor

__all__ = [
    "QuantizedTensor",
    "bcq_error",
    "compression_ratio",
    "dequantize",
    "fuse_tensors",
    "pack_signs",
    "quantize_bcq",
    "quantize_bcq_greedy",
    "quantize_tensor",
    "unpack_signs",
]
