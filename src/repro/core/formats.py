"""QuantFormat — the pluggable quantization-format protocol + registry.

The kernel/format boundary is a real, stable API seam (FLUTE generalises LUT
kernels over arbitrary codebooks; FineQuant ships group-wise uniform int-q
behind the same serving stack): a *format* owns how a weight is packed, how
its kernels consume the packed form, how it shards under tensor parallelism,
and which capabilities it supports. Everything else in the framework —
layers, the fuser, the autotuner, TP placement, the engines — talks to the
registry through :func:`repro.kernels.ops.qmatmul` and the methods below, and
never branches on a concrete format again (DESIGN.md §2.4).

Registered formats
------------------
``bcq``      group-wise binary-coding quantization (the paper, §III): q sign
             planes + q per-group scale planes. Kernels: ``bcq_mm`` (unpack →
             MXU, TPU-native) and ``lutgemm`` (paper-faithful LUT). Supports
             nested truncation (self-speculative drafts) and fusion.
``uniform``  FineQuant-style group-wise uniform int-q: q magnitude bit planes
             + a (scale, zero) affine pair per group. Kernel: ``uniform_mm``
             (unpack → affine → MXU, one pass). Supports fusion.
``dequant``  the paper's comparison target — identical packing to ``uniform``
             but served through an explicit dequantize-into-HBM-then-GEMM
             pipeline (``dequant_mm``). Exists so the baseline side of
             Table 3 / Fig. 9 is executable code, not just a citation.
``codebook`` FLUTE-style arbitrary codebook: per-(group, column) table of
             ``2^q`` learned scalar centroids (k-means, or the fixed NF4 grid
             via ``method="nf4"``) with ``q`` index bit planes. Kernel:
             ``codebook_mm`` (LUT retrieve from the VMEM-resident table →
             MXU) — the paper's LUT mechanism generalized beyond sign
             patterns.
``ternary``  T-MAC ``tl2``-style {-1, 0, +1}: two packed bit planes (sign +
             mask) and ONE per-group magnitude ``alpha``. Kernel:
             ``ternary_mm``. Ternary is masked BCQ (``t = 0.5·b1 + 0.5·b2``),
             so it supports ``truncate`` — self-speculation gets a nested
             1-plane BCQ draft at sub-1-bit cost.

Shared physical layout (so sharding/fusion/stacking machinery is generic):
``packed (…, P, k//8, o)`` uint8 code planes, ``scales (…, S, k//g, o)`` group
parameters — P, S and the reconstruction rule are the format's business.

Capability matrix
-----------------
============  ========  =========  =====================================
format        truncate  fuse       kernels (autotune impl keys)
============  ========  =========  =====================================
``bcq``       yes       yes        ``bcq_mm``, ``lutgemm``
``uniform``   no        yes        ``uniform_mm``
``dequant``   no        yes        ``dequant_mm`` (materialise + GEMM)
``codebook``  no        yes        ``codebook_mm`` (LUT retrieve + MXU)
``ternary``   yes       yes        ``ternary_mm`` (drafts run ``bcq_mm``)
============  ========  =========  =====================================
"""

from __future__ import annotations

import abc
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import bcq as bcq_lib
from repro.core import packing
from repro.core.qtensor import QuantizedTensor
from repro.kernels import autotune

_SUBLANE = 8
_LANE = 128


# ---------------------------------------------------------------------------
# shared Pallas dispatch plumbing (padding + autotuned blocks)
# ---------------------------------------------------------------------------


def _pad_o(packed, scales, o: int):
    """Pad the output dim to the lane block when no candidate divides it."""
    if any(o % c == 0 for c in autotune._CANDIDATE_O):
        return packed, scales, o
    pad = -o % _LANE
    packed = jnp.pad(packed, ((0, 0), (0, 0), (0, pad)))
    scales = jnp.pad(scales, ((0, 0), (0, 0), (0, pad)))
    return packed, scales, o + pad


def _pallas_matvec(
    xb, qt: QuantizedTensor, kernel_fn, impl: str, interpret: bool
) -> jax.Array:
    """Padded (B, k) @ qt → (B, o_padded) f32 through a format's Pallas kernel.

    Normalises the batch to the sublane width and the output dim to a valid
    lane block, resolves ``(block_k, block_o)`` through the measured autotuner
    (keys carry ``impl``, so per-format winners never collide), and dispatches.
    """
    packed, scales, o = _pad_o(qt.packed, qt.scales, qt.o)
    B = xb.shape[0]
    pad_b = -B % _SUBLANE
    if pad_b:
        xb = jnp.pad(xb, ((0, pad_b), (0, 0)))
    block_k, block_o = autotune.get_blocks(
        B=xb.shape[0], k=qt.k, o=o, q=qt.q, g=qt.g, impl=impl, interpret=interpret
    )
    if not block_k:
        raise ValueError(f"k={qt.k} has no valid Pallas tiling (g={qt.g})")
    if not block_o:
        raise ValueError(f"o={o} has no valid Pallas tiling")
    y = kernel_fn(
        xb,
        packed,
        scales,
        g=qt.g,
        block_k=block_k,
        block_o=block_o,
        interpret=interpret,
    )
    return y[:B]


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


class QuantFormat(abc.ABC):
    """One quantization format: packing, kernels, sharding, capabilities.

    Subclasses set ``name`` (the registry key), ``impls`` (Pallas kernel ids
    in preference order — also the autotune-table ``impl`` key axis) and the
    capability flags, and implement ``quantize``/``dequantize``/``matvec``.
    The base class provides the shared-layout defaults for everything else
    (``matmul``, ``nbytes``, ``fuse``, ``tp_specs``, ``relocalize``).
    """

    name: str
    impls: Tuple[str, ...] = ()
    supports_truncate: bool = False  # nested low-bit views (speculative drafts)
    supports_fuse: bool = True  # output-dim fusion (fused QKV / gate-up)

    # -- pack / unpack -------------------------------------------------------

    @abc.abstractmethod
    def quantize(
        self,
        w: jax.Array,
        *,
        q: int,
        g: int,
        scale_dtype=jnp.bfloat16,
        method: str = "alternating",
        iters: int = 8,
    ) -> QuantizedTensor:
        """Quantize + pack a dense 2-D ``(k, o)`` weight. Must be traceable
        (``quant/quantize.py`` maps it over layer-stacked leaves)."""

    @abc.abstractmethod
    def dequantize(self, qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
        """Reconstruct the dense ``(…, k, o)`` matrix (supports leading
        layer/expert stacking)."""

    # -- kernel entries ------------------------------------------------------

    @abc.abstractmethod
    def matvec(
        self, xb: jax.Array, qt: QuantizedTensor, *, impl: str, interpret: bool
    ) -> jax.Array:
        """Decode entry: ``(B, k) @ qt → (B, o≥) f32`` consuming the packed
        form directly through the named Pallas kernel (``impl ∈ self.impls``;
        output may carry lane padding — callers slice ``[:, :qt.o]``)."""

    def matmul(self, xb: jax.Array, qt: QuantizedTensor, *, dtype) -> jax.Array:
        """Prefill / oracle entry: dequantize into the compute dtype and run
        one dense dot (XLA-fusable; on TPU deployments the Pallas ``matvec``
        replaces this HLO region — paper Fig. 13's stage split)."""
        w = self.dequantize(qt, dtype=dtype)
        return jnp.dot(xb, w, preferred_element_type=jnp.float32)

    def resolve_impl(
        self, impl: str, interpret: Optional[bool]
    ) -> Tuple[str, bool]:
        """``auto`` → this format's preferred kernel on TPU, ``ref`` elsewhere."""
        if impl == "auto":
            on_tpu = jax.default_backend() == "tpu"
            impl = self.impls[0] if (on_tpu and self.impls) else "ref"
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if impl != "ref" and impl not in self.impls:
            raise ValueError(
                f"format {self.name!r} has no kernel impl {impl!r}; "
                f"available: {('ref',) + tuple(self.impls)}"
            )
        return impl, interpret

    # -- accounting ----------------------------------------------------------

    def nbytes(self, qt: QuantizedTensor) -> int:
        """Packed size in bytes (code planes + scales)."""
        return (
            int(qt.packed.size)
            + int(qt.scales.size) * jnp.dtype(qt.scales.dtype).itemsize
        )

    def scales_shape(self, q: int, groups: int, o: int) -> Tuple[int, ...]:
        """Shape of the per-(group, column) parameter planes."""
        raise NotImplementedError

    def struct(
        self, lead: Tuple[int, ...], k: int, o: int, q: int, g: int, scale_dtype
    ) -> QuantizedTensor:
        """ShapeDtypeStruct-leaved container (dry-run lowering of huge models
        without allocating them — ``quant/quantize.py::quantized_structs``)."""
        return QuantizedTensor(
            packed=jax.ShapeDtypeStruct((*lead, q, k // 8, o), jnp.uint8),
            scales=jax.ShapeDtypeStruct(
                (*lead, *self.scales_shape(q, k // g, o)), jnp.dtype(scale_dtype)
            ),
            g=g,
            k=k,
            o=o,
            fmt=self.name,
        )

    # -- capabilities --------------------------------------------------------

    def truncate(self, qt: QuantizedTensor, q_new: int) -> QuantizedTensor:
        """Nested ``q_new``-bit view. Only formats whose planes are successive
        residual refinements (BCQ) can offer this; everything else refuses."""
        raise ValueError(
            f"format {self.name!r} does not support nested truncation "
            "(self-speculative drafts need a residual-nested format like 'bcq')"
        )

    def fuse(self, qts: Sequence[QuantizedTensor]) -> QuantizedTensor:
        """Concatenate N projections along the output dim (shared-layout
        default — valid for every plane-packed format)."""
        if not self.supports_fuse:
            raise ValueError(
                f"format {self.name!r} does not support output-dim fusion"
            )
        first = qts[0]
        for t in qts[1:]:
            if (t.k, t.q, t.g) != (first.k, first.q, first.g):
                raise ValueError(
                    f"cannot fuse: (k, q, g) mismatch {(t.k, t.q, t.g)} vs "
                    f"{(first.k, first.q, first.g)}"
                )
            if t.scales.dtype != first.scales.dtype:
                raise ValueError("cannot fuse: scale dtype mismatch")
            if t.packed.shape[:-1] != first.packed.shape[:-1]:
                raise ValueError("cannot fuse: leading (layer/expert) dims differ")
        return QuantizedTensor(
            packed=jnp.concatenate([t.packed for t in qts], axis=-1),
            scales=jnp.concatenate([t.scales for t in qts], axis=-1),
            g=first.g,
            k=first.k,
            o=sum(t.o for t in qts),
            fmt=first.fmt,
        )

    # -- tensor parallelism --------------------------------------------------

    def tp_specs(self, dense_spec: P, qt: QuantizedTensor, ax) -> QuantizedTensor:
        """PartitionSpec-leaved container matching the dense weight's
        (possibly layer-stacked) spec ``(…lead, k_ax, o_ax)``.

        Shared-layout rule (subsumes the old BCQ-only ``qt_specs_like`` group
        divisibility logic): the packed k-rows (``k/8``) and the scale groups
        (``k/g``) shard along ``k_ax`` only when the mesh axis divides them —
        group scales must travel WITH the k-rows they scale (the paper's
        group-wise-TP argument, §V.C); an axis that doesn't divide is dropped
        (replicated) and it is the *caller's* job to refuse loudly when
        sharding was mandatory (``parallel/tp.py``)."""
        *lead, k_ax, o_ax = tuple(dense_spec)
        kc = qt.packed.shape[-2]
        kg = qt.scales.shape[-2]

        def keep(axis, dim):
            if axis is None:
                return None
            size = ax.size(axis)
            return axis if (size > 0 and dim % size == 0) else None

        return QuantizedTensor(
            packed=P(*lead, None, keep(k_ax, kc), o_ax),
            scales=P(*lead, None, keep(k_ax, kg), o_ax),
            g=qt.g,
            k=qt.k,
            o=qt.o,
            fmt=qt.fmt,
        )

    def relocalize(self, qt: QuantizedTensor) -> QuantizedTensor:
        """Fix static ``(k, o)`` to per-device shard shapes (shard_map hands
        the body local planes but the statics still say the global shape)."""
        return QuantizedTensor(
            packed=qt.packed,
            scales=qt.scales,
            g=qt.g,
            k=qt.packed.shape[-2] * 8,
            o=qt.packed.shape[-1],
            fmt=qt.fmt,
        )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, QuantFormat] = {}


def register_format(fmt: QuantFormat) -> QuantFormat:
    """Register a format instance under ``fmt.name`` (last write wins)."""
    _REGISTRY[fmt.name] = fmt
    return fmt


def get_format(name: str) -> QuantFormat:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown quantization format {name!r}; registered formats: "
            f"{sorted(_REGISTRY)}"
        ) from None


def format_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# bcq — the paper's format (group-wise binary-coding quantization)
# ---------------------------------------------------------------------------


class BCQFormat(QuantFormat):
    name = "bcq"
    impls = ("bcq_mm", "lutgemm")
    supports_truncate = True

    def quantize(
        self, w, *, q, g, scale_dtype=jnp.bfloat16, method="alternating", iters=8
    ) -> QuantizedTensor:
        k, o = w.shape
        if method == "alternating":
            scales, binary = bcq_lib.quantize_bcq(w, q=q, g=g, iters=iters)
        elif method == "greedy":
            scales, binary = bcq_lib.quantize_bcq_greedy(w, q=q, g=g)
        else:
            raise ValueError(f"unknown method {method!r}")
        return QuantizedTensor(
            packed=packing.pack_signs(binary),
            scales=scales.astype(scale_dtype),
            g=g,
            k=k,
            o=o,
            fmt=self.name,
        )

    def dequantize(self, qt, dtype=jnp.float32):
        signs = packing.unpack_signs(qt.packed)  # (…, q, k, o) int8
        w = bcq_lib.dequantize(qt.scales.astype(jnp.float32), signs, qt.g)
        return w.astype(dtype)

    def matvec(self, xb, qt, *, impl, interpret):
        from repro.kernels.bcq_mm import bcq_mm
        from repro.kernels.lutgemm import lutgemm

        fn = {"bcq_mm": bcq_mm, "lutgemm": lutgemm}[impl]
        return _pallas_matvec(xb, qt, fn, impl, interpret)

    def scales_shape(self, q, groups, o):
        return (q, groups, o)

    def truncate(self, qt, q_new):
        """The nested ``q_new``-bit approximation: the greedy solver builds
        plane ``i`` as a refinement of the residual left by planes ``< i``
        (paper §III.A), so ``packed[:q_new], scales[:q_new]`` is bit-identical
        to what the solver would emit at ``q=q_new``. The slice is a view at
        trace time; ``g, k, o`` and leading stacking are preserved."""
        if not 1 <= q_new <= qt.q:
            raise ValueError(f"cannot truncate q={qt.q} tensor to q'={q_new}")
        if q_new == qt.q:
            return qt
        return QuantizedTensor(
            packed=qt.packed[..., :q_new, :, :],
            scales=qt.scales[..., :q_new, :, :],
            g=qt.g,
            k=qt.k,
            o=qt.o,
            fmt=qt.fmt,
        )


# ---------------------------------------------------------------------------
# uniform — FineQuant-style group-wise uniform int quantization
# ---------------------------------------------------------------------------


class UniformFormat(QuantFormat):
    name = "uniform"
    impls = ("uniform_mm",)

    def quantize(
        self, w, *, q, g, scale_dtype=jnp.bfloat16, method="alternating", iters=8
    ) -> QuantizedTensor:
        """Closed-form per-group affine: ``code = round((w - min) / s)`` with
        ``s = (max - min) / (2^q - 1)`` — ``method``/``iters`` are ignored
        (kept in the signature so policies drive every format uniformly)."""
        del method, iters
        k, o = w.shape
        bcq_lib._check_args(k, q, g)
        grouped = w.astype(jnp.float32).reshape(k // g, g, o)
        wmin = grouped.min(axis=1)  # (G, o)
        wmax = grouped.max(axis=1)
        scale = jnp.maximum((wmax - wmin) / (2**q - 1), 1e-8)
        codes = jnp.clip(
            jnp.round((grouped - wmin[:, None, :]) / scale[:, None, :]),
            0,
            2**q - 1,
        )
        packed = packing.pack_codes(codes.reshape(k, o).astype(jnp.uint8), q)
        scales = jnp.stack([scale, wmin]).astype(scale_dtype)  # (2, G, o)
        return QuantizedTensor(
            packed=packed, scales=scales, g=g, k=k, o=o, fmt=self.name
        )

    def dequantize(self, qt, dtype=jnp.float32):
        codes = packing.unpack_codes(qt.packed).astype(jnp.float32)  # (…, k, o)
        s = qt.scales[..., 0, :, :].astype(jnp.float32)  # (…, G, o)
        z = qt.scales[..., 1, :, :].astype(jnp.float32)
        *lead, k, o = codes.shape
        grouped = codes.reshape(*lead, k // qt.g, qt.g, o)
        w = grouped * s[..., :, None, :] + z[..., :, None, :]
        return w.reshape(*lead, k, o).astype(dtype)

    def matvec(self, xb, qt, *, impl, interpret):
        from repro.kernels.uniform_mm import uniform_mm

        return _pallas_matvec(xb, qt, uniform_mm, impl, interpret)

    def scales_shape(self, q, groups, o):
        return (2, groups, o)


# ---------------------------------------------------------------------------
# dequant — the paper's baseline: same packing, dequantize-then-GEMM pipeline
# ---------------------------------------------------------------------------


class DequantFormat(UniformFormat):
    """Identical representation to ``uniform`` (so any latency difference is
    *pipeline*, not packing), served the slow way round: materialise the dense
    weight to HBM, then run a stock GEMM — the OPTQ/nuQmm recipe the paper
    benchmarks against (Table 3 / Fig. 9)."""

    name = "dequant"
    impls = ("dequant_mm",)

    def matvec(self, xb, qt, *, impl, interpret):
        from repro.kernels.dequant_mm import dequant_mm

        return _pallas_matvec(xb, qt, dequant_mm, impl, interpret)


# ---------------------------------------------------------------------------
# codebook — FLUTE-style arbitrary-codebook (learned centroids or NF4 grid)
# ---------------------------------------------------------------------------

# The QLoRA NF4 grid: 16 quantiles of N(0, 1) normalised to [-1, 1]; a weight
# group is coded as ``absmax · level`` — the fixed-codebook special case.
_NF4_LEVELS = (
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0,
)


def _kmeans_centroids(grouped: jax.Array, q: int, iters: int) -> jax.Array:
    """Per-(group, column) 1-D Lloyd k-means: ``(G, g, o)`` → ``(G, 2^q, o)``.

    Quantile init (centroid ``i`` at the ``(i+0.5)/2^q`` percentile of the
    group) then ``iters`` assign/update rounds; an empty cluster keeps its old
    centroid. Fully traceable — ``quant/quantize.py`` maps this over
    layer-stacked leaves under ``jax.lax.map``.
    """
    n = 1 << q
    probs = (jnp.arange(n, dtype=jnp.float32) + 0.5) / n
    cent = jnp.moveaxis(jnp.quantile(grouped, probs, axis=1), 0, 1)  # (G, n, o)

    def step(cent, _):
        d = jnp.abs(grouped[:, :, None, :] - cent[:, None, :, :])  # (G, g, n, o)
        onehot = jax.nn.one_hot(jnp.argmin(d, axis=2), n, axis=2)  # (G, g, n, o)
        counts = onehot.sum(axis=1)  # (G, n, o)
        sums = (grouped[:, :, None, :] * onehot).sum(axis=1)
        cent = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), cent)
        return cent, None

    cent, _ = jax.lax.scan(step, cent, None, length=max(int(iters), 1))
    return cent


class CodebookFormat(QuantFormat):
    """Arbitrary scalar codebook per (group, column): ``2^q`` centroids in the
    scales planes, ``q`` index bit planes in the packed planes. The kernel
    retrieves centroids from the VMEM-resident table — the paper's LUT
    mechanism generalized beyond sign patterns (FLUTE)."""

    name = "codebook"
    impls = ("codebook_mm",)

    def quantize(
        self, w, *, q, g, scale_dtype=jnp.bfloat16, method="alternating", iters=8
    ) -> QuantizedTensor:
        """``method``: any of the shared solver names (``alternating`` /
        ``greedy`` / ``kmeans``) runs per-group Lloyd k-means — policies drive
        every format with one vocabulary; ``nf4`` selects the fixed QLoRA grid
        scaled by the group absmax (requires ``q == 4``)."""
        k, o = w.shape
        bcq_lib._check_args(k, q, g)
        grouped = w.astype(jnp.float32).reshape(k // g, g, o)
        if method == "nf4":
            if q != 4:
                raise ValueError(
                    f"method 'nf4' is a fixed 16-entry codebook; needs q=4, got q={q}"
                )
            levels = jnp.asarray(_NF4_LEVELS, jnp.float32)
            absmax = jnp.max(jnp.abs(grouped), axis=1)  # (G, o)
            cent = levels[None, :, None] * absmax[:, None, :]  # (G, 16, o)
        elif method in ("alternating", "greedy", "kmeans"):
            cent = _kmeans_centroids(grouped, q, iters)
        else:
            raise ValueError(f"unknown method {method!r}")
        codes = jnp.argmin(
            jnp.abs(grouped[:, :, None, :] - cent[:, None, :, :]), axis=2
        )  # (G, g, o)
        packed = packing.pack_codes(codes.reshape(k, o).astype(jnp.uint8), q)
        scales = jnp.swapaxes(cent, 0, 1).astype(scale_dtype)  # (2^q, G, o)
        return QuantizedTensor(
            packed=packed, scales=scales, g=g, k=k, o=o, fmt=self.name
        )

    def dequantize(self, qt, dtype=jnp.float32):
        codes = packing.unpack_codes(qt.packed)  # (…, k, o) int32
        *lead, k, o = codes.shape
        cent = jnp.swapaxes(qt.scales.astype(jnp.float32), -3, -2)  # (…, G, 2^q, o)
        idx = codes.reshape(*lead, k // qt.g, qt.g, o)
        w = jnp.take_along_axis(cent, idx, axis=-2)  # (…, G, g, o)
        return w.reshape(*lead, k, o).astype(dtype)

    def matvec(self, xb, qt, *, impl, interpret):
        from repro.kernels.codebook_mm import codebook_mm

        return _pallas_matvec(xb, qt, codebook_mm, impl, interpret)

    def scales_shape(self, q, groups, o):
        return (1 << q, groups, o)


# ---------------------------------------------------------------------------
# ternary — T-MAC tl2-style {-1, 0, +1} (masked BCQ; supports truncation)
# ---------------------------------------------------------------------------


class TernaryFormat(QuantFormat):
    """{-1, 0, +1} codes as two packed bit planes (sign + nonzero mask) and one
    per-group magnitude ``alpha`` — 2 bits + one scale per group, less storage
    than 2-bit BCQ (which carries two scale planes).

    Ternary IS masked BCQ: ``t = 0.5·b1 + 0.5·b2`` with ``b1 = sign | ~mask``
    and ``b2 = sign & mask`` (bit-wise on the packed bytes) — exact in float
    (``0.5·alpha`` and the ±0.5 sums are exact), which is what makes
    ``truncate`` available: the 1-plane slice is a genuine nested BCQ draft at
    0.5 bits of extra storage over nothing (self-speculation, DESIGN.md §4).
    """

    name = "ternary"
    impls = ("ternary_mm",)
    supports_truncate = True

    PLANES = 2  # sign + mask, fixed — the policy's q does not change storage

    def quantize(
        self, w, *, q, g, scale_dtype=jnp.bfloat16, method="alternating", iters=8
    ) -> QuantizedTensor:
        """TWN-style ternarisation per (group, column): threshold init
        ``Δ = 0.75·mean|w|``, then ``iters`` alternating refinements of
        ``alpha = mean(|w| over mask)`` and ``Δ = alpha/2`` (the 1-D Lloyd
        condition for {-α, 0, +α}). ``q``/``method`` are accepted but do not
        change the stored planes — ternary is fixed at 2."""
        del q, method
        k, o = w.shape
        bcq_lib._check_args(k, self.PLANES, g)
        grouped = w.astype(jnp.float32).reshape(k // g, g, o)
        absg = jnp.abs(grouped)
        delta = 0.75 * absg.mean(axis=1)  # (G, o) — the TWN threshold

        def refine(delta, _):
            mask = absg > delta[:, None, :]
            cnt = jnp.maximum(mask.sum(axis=1), 1)
            alpha = (absg * mask).sum(axis=1) / cnt
            return 0.5 * alpha, alpha

        delta, alphas = jax.lax.scan(refine, delta, None, length=max(int(iters), 1))
        alpha = alphas[-1]
        mask = absg > delta[:, None, :]

        sign_pm = jnp.where(grouped >= 0, 1, -1).astype(jnp.int8)
        mask_pm = jnp.where(mask, 1, -1).astype(jnp.int8)
        planes = jnp.stack([sign_pm, mask_pm]).reshape(self.PLANES, k, o)
        return QuantizedTensor(
            packed=packing.pack_signs(planes),
            scales=alpha[None].astype(scale_dtype),  # (1, G, o)
            g=g,
            k=k,
            o=o,
            fmt=self.name,
        )

    def dequantize(self, qt, dtype=jnp.float32):
        planes = packing.unpack_signs(qt.packed).astype(jnp.float32)  # (…, 2, k, o)
        sign = planes[..., 0, :, :]
        nonzero = (planes[..., 1, :, :] + 1.0) * 0.5  # {-1,+1} → {0,1}
        t = sign * nonzero
        *lead, k, o = t.shape
        alpha = qt.scales.astype(jnp.float32)[..., 0, :, :]  # (…, G, o)
        grouped = t.reshape(*lead, k // qt.g, qt.g, o) * alpha[..., :, None, :]
        return grouped.reshape(*lead, k, o).astype(dtype)

    def matvec(self, xb, qt, *, impl, interpret):
        from repro.kernels.ternary_mm import ternary_mm

        return _pallas_matvec(xb, qt, ternary_mm, impl, interpret)

    def scales_shape(self, q, groups, o):
        return (1, groups, o)

    def struct(self, lead, k, o, q, g, scale_dtype):
        """Ternary stores exactly 2 planes whatever the policy's ``q`` says —
        the dry-run struct must agree with ``quantize`` (staticcheck traces
        through these shapes)."""
        del q
        return QuantizedTensor(
            packed=jax.ShapeDtypeStruct((*lead, self.PLANES, k // 8, o), jnp.uint8),
            scales=jax.ShapeDtypeStruct(
                (*lead, 1, k // g, o), jnp.dtype(scale_dtype)
            ),
            g=g,
            k=k,
            o=o,
            fmt=self.name,
        )

    def as_bcq(self, qt: QuantizedTensor) -> QuantizedTensor:
        """The exact 2-plane BCQ view: ``b1 = sign | ~mask``, ``b2 = sign &
        mask`` on the packed bytes, each plane scaled ``alpha/2``. Float-exact
        (``0.5·alpha`` is an exponent decrement; ``±0.5 ± 0.5 ∈ {-1, 0, 1}``
        is exact), so dequantize(as_bcq(qt)) == dequantize(qt) bit-for-bit."""
        sign = qt.packed[..., 0, :, :]
        mask = qt.packed[..., 1, :, :]
        b1 = sign | ~mask
        b2 = sign & mask
        half = (0.5 * qt.scales.astype(jnp.float32)).astype(qt.scales.dtype)
        return QuantizedTensor(
            packed=jnp.stack([b1, b2], axis=-3),
            scales=jnp.concatenate([half, half], axis=-3),  # (…, 2, G, o)
            g=qt.g,
            k=qt.k,
            o=qt.o,
            fmt="bcq",
        )

    def truncate(self, qt, q_new):
        """Nested draft views via the masked-BCQ identity: ``q_new == 2`` is
        the full-precision self (served by ``ternary_mm``); ``q_new == 1``
        re-tags the ``b1 = sign | ~mask`` plane as a 1-plane BCQ tensor
        (drafts then dispatch through ``bcq_mm`` — ``ops.qmatmul`` routes per
        leaf ``fmt``)."""
        if not 1 <= q_new <= self.PLANES:
            raise ValueError(
                f"cannot truncate ternary tensor to q'={q_new} "
                f"(valid: 1..{self.PLANES})"
            )
        if q_new == self.PLANES:
            return qt
        return get_format("bcq").truncate(self.as_bcq(qt), q_new)


# ---------------------------------------------------------------------------
# registration (formats + their kernels' autotune measurement entries)
# ---------------------------------------------------------------------------

register_format(BCQFormat())
register_format(UniformFormat())
register_format(DequantFormat())
register_format(CodebookFormat())
register_format(TernaryFormat())


def _load_uniform_mm():
    from repro.kernels.uniform_mm import uniform_mm

    return uniform_mm


def _load_dequant_mm():
    from repro.kernels.dequant_mm import dequant_mm

    return dequant_mm


def _load_codebook_mm():
    from repro.kernels.codebook_mm import codebook_mm

    return codebook_mm


def _load_ternary_mm():
    from repro.kernels.ternary_mm import ternary_mm

    return ternary_mm


def _affine_meas_scales(rng, q, k, o, g):
    return rng.standard_normal((2, k // g, o))


def _codebook_meas_scales(rng, q, k, o, g):
    return rng.standard_normal((1 << q, k // g, o))


def _ternary_meas_scales(rng, q, k, o, g):
    del q
    return rng.standard_normal((1, k // g, o))


autotune.register_measure_kernel("uniform_mm", _load_uniform_mm, _affine_meas_scales)
autotune.register_measure_kernel("dequant_mm", _load_dequant_mm, _affine_meas_scales)
autotune.register_measure_kernel("codebook_mm", _load_codebook_mm, _codebook_meas_scales)
autotune.register_measure_kernel("ternary_mm", _load_ternary_mm, _ternary_meas_scales)
