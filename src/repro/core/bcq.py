"""Group-wise binary-coding quantization (BCQ) — paper §III.A.

A weight matrix ``W`` of shape ``(k, o)`` (used as ``y = x @ W``) is approximated

    W[k, o]  ≈  Σ_{i=1..q}  alpha_i[k // g, o] · b_i[k, o]

with ``b_i ∈ {-1, +1}`` and a scaling factor ``alpha`` shared by ``g`` consecutive
weights along the **reduction** dimension ``k`` (the paper's row dimension — its
``B`` is ``(m × n)`` acting on ``x ∈ R^n``; we store the transpose so that
activations contract on the leading weight axis, the JAX convention).

Solvers
-------
``quantize_bcq_greedy``   residual greedy (Guo et al., "network sketching"): exact
                          for q=1, good init otherwise.
``quantize_bcq``          greedy init + the alternating iterative solver the paper
                          uses (Xu et al. [20]): alternate a per-group least-squares
                          refit of ``alpha`` with an exhaustive 2^q re-selection of
                          the binary codes. Monotone non-increasing error.

Shapes
------
binary  : int8  ``(q, k, o)`` in {-1, +1}
scales  : f32   ``(q, G, o)`` with ``G = k // g``

Eq. (3) of the paper gives the space complexity these produce:
``S = O(m·n·q·(1 + 32/g))`` — see :func:`compression_ratio`.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _check_args(k: int, q: int, g: int) -> None:
    if q < 1 or q > 8:
        raise ValueError(f"q must be in [1, 8], got {q}")
    if g < 8:
        raise ValueError(f"group size g must be >= 8 (paper §III.A), got {g}")
    if k % g != 0:
        raise ValueError(f"group size g={g} must divide the reduction dim k={k}")


def _sign(x: Array) -> Array:
    """sign with sign(0) := +1 so codes are always in {-1,+1}."""
    return jnp.where(x >= 0, 1.0, -1.0)


# ---------------------------------------------------------------------------
# Greedy solver (init)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("q", "g"))
def quantize_bcq_greedy(w: Array, q: int, g: int) -> Tuple[Array, Array]:
    """Residual-greedy BCQ. Returns ``(scales (q,G,o) f32, binary (q,k,o) int8)``.

    Per group, iteratively: ``b_i = sign(r)``, ``alpha_i = mean(|r|)`` (the optimal
    scale for that code), ``r -= alpha_i * b_i``.
    """
    k, o = w.shape
    _check_args(k, q, g)
    grouped = w.astype(jnp.float32).reshape(k // g, g, o)

    def step(r, _):
        b = _sign(r)
        alpha = jnp.mean(jnp.abs(r), axis=1)  # (G, o); == <b,r>/g for b=sign(r)
        r = r - alpha[:, None, :] * b
        return r, (alpha, b)

    _, (scales, binary) = jax.lax.scan(step, grouped, None, length=q)
    binary = binary.reshape(q, k, o).astype(jnp.int8)
    return scales, binary


# ---------------------------------------------------------------------------
# Alternating solver (paper's PTQ method, Xu et al. [20])
# ---------------------------------------------------------------------------


def _alpha_lstsq(w_g: Array, b_g: Array, ridge: float) -> Array:
    """Least-squares refit of scales given codes.

    w_g: (G, g, o) grouped weights; b_g: (q, G, g, o) codes.
    Solves per (G, o): min_alpha || w - B alpha ||^2 with B = codes as (g, q),
    via the ridge-regularised normal equations (codes can be collinear when the
    residual hits zero). Returns (q, G, o).
    """
    q = b_g.shape[0]
    btb = jnp.einsum("iago,jago->aoij", b_g, b_g)  # (G, o, q, q)
    btw = jnp.einsum("iago,ago->aoi", b_g, w_g)  # (G, o, q)
    eye = jnp.eye(q, dtype=btb.dtype)
    sol = jnp.linalg.solve(btb + ridge * eye, btw[..., None])[..., 0]  # (G, o, q)
    return jnp.moveaxis(sol, -1, 0)  # (q, G, o)


def _bits_step(w_g: Array, scales: Array) -> Array:
    """Exhaustive re-selection of codes given scales.

    Every weight independently picks the pattern c in {-1,+1}^q minimising
    (w - c·alpha)^2. 2^q candidates (q <= 8 → <= 256).

    w_g: (G, g, o); scales: (q, G, o). Returns codes (q, G, g, o).
    """
    q = scales.shape[0]
    n_pat = 1 << q
    idx = np.arange(n_pat)
    # patterns[p, i] in {-1,+1}; bit i of p (LSB-first)
    patterns = jnp.asarray(
        2.0 * ((idx[:, None] >> np.arange(q)[None, :]) & 1) - 1.0, dtype=w_g.dtype
    )  # (2^q, q)
    cand = jnp.einsum("pi,iao->pao", patterns, scales)  # (2^q, G, o)
    # distance of each weight to each candidate value: (G, g, o, 2^q)
    dist = jnp.abs(w_g[..., None] - jnp.moveaxis(cand, 0, -1)[:, None, :, :])
    best = jnp.argmin(dist, axis=-1)  # (G, g, o) int
    codes = jnp.moveaxis(patterns[best], -1, 0)  # (q, G, g, o)
    return codes


@functools.partial(jax.jit, static_argnames=("q", "g", "iters", "col_chunk"))
def quantize_bcq(
    w: Array, q: int, g: int, iters: int = 10, col_chunk: int = 512
) -> Tuple[Array, Array]:
    """Greedy init + ``iters`` rounds of alternating optimisation.

    ``col_chunk`` bounds peak memory of the exhaustive bits-step
    (O(k · col_chunk · 2^q) floats) by scanning over output-column chunks.

    Returns ``(scales (q,G,o) f32, binary (q,k,o) int8)``.
    """
    k, o = w.shape
    _check_args(k, q, g)
    wf = w.astype(jnp.float32)

    col_chunk = min(col_chunk, o)
    if o % col_chunk != 0:
        # fall back to a divisor of o
        col_chunk = int(np.gcd(o, col_chunk)) or o

    def solve_chunk(w_chunk: Array) -> Tuple[Array, Array]:
        kk, oo = w_chunk.shape
        scales0, binary0 = quantize_bcq_greedy(w_chunk, q, g)
        w_g = w_chunk.reshape(kk // g, g, oo)

        def body(carry, _):
            scales, codes = carry
            scales = _alpha_lstsq(w_g, codes, ridge=1e-8)
            codes = _bits_step(w_g, scales)
            return (scales, codes), None

        codes0 = binary0.astype(jnp.float32).reshape(q, kk // g, g, oo)
        (scales, codes), _ = jax.lax.scan(body, (scales0, codes0), None, length=iters)
        binary = codes.reshape(q, kk, oo).astype(jnp.int8)
        return scales, binary

    chunks = jnp.moveaxis(wf.reshape(k, o // col_chunk, col_chunk), 1, 0)
    scales_c, binary_c = jax.lax.map(solve_chunk, chunks)
    scales = jnp.moveaxis(scales_c, 0, 2).reshape(q, k // g, o)
    binary = jnp.moveaxis(binary_c, 0, 2).reshape(q, k, o)
    return scales, binary


# ---------------------------------------------------------------------------
# Reconstruction / metrics
# ---------------------------------------------------------------------------


def dequantize(scales: Array, binary: Array, g: int) -> Array:
    """Reconstruct ``W ≈ Σ_i alpha_i ∘ b_i`` → (..., k, o) f32.

    Supports leading batch dims (stacked layers / experts): binary
    ``(..., q, k, o)``, scales ``(..., q, k//g, o)``.
    """
    *lead, q, k, o = binary.shape
    b = binary.astype(jnp.float32).reshape(*lead, q, k // g, g, o)
    w = jnp.einsum("...iago,...iao->...ago", b, scales.astype(jnp.float32))
    return w.reshape(*lead, k, o)


def bcq_error(w: Array, scales: Array, binary: Array, g: int) -> Array:
    """Relative Frobenius reconstruction error ||W - Ŵ|| / ||W||."""
    w_hat = dequantize(scales, binary, g)
    return jnp.linalg.norm(w.astype(jnp.float32) - w_hat) / (
        jnp.linalg.norm(w.astype(jnp.float32)) + 1e-12
    )


def compression_ratio(q: int, g: int, base_bits: int = 16, scale_bits: int = 16) -> float:
    """Paper Eq. (3): bits-per-weight of BCQ vs a ``base_bits`` dense format.

    BCQ stores q binary bits + (scale_bits / g) amortised scale bits per weight.
    The paper uses FP32 for both (base 32, scales 32); our TPU framework defaults
    to bf16 baselines and bf16 scales (their §VI halving note).
    """
    bcq_bits = q * (1.0 + scale_bits / g)
    return base_bits / bcq_bits
