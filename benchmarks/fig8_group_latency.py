"""Paper Fig. 8: matmul latency vs group size g (q=4), normalised to row-wise.

The paper's observation — g ≥ 64 is as fast as row-wise because scale bytes
amortise (Eq. 3: S ∝ 1 + 32/g) — falls straight out of the memory-bound
roofline; we reproduce the curve and quantify when group-wise starts to cost.
"""

from __future__ import annotations

from benchmarks.common import bcq_bytes, csv_row, matvec_latency_s


def run() -> list:
    rows = []
    q = 4
    for m in (4096, 8192, 12288):
        base = matvec_latency_s(bcq_bytes(m, m, q, g=m))  # row-wise
        for g in (32, 64, 128, 256, 512, 2048, m):
            t = matvec_latency_s(bcq_bytes(m, m, q, g=g))
            rows.append(
                csv_row(
                    f"fig8/m{m}/g{g if g != m else 'rowwise'}",
                    t * 1e6,
                    f"norm_latency={t/base:.3f}",
                )
            )
    return rows
