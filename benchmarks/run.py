"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. TPU numbers come from the v5e
roofline model (this container is CPU-only); CPU wall-times are functional
sanity checks only. Run: ``PYTHONPATH=src python -m benchmarks.run [--fast]``.

``--json PATH`` additionally records the rows as a JSON list of
``{name, us_per_call, derived}`` objects — used to check in decode-path
baselines (``BENCH_decode.json``) that later PRs can diff against.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip training-based figs")
    ap.add_argument("--only", default=None, help="comma-list of module tags")
    ap.add_argument("--json", default=None, help="also write rows to this JSON file")
    args = ap.parse_args()

    from benchmarks import (
        fig8_group_latency,
        fig9_latency_compression,
        kernel_bench,
        table3_memory_latency,
        table4_tp_vs_quant,
        table5_gpt3,
    )

    modules = [
        ("table3", table3_memory_latency),
        ("fig8", fig8_group_latency),
        ("fig9", fig9_latency_compression),
        ("table4", table4_tp_vs_quant),
        ("table5", table5_gpt3),
        ("kernel", kernel_bench),
    ]
    if not args.fast:
        from benchmarks import fig5_ppl_tradeoff, fig12_mixed_precision

        modules += [("fig5", fig5_ppl_tradeoff), ("fig12", fig12_mixed_precision)]
    if args.only:
        keep = set(args.only.split(","))
        modules = [(t, m) for t, m in modules if t in keep]

    collected = []
    print("name,us_per_call,derived")
    for tag, mod in modules:
        t0 = time.time()
        try:
            for row in mod.run():
                print(row)
                collected.append(row)
        except Exception as e:  # keep the harness going; report the failure
            err = f"{tag}/ERROR,0,{type(e).__name__}:{e}"
            print(err, file=sys.stdout)
            collected.append(err)  # JSON baselines must record the failure too
        print(f"# {tag} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if args.json:
        records = []
        for row in collected:
            name, us, derived = row.split(",", 2)
            records.append(
                {"name": name, "us_per_call": float(us), "derived": derived}
            )
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
