"""Paper Table IV / Fig. 10: tensor parallelism (dense) vs 1-chip quantized.

The paper's core systems claim: TP speedup is sub-linear (collectives +
tall-skinny matmuls) while quantization shrinks the model onto fewer chips at
full efficiency. Reproduced with the v5e latency model: dense bf16 (m×m)·(m×1)
on 1..8 chips vs BCQ q∈{2,4} on one chip, with the 200 W/chip energy model.
"""

from __future__ import annotations

from benchmarks.common import (
    BF16,
    bcq_bytes,
    csv_row,
    energy_j,
    matvec_latency_s,
    tp_matvec_latency_s,
)


def run() -> list:
    rows = []
    for m in (8192, 12288, 16384):
        t1 = tp_matvec_latency_s(m, m, 1)
        e1 = energy_j(t1, 1)
        for chips in (1, 2, 4, 8):
            t = tp_matvec_latency_s(m, m, chips)
            e = energy_j(t, chips)
            comm_frac = 1 - (m * m * BF16 / chips / 819e9) / t
            rows.append(
                csv_row(
                    f"table4/dense_tp{chips}/m{m}",
                    t * 1e6,
                    f"speedup={t1/t:.2f}x;comm_frac={comm_frac:.2%};"
                    f"norm_energy={e/e1:.2f}",
                )
            )
        for q in (2, 4):
            tq = matvec_latency_s(bcq_bytes(m, m, q, g=m))
            eq_ = energy_j(tq, 1)
            rows.append(
                csv_row(
                    f"table4/bcq_q{q}_1chip/m{m}",
                    tq * 1e6,
                    f"speedup={t1/tq:.2f}x;comm_frac=0%;norm_energy={eq_/e1:.2f}",
                )
            )
    return rows
