"""Tensor-parallel serving benchmark: tok/s across tp ∈ {1, 2, 4}.

The ISSUE 4 measurement: the llama3.2-3b-shaped reduced config served through
the shard_map TP engine (DESIGN.md §7) on a forced 4-device host mesh —
decode rows (one-shot scanned `generate`) and serve rows (continuous batching
via the scheduler). Every tp>1 cell is asserted token-identical to tp=1
before it is timed, so the numbers always describe correct configurations.

Host CPU numbers are FUNCTIONAL floors, not TPU claims (benchmarks/common.py):
on one CPU the 4 placeholder devices share the same memory bus, so tp>1 pays
collective overhead with no bandwidth to win. The TPU-side gain lives in the
roofline model — `common.tp_matvec_latency_s` (per-chip weight read + ICI
all-reduce) shrinks the dominant decode term by ~1/tp; see
`benchmarks/table4_tp_vs_quant.py` for that modeled TP-vs-quantization sweep.

XLA_FLAGS is set before the jax import (device count is fixed at backend
init), same constraint as launch/dryrun.py.

PYTHONPATH=src python benchmarks/tp_bench.py [--out BENCH_tp.json]
"""

from __future__ import annotations

import os

from repro.launch._hostdev import force_host_devices

force_host_devices(4)  # before the jax import; preserves unrelated XLA_FLAGS
os.environ.setdefault("REPRO_AUTOTUNE", "0")  # deterministic kernel blocks

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.infer import Engine, Request, Scheduler
from repro.launch.serve import build_requests
from repro.models import init_params, reduced
from repro.parallel.tp import make_tp_mesh
from repro.quant import QuantPolicy, quantize_params

N_REQUESTS = 8
PROMPT_LEN = 16
GEN = 24
SLOTS = 4
CHUNK = 8
Q, G = 4, 64  # g=64 keeps (k/g) % 4 == 0 for the row-parallel wo (k=256)
TPS = (1, 2, 4)


def _build():
    cfg = reduced(get_config("llama3.2-3b"), d_model=256, n_kv_heads=4, d_ff=512)
    params = quantize_params(
        init_params(jax.random.PRNGKey(0), cfg), QuantPolicy(q=Q, g=G, iters=4)
    )
    return cfg, params


def _decode_run(engine, prompts):
    return engine.generate(prompts, GEN)


def _serve_run(engine, reqs):
    sched = Scheduler(engine, n_slots=SLOTS, chunk=CHUNK)
    for r in reqs:
        sched.submit(
            Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens)
        )  # fresh rids per run
    done = sched.run()
    return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(__file__), "..", "BENCH_tp.json"),
    )
    args = ap.parse_args()

    cfg, params = _build()
    reqs = build_requests(cfg, N_REQUESTS, PROMPT_LEN, GEN, mixed_temperature=False)
    prompts = np.stack([r.prompt for r in reqs[:SLOTS]])
    decode_tokens = SLOTS * GEN
    serve_tokens = sum(r.max_new_tokens for r in reqs)
    rows = []
    ref_decode = ref_serve = None

    for tp in TPS:
        mesh = make_tp_mesh(tp) if tp > 1 else None
        engine = Engine(cfg, params, max_seq=PROMPT_LEN + GEN + 8, mesh=mesh)

        # warm + differential check: tp>1 must reproduce tp=1 exactly (greedy)
        out = _decode_run(engine, prompts)
        if ref_decode is None:
            ref_decode = out.tokens
        elif not np.array_equal(out.tokens, ref_decode):
            raise AssertionError(f"tp={tp} decode diverged from tp=1")
        t0 = time.perf_counter()
        _decode_run(engine, prompts)
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"tp/decode_batch{SLOTS}/tp{tp}",
            "tokens_per_s": round(decode_tokens / dt, 2),
            "makespan_s": round(dt, 3),
            "derived": f"prompt={PROMPT_LEN};gen={GEN};q={Q};g={G};"
                       f"host-mesh functional floor, not a TPU claim",
        })
        print(f"decode tp={tp}: {decode_tokens/dt:.1f} tok/s")

        done = _serve_run(engine, reqs)  # warm scheduler path
        # rids restart at 0 per fresh scheduler and follow submission order,
        # so they key the differential exactly (prompts may repeat)
        assert len(done) == N_REQUESTS, f"tp={tp}: {len(done)} completions"
        toks = {c.rid: c.new_tokens for c in done}
        if ref_serve is None:
            ref_serve = toks
        else:
            for rid, v in toks.items():
                if not np.array_equal(v, ref_serve[rid]):
                    raise AssertionError(f"tp={tp} serve diverged from tp=1")
        t0 = time.perf_counter()
        _serve_run(engine, reqs)
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"tp/serve_slots{SLOTS}/tp{tp}",
            "tokens_per_s": round(serve_tokens / dt, 2),
            "makespan_s": round(dt, 3),
            "derived": f"requests={N_REQUESTS};prompt={PROMPT_LEN};gen={GEN};"
                       f"q={Q};g={G};chunk={CHUNK};"
                       f"host-mesh functional floor, not a TPU claim",
        })
        print(f"serve  tp={tp}: {serve_tokens/dt:.1f} tok/s")

    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
