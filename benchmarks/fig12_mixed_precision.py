"""Paper Fig. 12: mixed precision — per-sublayer-type (q, g) assignment.

Attention and FFN matrices get independent (q, g) configs (the paper's
constraint set: q ∈ {3,4,5}, g ∈ {128, 256} here scaled to the small model);
the Pareto of (compression, PPL) widens vs single-config quantization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from benchmarks.fig5_ppl_tradeoff import _ppl, _train
from repro.quant import QuantPolicy, quantize_params, quantized_bytes


def run() -> list:
    rows = []
    cfg, params, corpus = _train(192, 3)
    base_ppl = _ppl(cfg, params, corpus)
    base_bytes = quantized_bytes(params)
    rows.append(csv_row("fig12/dense", 0.0, f"ppl={base_ppl:.3f}"))
    qs = (3, 4)
    gs = (64, 128)
    for qa in qs:
        for ga in gs:
            for qf in qs:
                for gf in gs:
                    pol = QuantPolicy(attn=(qa, ga), ffn=(qf, gf), iters=5)
                    qp = quantize_params(params, pol)
                    ppl = _ppl(cfg, qp, corpus)
                    ratio = base_bytes / quantized_bytes(qp)
                    rows.append(
                        csv_row(
                            f"fig12/attn_q{qa}g{ga}_ffn_q{qf}g{gf}",
                            0.0,
                            f"ppl_deg={ppl-base_ppl:.3f};comp_ratio={ratio:.2f}",
                        )
                    )
    return rows
