"""Shared benchmark utilities: the latency/energy model + timing helpers.

The container is CPU-only, so TPU latencies come from the byte/FLOP roofline
model (v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI/link) — the same
constants as the dry-run analysis. Measured CPU wall-times are reported
alongside as functional sanity numbers, never as TPU claims.
"""

from __future__ import annotations

import time

import jax
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIP_POWER_W = 200.0  # v5e-class chip power envelope (energy model)

BF16 = 2
F32 = 4


def bcq_bytes(m: int, n: int, q: int, g: int, scale_bytes: int = 2) -> int:
    """Packed BCQ footprint of an (m × n) matrix (paper Eq. 3)."""
    return q * (m * n // 8) + q * (m * n // g) * scale_bytes


def matvec_latency_s(weight_bytes: int, io_bytes: int = 0) -> float:
    """Single-batch matmul is memory-bound: latency ≈ bytes / HBM bandwidth."""
    return (weight_bytes + io_bytes) / HBM_BW


def tp_matvec_latency_s(m: int, n: int, chips: int, dtype_bytes: int = BF16) -> float:
    """Tensor-parallel dense matvec on `chips` chips: per-chip weight read +
    the output all-reduce over ICI (ring, 2(n-1)/n)."""
    w = m * n * dtype_bytes / chips
    t_mem = w / HBM_BW
    out_bytes = m * F32
    t_coll = 0.0
    if chips > 1:
        t_coll = out_bytes * 2 * (chips - 1) / chips / ICI_BW
        t_coll += 2e-6 * np.log2(chips)  # per-hop launch/sync latency
    return t_mem + t_coll


def energy_j(latency_s: float, chips: int) -> float:
    return latency_s * chips * CHIP_POWER_W


def time_call(fn, *args, reps: int = 5) -> float:
    """Median wall-time (µs) of a jitted callable on this CPU."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
