"""Paper Table V: GPT-3 175B (m = 12288) — the 4 major matmuls per layer,
speed-up + normalised energy for BCQ q∈{2,4} vs the dense baseline, plus the
8-chip dense TP comparison. Paper (FP32 baseline): q=2 total 14.41×, energy
0.07; our bf16 baseline should land near half the speed-up (paper §VI).
"""

from __future__ import annotations

from benchmarks.common import (
    bcq_bytes,
    csv_row,
    energy_j,
    matvec_latency_s,
    tp_matvec_latency_s,
)

M = 12288
LAYERS = [
    ("qkv", 3 * M, M),
    ("attn_out", M, M),
    ("ffn1", M, 4 * M),
    ("ffn2", 4 * M, M),
]


def run() -> list:
    rows = []
    tot = {"dense1": 0.0, "dense8": 0.0, "q2": 0.0, "q4": 0.0}
    for name, mm, nn in LAYERS:
        t1 = tp_matvec_latency_s(mm, nn, 1)
        t8 = tp_matvec_latency_s(mm, nn, 8)
        tq2 = matvec_latency_s(bcq_bytes(mm, nn, 2, g=mm))
        tq4 = matvec_latency_s(bcq_bytes(mm, nn, 4, g=mm))
        tot["dense1"] += t1
        tot["dense8"] += t8
        tot["q2"] += tq2
        tot["q4"] += tq4
        e1 = energy_j(t1, 1)
        for tag, t, chips in (("dense_tp8", t8, 8), ("bcq_q2", tq2, 1), ("bcq_q4", tq4, 1)):
            rows.append(
                csv_row(
                    f"table5/{name}/{tag}",
                    t * 1e6,
                    f"speedup={t1/t:.2f}x;norm_energy={energy_j(t, chips)/e1:.2f}",
                )
            )
    e1 = energy_j(tot["dense1"], 1)
    rows.append(
        csv_row(
            "table5/total/dense_tp8", tot["dense8"] * 1e6,
            f"speedup={tot['dense1']/tot['dense8']:.2f}x;"
            f"norm_energy={energy_j(tot['dense8'], 8)/e1:.2f}",
        )
    )
    for q in (2, 4):
        t = tot[f"q{q}"]
        rows.append(
            csv_row(
                f"table5/total/bcq_q{q}", t * 1e6,
                f"speedup={tot['dense1']/t:.2f}x;"
                f"norm_energy={energy_j(t, 1)/e1:.2f};"
                f"paper_fp32_speedup={'14.41x' if q == 2 else '7.50x'}",
            )
        )
    return rows
