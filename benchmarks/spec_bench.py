"""Self-speculative decoding benchmark: acceptance rate and tok/s vs plain
scanned decode (ISSUE 3 acceptance number).

The workload is the quickstart/serve model shape: a reduced llama briefly
trained on the synthetic Markov corpus (so the 4-bit target's greedy decode
is meaningful and the nested low-bit drafts actually agree with it), BCQ-
quantized with the *greedy* solver — whose plane prefixes are bit-identical
to the lower-bit greedy solutions, i.e. the best nested drafts the format
carries (core/qtensor.QuantizedTensor.truncate).

Grid: q_draft ∈ {1, 2} × γ ∈ {2, 4, 8}, all against one warm plain-scan
baseline, greedy decode (speculative greedy output is token-identical to the
baseline — asserted here for every cell). The acceptance gate is the
q_draft=2, γ=4 cell: host tok/s must be >= the plain scanned decode.

CPU-host numbers are functional sanity, not TPU claims (benchmarks/common.py):
on the host the draft advantage is the q-proportional dequant/unpack work in
the ref path; on TPU it is the q-proportional HBM weight traffic the paper's
latency model prices (§IV), which is strictly larger.

PYTHONPATH=src python benchmarks/spec_bench.py [--out BENCH_spec.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import MarkovCorpus, batch_iterator
from repro.infer import Engine, SpecConfig
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params
from repro.train import adamw_init, make_train_step

Q_TARGET = 4
GRID_QD = (1, 2)
GRID_GAMMA = (2, 4, 8)
GEN = 48
BATCH = 1  # the paper's canonical single-stream generation (§V)
PROMPT = 16
TRAIN_STEPS = 140


def build_model():
    """Quickstart-sized serving model: big enough that quantization bites on
    every linear and decode is weight-dominated (wide FFN + LM head, B=1 so
    per-step dequant isn't amortised over batch rows); branching-1 corpus —
    a deterministic successor chain — so the trained model's argmax margin is
    large and the truncated draft agrees with the full-precision target on
    most steps. That is speculative decoding's native regime (predictable
    continuations); the grid below also reports the low-acceptance cells."""
    cfg = reduced(
        get_config("llama3.2-3b"), d_model=512, n_layers=2, n_heads=8,
        n_kv_heads=2, d_ff=2048, vocab=1024,
    )
    corpus = MarkovCorpus(cfg.vocab, branching=1, seed=5)
    params = init_params(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, lr=2e-3))
    opt = adamw_init(params)
    it = batch_iterator(corpus, batch=16, seq_len=48)
    for _ in range(TRAIN_STEPS):
        b = next(it)
        params, opt, _ = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
    qp = quantize_params(
        params, QuantPolicy(q=Q_TARGET, g=64, method="greedy")
    )
    return cfg, corpus, qp


def timed(fn, repeats=3):
    fn()  # warm (compile)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(__file__), "..", "BENCH_spec.json"),
    )
    args = ap.parse_args()

    t0 = time.perf_counter()
    cfg, corpus, qp = build_model()
    print(f"model build+train: {time.perf_counter() - t0:.1f}s")

    prompts = corpus.sample(BATCH, PROMPT, seed=99)[:, :PROMPT].astype(np.int32)
    eng = Engine(cfg, qp, max_seq=PROMPT + GEN + 16)
    total = BATCH * GEN

    plain_dt = timed(lambda: eng.generate(prompts, GEN))
    plain_tps = total / plain_dt
    reference = eng.generate(prompts, GEN)
    rows = [
        {
            "name": "spec/plain_scan_decode",
            "tokens_per_s": round(plain_tps, 2),
            "accept_rate": None,
            "derived": f"q={Q_TARGET};batch={BATCH};gen={GEN};greedy",
        }
    ]
    print(f"plain scan decode: {plain_tps:.1f} tok/s")

    gate_tps = None
    for qd in GRID_QD:
        for gamma in GRID_GAMMA:
            sc = SpecConfig(q_draft=qd, gamma=gamma)
            res = eng.generate(prompts, GEN, speculate=sc)
            np.testing.assert_array_equal(
                res.tokens, reference.tokens,
                err_msg=f"speculative greedy diverged at q'={qd} γ={gamma}",
            )
            dt = timed(lambda: eng.generate(prompts, GEN, speculate=sc))
            tps = total / dt
            acc = res.spec_stats["accept_rate"]
            rows.append(
                {
                    "name": f"spec/qdraft{qd}_gamma{gamma}",
                    "tokens_per_s": round(tps, 2),
                    "accept_rate": round(acc, 4),
                    "derived": f"q={Q_TARGET};q_draft={qd};gamma={gamma};"
                    f"batch={BATCH};gen={GEN};speedup={tps / plain_tps:.2f}x",
                }
            )
            print(
                f"q'={qd} γ={gamma}: {tps:.1f} tok/s "
                f"(accept {acc:.0%}, {tps / plain_tps:.2f}x plain)"
            )
            if qd == 2 and gamma == 4:
                gate_tps = tps

    rows.append(
        {
            "name": "spec/speedup_qdraft2_gamma4_vs_plain",
            "tokens_per_s": None,
            "accept_rate": None,
            "derived": f"speedup={gate_tps / plain_tps:.2f}x",
        }
    )
    print(f"gate (q'=2, γ=4) vs plain: {gate_tps / plain_tps:.2f}x")
    assert gate_tps >= plain_tps, (
        "acceptance: speculative decode must reach plain-scan tok/s at "
        f"q_draft=2, γ=4 (got {gate_tps / plain_tps:.2f}x)"
    )

    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
        f.write("\n")
    print("wrote", out)


if __name__ == "__main__":
    main()
