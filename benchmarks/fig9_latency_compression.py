"""Paper Fig. 9: latency is a function of compression ratio alone (m=12288).

Sweep (q, g) pairs; if two pairs give a similar footprint they give a similar
latency — single-batch quantized matmul is purely memory-bound (paper §III.C).
"""

from __future__ import annotations

from benchmarks.common import BF16, bcq_bytes, csv_row, matvec_latency_s


def run() -> list:
    rows = []
    m = 12288
    dense = m * m * BF16
    for q in (1, 2, 3, 4, 5):
        for g in (32, 64, 128, 256, 1024, m):
            b = bcq_bytes(m, m, q, g)
            t = matvec_latency_s(b)
            rows.append(
                csv_row(
                    f"fig9/q{q}_g{g if g != m else 'rowwise'}",
                    t * 1e6,
                    f"comp_ratio={dense/b:.2f};bytes_mb={b/2**20:.1f}",
                )
            )
    return rows
