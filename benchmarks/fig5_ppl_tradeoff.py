"""Paper Figs. 5 & 6: PPL degradation vs compression ratio over (q, g).

A small LM is trained on the deterministic Markov corpus (WikiText stand-in —
offline container), post-training-quantized with the paper's alternating
solver across the (q, g) grid, and evaluated on held-out text. Fig. 6's
larger-models-compress-better claim is probed with two model widths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core.bcq import compression_ratio
from repro.data import MarkovCorpus, batch_iterator
from repro.models import forward, init_params, reduced
from repro.quant import QuantPolicy, quantize_params, quantized_bytes
from repro.train import adamw_init, cross_entropy, make_train_step

VOCAB = 512
STEPS = 120


def _train(d_model: int, n_layers: int, seed: int = 0):
    cfg = reduced(
        get_config("llama3.2-3b"), d_model=d_model, n_layers=n_layers,
        n_kv_heads=4, d_ff=2 * d_model, vocab=VOCAB,
    )
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=2e-3))
    corpus = MarkovCorpus(VOCAB, seed=7)
    it = batch_iterator(corpus, batch=16, seq_len=64, seed=11)
    for _ in range(STEPS):
        b = next(it)
        params, opt, _ = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
    return cfg, params, corpus


def _ppl(cfg, params, corpus) -> float:
    eval_fn = jax.jit(lambda p, t, l: cross_entropy(forward(cfg, p, tokens=t)[0], l))
    it = batch_iterator(corpus, batch=16, seq_len=64, seed=999)  # held-out stream
    nll = [float(eval_fn(params, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"])))
           for b in (next(it) for _ in range(4))]
    return float(np.exp(np.mean(nll)))


def run() -> list:
    rows = []
    for d_model, n_layers, tag in ((128, 2, "small"), (256, 4, "large")):
        cfg, params, corpus = _train(d_model, n_layers)
        base_ppl = _ppl(cfg, params, corpus)
        base_bytes = quantized_bytes(params)
        rows.append(csv_row(f"fig5/{tag}/dense", 0.0, f"ppl={base_ppl:.3f}"))
        for q in (2, 3, 4):
            for g in (32, 64, 128):
                qp = quantize_params(params, QuantPolicy(q=q, g=g, iters=6))
                ppl = _ppl(cfg, qp, corpus)
                ratio = base_bytes / quantized_bytes(qp)
                rows.append(
                    csv_row(
                        f"fig5/{tag}/q{q}_g{g}",
                        0.0,
                        f"ppl={ppl:.3f};ppl_deg={ppl-base_ppl:.3f};"
                        f"comp_ratio={ratio:.2f};eq3_weight_ratio="
                        f"{compression_ratio(q, g):.2f}",
                    )
                )
    return rows
