"""Kernel micro-bench: LUT-GEMM vs unpack-MXU variant vs dense ref (CPU
functional timings + modeled TPU bytes). Informs the DESIGN.md §2 claim that
the unpack variant is the better TPU mapping.

Decode-shaped rows (ISSUE 1): B∈{1,8} GQA-sized projections comparing the
heuristic block schedule against the measured autotuner pick, and the fused
QKV kernel (one pass, one activation read) against three per-projection
dispatches. Interpret-mode CPU timings are the recorded proxy for this
container; the roofline-modeled bytes carry the TPU claim.

Format-comparison rows (ISSUE 5, DESIGN.md §2.4): the paper's kernel
comparison shape — LUT-GEMM (``bcq``) vs uniform int-q (``uniform``) vs
*dequantize-then-matmul* (``dequant``, the Table 3 / Fig. 9 baseline) — at
the same (q, g) on the same decode matvec, each through its registered
``qmatmul`` kernel. The modeled decode latency charges ``dequant`` the dense
round trip (packed read + dense write + dense read) the fused kernels avoid,
which is the paper's argument in numbers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BF16, bcq_bytes, csv_row, matvec_latency_s, time_call
from repro.core import fuse_tensors, get_format, quantize_tensor
from repro.kernels import autotune, qmatmul
from repro.kernels.bcq_mm import bcq_mm
from repro.kernels.bcq_mm_fused import bcq_mm_fused
from repro.kernels.ops import quantized_matmul

# decode-shaped GQA projection sizes (4:1 query:kv head ratio)
DEC_K, DEC_QDIM, DEC_KVDIM, DEC_Q, DEC_G = 1024, 1024, 256, 3, 128


def _decode_rows(rng) -> list:
    rows = []
    wq, wk, wv = (
        jnp.asarray(rng.standard_normal((DEC_K, o)), jnp.float32)
        for o in (DEC_QDIM, DEC_KVDIM, DEC_KVDIM)
    )
    qts = [
        quantize_tensor(w, DEC_Q, DEC_G, iters=1, scale_dtype=jnp.float32)
        for w in (wq, wk, wv)
    ]
    fused = fuse_tensors(qts)
    out_dims = tuple(t.o for t in qts)

    for B in (1, 8):
        x = jnp.asarray(rng.standard_normal((B, DEC_K)), jnp.float32)
        # dispatch (ops._pallas_mm) pads B to the sublane width before asking
        # the tuner — query the same key here so the benchmarked schedule is
        # the one production actually selects for this batch
        B_disp = B + (-B % 8)
        qt = qts[0]
        # default (heuristic) vs measured-autotuned block schedule
        bk_h, bo_h = autotune.heuristic_blocks(qt.k, qt.o, qt.g)
        bk_a, bo_a = autotune.get_blocks(
            B=B_disp, k=qt.k, o=qt.o, q=qt.q, g=qt.g, impl="bcq_mm", interpret=True
        )
        for tag, (bk, bo) in (("default", (bk_h, bo_h)), ("autotuned", (bk_a, bo_a))):
            fn = functools.partial(
                bcq_mm, g=qt.g, block_k=bk, block_o=bo, interpret=True
            )
            rows.append(
                csv_row(
                    f"kernel/decode_b{B}/bcq_mm_{tag}_bk{bk}_bo{bo}",
                    time_call(fn, x, qt.packed, qt.scales, reps=3),
                    f"hbm_bytes_model={bcq_bytes(DEC_K, DEC_QDIM, DEC_Q, DEC_G)}",
                )
            )

        # fused QKV (one pass, activations read once for 3 projections)
        # vs three per-projection dispatches — each side gets its autotuned
        # schedule: the fused kernel may tile the output wider than any single
        # projection allows, which is part of the fusion win
        t_sep = 0.0
        for t in qts:
            sbk, sbo = autotune.get_blocks(
                B=B_disp, k=t.k, o=t.o, q=t.q, g=t.g, impl="bcq_mm", interpret=True
            )
            t_sep += time_call(
                functools.partial(
                    bcq_mm, g=t.g, block_k=sbk, block_o=sbo, interpret=True
                ),
                x, t.packed, t.scales, reps=3,
            )
        fbk, fbo = autotune.get_blocks(
            B=B_disp, k=fused.k, o=fused.o, q=fused.q, g=fused.g, impl="bcq_mm",
            interpret=True,
        )
        t_fused = time_call(
            functools.partial(
                bcq_mm_fused, g=fused.g, out_dims=out_dims,
                block_k=fbk, block_o=fbo, interpret=True,
            ),
            x, fused.packed, fused.scales, reps=3,
        )
        # modeled v5e decode latency: weight+activation HBM stream + ~2us
        # launch overhead per dispatch. At matvec size the launches dominate,
        # which is exactly what fusion removes; the CPU interpreter executes
        # the same grid-cell work either way so its wall time can't see that
        # (recorded anyway as the functional proxy).
        act_bytes = B * DEC_K * 4
        launch_us = 2.0
        w_bytes = [bcq_bytes(t.k, t.o, t.q, t.g) for t in qts]
        model_sep = sum(
            matvec_latency_s(wb, act_bytes) * 1e6 + launch_us for wb in w_bytes
        )
        model_fused = matvec_latency_s(sum(w_bytes), act_bytes) * 1e6 + launch_us
        rows.append(
            csv_row(
                f"kernel/decode_b{B}/qkv_3x_separate",
                t_sep,
                f"activation_reads=3x{act_bytes}B;dispatches=3;"
                f"tpu_model_us={model_sep:.2f}",
            )
        )
        rows.append(
            csv_row(
                f"kernel/decode_b{B}/qkv_fused",
                t_fused,
                f"activation_reads=1x{act_bytes}B;dispatches=1;"
                f"tpu_model_us={model_fused:.2f};"
                f"speedup_model={model_sep / model_fused:.2f}x;"
                f"speedup_cpu_interpret={t_sep / max(t_fused, 1e-9):.2f}x",
            )
        )
    return rows


def _format_bytes(fmt: str, k: int, o: int, q: int, g: int,
                  scale_bytes: int = 2) -> int:
    """Decode-step HBM bytes per format (weight-side; activations added by
    the caller). ``dequant`` pays its packed read PLUS the dense bf16
    round trip (write after dequant, read by the GEMM) — the pipeline cost
    the paper's comparison isolates."""
    if fmt == "bcq":
        return bcq_bytes(k, o, q, g, scale_bytes)  # paper Eq. 3
    if fmt == "codebook":
        # q index bit planes + the 2^q-entry centroid table per group
        return q * (k * o // 8) + (1 << q) * (k * o // g) * scale_bytes
    if fmt == "ternary":
        # 2 fixed bit planes (sign + mask) + ONE alpha plane per group
        return 2 * (k * o // 8) + (k * o // g) * scale_bytes
    # uniform/dequant: q bit planes + a (scale, zero) affine pair per group
    affine = q * (k * o // 8) + 2 * (k * o // g) * scale_bytes
    if fmt == "uniform":
        return affine
    return affine + 2 * k * o * BF16  # dequant: + dense write + dense read


def _format_rows(rng) -> list:
    """Every registered format's decode matvec at the same (q, g) — the
    paper's kernel-comparison shape, reproduced on host, with all five
    formats priced on one axis. CPU interpret wall time is the functional
    proxy; the modeled v5e latency (memory-bound byte stream + 2us per
    dispatch) carries the claim, and shows the dequant baseline strictly
    slower than the one-pass kernels."""
    k = o = 1024
    q, g, B = 4, 128, 1
    w = jnp.asarray(rng.standard_normal((k, o)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, k)), jnp.float32)
    act_bytes = B * k * 4 + B * o * 4
    launch_us = 2.0
    rows, model_us = [], {}
    for fmt in ("bcq", "uniform", "dequant", "codebook", "ternary"):
        qt = quantize_tensor(
            w, q, g, iters=1, scale_dtype=jnp.float32, method="greedy", fmt=fmt
        )
        impl = get_format(fmt).impls[0]
        fn = functools.partial(qmatmul, fmt, impl=impl, interpret=True)
        t_cpu = time_call(lambda xx: fn(xx, qt)[0], x, reps=3)
        dispatches = 2 if fmt == "dequant" else 1
        model_us[fmt] = (
            matvec_latency_s(_format_bytes(fmt, k, o, q, g), act_bytes) * 1e6
            + launch_us * dispatches
        )
        rows.append(
            csv_row(
                f"kernel/decode_fmt_b{B}/{fmt}_{impl}",
                t_cpu,
                f"tpu_model_us={model_us[fmt]:.2f};"
                f"hbm_bytes={_format_bytes(fmt, k, o, q, g)};"
                f"dispatches={dispatches};nbytes_packed={qt.nbytes()}",
            )
        )
    rows.append(
        csv_row(
            f"kernel/decode_fmt_b{B}/dequant_vs_bcq",
            model_us["dequant"],
            f"slowdown_model={model_us['dequant'] / model_us['bcq']:.2f}x;"
            f"slowdown_vs_uniform={model_us['dequant'] / model_us['uniform']:.2f}x;"
            "baseline=dequantize-then-matmul (paper Table 3 / Fig. 9 shape)",
        )
    )
    return rows


def _engine_rows() -> list:
    """End-to-end decode: scanned + fused engine vs per-token step loop.

    This is where the tentpole's wins are measurable on THIS host: the scan
    removes N-1 dispatches and every per-token device→host logits sync, and
    fusion turns 3 QKV (+2 gate-up) matmuls into 1 (+1) per layer."""
    import time as _time

    import numpy as np_

    from repro.configs import get_config
    from repro.data import MarkovCorpus
    from repro.infer import Engine
    from repro.models import init_params, reduced

    cfg = reduced(get_config("llama3.2-3b"), d_model=256, n_kv_heads=4, d_ff=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = MarkovCorpus(cfg.vocab, seed=3).sample(4, 16, seed=7)
    prompts = prompts[:, :16].astype(np_.int32)
    gen = 32
    rows = []
    timings = {}
    for mode, engine_kw, gen_kw in (
        ("step_unfused", {"fuse": False}, {"scan": False}),
        ("scan_fused", {"fuse": True}, {"scan": True}),
    ):
        eng = Engine(cfg, params, max_seq=64, **engine_kw)
        eng.generate(prompts, gen, **gen_kw)  # warmup: compile
        t0 = _time.perf_counter()
        eng.generate(prompts, gen, **gen_kw)
        timings[mode] = (_time.perf_counter() - t0) * 1e6
    speed = timings["step_unfused"] / max(timings["scan_fused"], 1e-9)
    rows.append(
        csv_row("engine/decode_step_unfused/b4_gen32", timings["step_unfused"],
                "dispatches_per_token=1;host_syncs_per_token=1")
    )
    rows.append(
        csv_row("engine/decode_scan_fused/b4_gen32", timings["scan_fused"],
                f"dispatches_total=1;host_syncs_total=1;"
                f"speedup_vs_step={speed:.2f}x")
    )
    return rows


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    m, q, g = 1024, 4, 128
    w = jnp.asarray(rng.standard_normal((m, m)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, m)), jnp.float32)
    qt = quantize_tensor(w, q, g, iters=1, scale_dtype=jnp.float32)
    fns = {
        "ref_dequant_dot": jax.jit(lambda x: quantized_matmul(x, qt, impl="ref")),
        "pallas_bcq_mm_interpret": lambda x: quantized_matmul(
            x, qt, impl="bcq_mm", interpret=True
        ),
        "pallas_lutgemm_interpret": lambda x: quantized_matmul(
            x, qt, impl="lutgemm", interpret=True
        ),
    }
    for name, fn in fns.items():
        rows.append(
            csv_row(
                f"kernel/{name}/m{m}_q{q}_g{g}",
                time_call(fn, x, reps=3),
                f"hbm_bytes_model={bcq_bytes(m, m, q, g)};dense={m*m*BF16}",
            )
        )
    rows.extend(_decode_rows(rng))
    rows.extend(_format_rows(rng))
    rows.extend(_engine_rows())
    return rows
