"""Kernel micro-bench: LUT-GEMM vs unpack-MXU variant vs dense ref (CPU
functional timings + modeled TPU bytes). Informs the DESIGN.md §2 claim that
the unpack variant is the better TPU mapping."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BF16, bcq_bytes, csv_row, time_call
from repro.core import quantize_tensor
from repro.kernels.ops import quantized_matmul


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    m, q, g = 1024, 4, 128
    w = jnp.asarray(rng.standard_normal((m, m)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, m)), jnp.float32)
    qt = quantize_tensor(w, q, g, iters=1, scale_dtype=jnp.float32)
    fns = {
        "ref_dequant_dot": jax.jit(lambda x: quantized_matmul(x, qt, impl="ref")),
        "pallas_bcq_mm_interpret": lambda x: quantized_matmul(
            x, qt, impl="bcq_mm", interpret=True
        ),
        "pallas_lutgemm_interpret": lambda x: quantized_matmul(
            x, qt, impl="lutgemm", interpret=True
        ),
    }
    for name, fn in fns.items():
        rows.append(
            csv_row(
                f"kernel/{name}/m{m}_q{q}_g{g}",
                time_call(fn, x, reps=3),
                f"hbm_bytes_model={bcq_bytes(m, m, q, g)};dense={m*m*BF16}",
            )
        )
    return rows
