"""Paper Table III: memory footprint + single-batch matmul latency,
dense (cuBLAS-analogue) vs BCQ (nuQmm/LUT-GEMM), (m×m)·(m×1).

Ours targets TPU v5e with a bf16 dense baseline (paper §VI: vs their FP32
numbers, reductions halve). Latency from the memory-bound roofline model;
measured CPU µs of the jnp reference path included as a functional check.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    BF16,
    bcq_bytes,
    csv_row,
    matvec_latency_s,
    time_call,
)
from repro.core import quantize_tensor
from repro.kernels.ops import quantized_matmul


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for m in (2048, 4096, 8192, 12288):
        dense_bytes = m * m * BF16
        t_dense = matvec_latency_s(dense_bytes, io_bytes=2 * m * BF16)
        rows.append(
            csv_row(
                f"table3/dense_bf16/m{m}",
                t_dense * 1e6,
                f"mem_mb={dense_bytes/2**20:.2f};model=tpu-roofline",
            )
        )
        for q in (2, 3, 4, 5):
            b = bcq_bytes(m, m, q, g=m)  # row-wise, as in Table III
            t = matvec_latency_s(b, io_bytes=2 * m * BF16)
            rows.append(
                csv_row(
                    f"table3/bcq_q{q}/m{m}",
                    t * 1e6,
                    f"mem_mb={b/2**20:.2f};mem_red={dense_bytes/b:.1f}x;"
                    f"speedup={t_dense/t:.1f}x",
                )
            )
    # functional CPU sample (small m): packed path vs dense, measured
    m = 2048
    w = jnp.asarray(rng.standard_normal((m, m)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, m)), jnp.float32)
    qt = quantize_tensor(w, 4, m, iters=1, scale_dtype=jnp.float32)
    f_dense = jax.jit(lambda x: x @ w)
    f_q = jax.jit(lambda x: quantized_matmul(x, qt, impl="ref"))
    rows.append(
        csv_row("table3/cpu_dense_measured/m2048", time_call(f_dense, x), "functional")
    )
    rows.append(
        csv_row("table3/cpu_bcq_ref_measured/m2048", time_call(f_q, x), "functional")
    )
    return rows
