"""Serving-throughput benchmark: continuous batching vs one-shot generate.

Measures the ISSUE 2 acceptance number: at >=4 concurrent requests the
continuous-batching scheduler must sustain higher tokens/sec than serving the
same workload as sequential one-shot scanned ``Engine.generate`` calls (the
PR 1 fast path). Two arrival regimes:

- ``burst``  — all requests queued at t=0 (pure throughput / makespan);
- ``poisson``— Poisson arrivals at ~2x the sequential service rate, the
  regime the paper's serving workload (§V, OPT token generation) lives in:
  the queue stays non-empty, so the win is batch-feeding, not queueing tricks.

ISSUE 6 adds the request-lifecycle regimes on top (the hardened scheduler's
operating envelope, not just its happy-path throughput):

- ``heavytail`` — Lomax/Pareto arrivals at the same mean rate as the Poisson
  trace but with bursty clumps and long gaps; reports TTFT/TPOT p50/p95/p99
  from the scheduler's own lifecycle records;
- ``cancel sweep`` — a fraction of requests is cancelled right after its
  first streamed token; survivor throughput and reclaimed-slot utilisation
  show cancellation freeing capacity instead of wasting it;
- ``bounded queue`` — a burst twice the queue bound with tight TTFT
  deadlines: overflow rejects loudly at submit, stale queue entries are shed
  before wasting a prefill, and the served remainder keeps its latency.

Both paths are warmed first so XLA compiles (per prompt-length/budget shape)
stay out of the timings. CPU-host numbers are functional sanity, not TPU
claims (benchmarks/common.py).

ISSUE 10 adds the prefix-cache / chunked-prefill regimes (DESIGN.md §12):

- ``shared prefix`` — half the traffic repeats a 24-token system prompt:
  serving it against a warm prefix cache must improve mean TTFT over the
  cold engine while TPOT stays within a bounded regression (both asserted);
- ``long-prompt interleave`` — long prefills dispatched whole-shot vs in
  ``prefill_chunk`` buckets interleaved with decode, reporting the short
  requests' TTFT tail (head-of-line blocking made visible);
- ``prefix overload`` — the warm cache under 2x overload with a bounded
  queue, cancels and deadlines: the refcount ledger must drain to zero and
  ``hits + misses == commits + aborts`` (leak-free accounting, asserted).

ISSUE 8 adds the observability overhead regime (``BENCH_obs.json``): the
same burst workload served with the tracer + metrics registry attached vs
bare, interleaved and min-of-N so the delta is the instrumentation and not
host noise, plus the raw per-span record cost and the instrumented run's
full metrics snapshot (the artifact a dashboard would scrape). The
documented budget — single-digit µs per span, serving overhead within noise
— is *asserted* in tests/test_obs.py; here it is measured and reported.

PYTHONPATH=src python benchmarks/serve_bench.py [--out BENCH_serve.json]
                                                [--obs-out BENCH_obs.json]
PYTHONPATH=src python benchmarks/serve_bench.py --obs-only   # just the obs
                                                             # artifact
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import MarkovCorpus
from repro.infer import Engine, PrefixCache, QueueFullError, Request, Scheduler
from repro.launch.serve import (
    build_requests,
    drive_continuous,
    drive_sequential,
    pareto_arrivals,
    poisson_arrivals,
)
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params

N_REQUESTS = 12
PROMPT_LEN = 16
GEN = 24
SLOTS = (4, 8)
CHUNK = 8


def _engine():
    cfg = reduced(get_config("llama3.2-3b"), d_model=256, n_kv_heads=4, d_ff=512)
    params = quantize_params(
        init_params(jax.random.PRNGKey(0), cfg), QuantPolicy(q=4, g=128, iters=4)
    )
    return cfg, params, Engine(cfg, params, max_seq=PROMPT_LEN + GEN + 8)


def _warmup(cfg, engine):
    """Compile every shape both paths will hit: the (PROMPT_LEN, GEN) scan
    generate, the batch-1 prefill, the admit install, and one decode chunk
    per slot width."""
    reqs = build_requests(cfg, 2, PROMPT_LEN, GEN)
    engine.generate(reqs[0].prompt[None], GEN, temperature=1.0, seed=0)
    engine.generate(reqs[0].prompt[None], GEN, temperature=0.0, seed=0)
    for n_slots in SLOTS:
        sched = Scheduler(engine, n_slots=n_slots, chunk=CHUNK)
        for r in reqs:
            sched.submit(r)
        sched.run()


def drive_hardened(
    engine,
    reqs,
    arrivals,
    *,
    n_slots,
    chunk,
    cancel_idx=(),
    max_queue=None,
    prefill_chunk=None,
):
    """Lifecycle-aware serve loop: like ``drive_continuous`` but tolerant of
    requests that never produce a Completion (cancelled / shed / rejected).
    Requests whose index is in ``cancel_idx`` are cancelled right after their
    first streamed token (a client hitting stop). Returns
    (scheduler, completions, makespan_s, n_rejected)."""
    watch = set()
    sched = Scheduler(
        engine,
        n_slots=n_slots,
        chunk=chunk,
        max_queue=max_queue,
        prefill_chunk=prefill_chunk,
        on_tokens=lambda rid, toks: (
            sched.cancel(rid, "client stop after first token")
            if rid in watch
            else None
        ),
    )
    done, rejected, i = [], 0, 0
    t0 = time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            try:
                rid = sched.submit(reqs[i])
                if i in cancel_idx:
                    watch.add(rid)
            except QueueFullError:
                rejected += 1
            i += 1
        if sched.idle:
            if i >= len(reqs):
                break
            time.sleep(max(0.0, arrivals[i] - (time.perf_counter() - t0)))
            continue
        done.extend(sched.step())
    return sched, done, time.perf_counter() - t0, rejected


# -- prefix-cache / chunked-prefill regimes (ISSUE 10, DESIGN.md §12) --------

P_PROMPT = 96    # long prompts so prefill is a compute-visible share of TTFT
P_SHARED = 88    # the repeated system prompt inside them
P_GEN = 8
P_CHUNK = 2      # decode chunk: several chunks per request so TPOT resolves
P_BLOCK = 8      # prefix-block granularity (matches in multiples of 8)


def _prefix_workload(cfg, run_seed, *, n=N_REQUESTS):
    """50% shared-prefix traffic: half the requests repeat a fixed
    P_SHARED-token system prompt with fresh per-run tails, half are fully
    fresh prompts.
    Only the shared prefix can ever hit — tails and unique prompts change
    every run, so the measured hit traffic is honestly 50%."""
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    shared = corpus.sample(1, P_PROMPT, seed=99)[0, :P_SHARED]
    reqs = []
    for i in range(n):
        if i % 2 == 0:
            tail = corpus.sample(
                1, P_PROMPT - P_SHARED, seed=1000 * run_seed + i
            )[0, : P_PROMPT - P_SHARED]
            prompt = np.concatenate([shared, tail])
        else:
            prompt = corpus.sample(
                1, P_PROMPT, seed=1000 * run_seed + 500 + i
            )[0, :P_PROMPT]
        reqs.append(
            Request(prompt=prompt.astype(np.int32), max_new_tokens=P_GEN,
                    seed=10 + i)
        )
    return reqs


def prefix_bench(rows) -> None:
    """Shared-prefix TTFT, long-prompt interleave, and the leak-free overload
    row. Appends rows in place; asserts the §12 acceptance numbers.

    Runs on a wider reduced model than the other regimes: at d_model=256 a
    whole 96-token prefill costs about the same as the warm path's
    install + suffix dispatches, so the cache's prefill savings drown in
    per-call overhead. d_model=512 makes prefill compute-visible, which is
    the regime the cache exists for."""
    cfg = reduced(
        get_config("llama3.2-3b"),
        d_model=512, n_kv_heads=4, d_ff=1536, n_layers=3,
    )
    params = quantize_params(
        init_params(jax.random.PRNGKey(1), cfg), QuantPolicy(q=4, g=128, iters=4)
    )
    max_seq = P_PROMPT + P_GEN + 8
    cold_eng = Engine(cfg, params, max_seq=max_seq)
    warm_eng = Engine(cfg, params, max_seq=max_seq,
                      prefix_cache=PrefixCache(block_tokens=P_BLOCK))
    zeros = np.zeros(N_REQUESTS)

    def serve(eng, run_seed, prefill_chunk=None):
        sched, done, dt = drive_continuous(
            eng, _prefix_workload(cfg, run_seed), zeros,
            n_slots=4, chunk=P_CHUNK, prefill_chunk=prefill_chunk,
        )
        s = sched.summary()
        return s["ttft_s"], s["tpot_s"], dt

    # warmups: compile both engines' shapes; the warm engine's pass also
    # commits the shared prefix, which is exactly the steady state measured.
    # Measured runs interleave cold/warm and take the min-of-N mean so host
    # load drift lands on both sides (same policy as obs_bench).
    serve(cold_eng, 0)
    serve(warm_eng, 0)
    cold_runs, warm_runs = [], []
    for rep in (1, 2):
        cold_runs.append(serve(cold_eng, rep))
        warm_runs.append(serve(warm_eng, rep))
    ttft_c, tpot_c, dt_c = min(cold_runs, key=lambda r: r[0]["mean"])
    ttft_w, tpot_w, dt_w = min(warm_runs, key=lambda r: r[0]["mean"])
    st = warm_eng.prefix_cache.stats()
    ttft_gain = 100.0 * (ttft_c["mean"] - ttft_w["mean"]) / ttft_c["mean"]
    for tag, t, p, dt, extra in (
        ("cold", ttft_c, tpot_c, dt_c, ""),
        ("warm", ttft_w, tpot_w, dt_w,
         f";hits={st['hits']};misses={st['misses']}"),
    ):
        rows.append(
            {
                "name": f"serve/prefix_shared50/{tag}",
                "tokens_per_s": round(N_REQUESTS * P_GEN / dt, 2),
                "makespan_s": round(dt, 3),
                "derived": (
                    f"ttft_mean={t['mean']:.3f}s;ttft_p50={t['p50']:.3f}s;"
                    f"ttft_p95={t['p95']:.3f}s;tpot_p50={p['p50'] * 1e3:.1f}ms;"
                    f"prompt={P_PROMPT};shared={P_SHARED};block={P_BLOCK}"
                    f"{extra}"
                ),
            }
        )
    rows.append(
        {
            "name": "serve/prefix_shared50/ttft_gain",
            "tokens_per_s": None,
            "makespan_s": None,
            "derived": f"ttft_mean_gain_pct={ttft_gain:.1f};"
            f"tpot_p50_cold={tpot_c['p50'] * 1e3:.1f}ms;"
            f"tpot_p50_warm={tpot_w['p50'] * 1e3:.1f}ms",
        }
    )
    print(f"prefix shared-50%: ttft mean {ttft_c['mean']:.3f}s -> "
          f"{ttft_w['mean']:.3f}s ({ttft_gain:+.1f}%), "
          f"tpot p50 {tpot_c['p50'] * 1e3:.1f} -> {tpot_w['p50'] * 1e3:.1f}ms, "
          f"{st['hits']} hits")
    assert ttft_w["mean"] < ttft_c["mean"], (
        "acceptance: warm prefix cache must improve mean TTFT at 50% "
        f"shared-prefix traffic (cold {ttft_c['mean']:.3f}s, "
        f"warm {ttft_w['mean']:.3f}s)"
    )
    assert tpot_w["p50"] <= tpot_c["p50"] * 1.5 + 2e-3, (
        "acceptance: TPOT regression must stay bounded "
        f"(cold {tpot_c['p50']:.4f}s, warm {tpot_w['p50']:.4f}s)"
    )

    # -- long-prompt interleave: whole-shot vs bucketed chunked prefill ------
    # All 12 requests are resident at once (n_slots=12), shorts queued ahead
    # of the longs, so every long admission happens while shorts decode.
    # Whole-shot: each long prefill is one 96-token dispatch that blocks the
    # step loop, inflating the shorts' time-to-first-chunk. Chunked: the same
    # prefill lands in P_BLOCK-token slices between decode chunks.
    def interleave_reqs():
        corpus = MarkovCorpus(cfg.vocab, seed=7)
        out = []
        for i in range(8):
            p = corpus.sample(1, 4, seed=400 + i)[0, :4]
            out.append(Request(prompt=p.astype(np.int32), max_new_tokens=P_GEN))
        for i in range(4):
            p = corpus.sample(1, P_PROMPT, seed=300 + i)[0, :P_PROMPT]
            out.append(Request(prompt=p.astype(np.int32), max_new_tokens=4))
        return out

    def interleave(prefill_chunk):
        reqs = interleave_reqs()
        sched, done, dt = drive_continuous(
            cold_eng, reqs, np.zeros(len(reqs)), n_slots=len(reqs),
            chunk=P_CHUNK, prefill_chunk=prefill_chunk,
        )
        short = [sched.outcomes[r.rid].ttft for r in reqs if r.prompt.size <= 8]
        short = np.asarray(sorted(t for t in short if t is not None))
        return float(short[len(short) // 2]), float(short[-1]), dt

    interleave(None), interleave(P_BLOCK)  # compile the interleave shapes
    p50_w, worst_w, dt_w2 = interleave(None)
    p50_ck, worst_ck, dt_ck = interleave(P_BLOCK)
    rows.append(
        {
            "name": "serve/prefill_interleave_long_prompts",
            "tokens_per_s": None,
            "makespan_s": None,
            "derived": (
                f"short_ttft_p50_wholeshot={p50_w:.3f}s;"
                f"short_ttft_p50_chunked={p50_ck:.3f}s;"
                f"short_ttft_max_wholeshot={worst_w:.3f}s;"
                f"short_ttft_max_chunked={worst_ck:.3f}s;"
                f"prefill_chunk={P_BLOCK};long_prompt={P_PROMPT}"
            ),
        }
    )
    print(f"long-prompt interleave: short-request ttft p50 "
          f"{p50_w:.3f}s (whole-shot) vs {p50_ck:.3f}s (chunked), "
          f"worst {worst_w:.3f}s vs {worst_ck:.3f}s")

    # -- overload: leak-free accounting under cancels + deadlines + bounds ---
    over = _prefix_workload(cfg, 2, n=2 * N_REQUESTS)
    for r in over:
        r.ttft_deadline_s = 2.0
    arrivals = poisson_arrivals(len(over), 2.0 * N_REQUESTS / max(dt_w, 0.1),
                                seed=5)
    sched, done, dt, rejected = drive_hardened(
        warm_eng, over, arrivals, n_slots=4, chunk=P_CHUNK,
        max_queue=N_REQUESTS // 2, prefill_chunk=P_BLOCK,
        cancel_idx=set(range(0, 2 * N_REQUESTS, 5)),
    )
    st = warm_eng.prefix_cache.stats()
    rows.append(
        {
            "name": "serve/prefix_overload_leakcheck",
            "tokens_per_s": None,
            "makespan_s": round(dt, 3),
            "derived": (
                f"offered={len(over)};finished={len(done)};rejected={rejected};"
                f"cancelled={sched.counters['cancelled']};"
                f"hits={st['hits']};misses={st['misses']};"
                f"commits={st['commits']};aborts={st['aborts']};"
                f"evictions={st['evictions']};pinned={st['pinned']}"
            ),
        }
    )
    print(f"prefix overload: {len(done)} finished, {rejected} rejected, "
          f"{sched.counters['cancelled']} cancelled; accounting "
          f"{st['hits']}+{st['misses']} == {st['commits']}+{st['aborts']}, "
          f"pinned={st['pinned']}")
    assert st["pinned"] == 0, "refcount leak: pins must drain to zero"
    assert st["hits"] + st["misses"] == st["commits"] + st["aborts"], (
        f"accounting leak: {st}"
    )


def obs_bench(cfg, engine, out_path) -> None:
    """ISSUE 8 artifact: tracer on/off serving overhead + per-span record
    cost + the instrumented run's metrics snapshot, written to
    ``BENCH_obs.json``. Bare and instrumented runs are interleaved and the
    min of N is compared, so host-load drift lands on both sides; the hard
    *assertion* of the per-span budget lives in tests/test_obs.py —
    this just measures and reports it on real serving."""
    from repro.obs import MetricsRegistry, Tracer

    zeros = np.zeros(N_REQUESTS)
    repeats = 3
    offs, ons = [], []
    tracer = registry = None
    for _ in range(repeats):
        _, _, dt_off = drive_continuous(
            engine, build_requests(cfg, N_REQUESTS, PROMPT_LEN, GEN), zeros,
            n_slots=4, chunk=CHUNK,
        )
        offs.append(dt_off)
        tracer, registry = Tracer(capacity=1 << 16), MetricsRegistry()
        _, _, dt_on = drive_continuous(
            engine, build_requests(cfg, N_REQUESTS, PROMPT_LEN, GEN), zeros,
            n_slots=4, chunk=CHUNK, tracer=tracer, metrics=registry,
        )
        ons.append(dt_on)
    off, on = min(offs), min(ons)
    overhead_pct = 100.0 * (on - off) / off
    total_new = N_REQUESTS * GEN
    st = tracer.stats()

    # Raw span-record cost, isolated from serving: the budget documented in
    # DESIGN.md §11 and asserted (<100us with wide slack) in test_obs.py.
    probe, n_spans = Tracer(capacity=1 << 17), 20000
    t0 = time.perf_counter()
    for _ in range(n_spans):
        with probe.span("bench", lane="bench"):
            pass
    per_span_us = (time.perf_counter() - t0) / n_spans * 1e6

    rows = [
        {
            "name": "obs/continuous_slots4/burst_tracer_off",
            "tokens_per_s": round(total_new / off, 2),
            "makespan_s": round(off, 3),
            "derived": f"requests={N_REQUESTS};gen={GEN};chunk={CHUNK};"
            f"min_of={repeats}",
        },
        {
            "name": "obs/continuous_slots4/burst_tracer_on",
            "tokens_per_s": round(total_new / on, 2),
            "makespan_s": round(on, 3),
            "derived": f"requests={N_REQUESTS};gen={GEN};chunk={CHUNK};"
            f"min_of={repeats};events={st['buffered']};evicted={st['evicted']}",
        },
        {
            "name": "obs/overhead_tracer_plus_metrics",
            "tokens_per_s": None,
            "makespan_s": None,
            "derived": f"overhead_pct={overhead_pct:.2f};"
            f"events_per_run={st['recorded']}",
        },
        {
            "name": "obs/span_record_cost",
            "tokens_per_s": None,
            "makespan_s": None,
            "derived": f"per_span_us={per_span_us:.2f};n_spans={n_spans};"
            "budget_us=100 (asserted in tests/test_obs.py)",
        },
    ]
    print(f"obs: tracer off {off:.2f}s / on {on:.2f}s "
          f"({overhead_pct:+.2f}%), {per_span_us:.2f}us/span")
    out = os.path.abspath(out_path)
    with open(out, "w") as f:
        json.dump(
            {"rows": rows, "metrics_snapshot": registry.snapshot()},
            f, indent=1, sort_keys=True,
        )
        f.write("\n")
    print("wrote", out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json"),
    )
    ap.add_argument(
        "--obs-out",
        default=os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json"),
    )
    ap.add_argument(
        "--obs-only",
        action="store_true",
        help="skip the serving regimes; only produce the BENCH_obs.json artifact",
    )
    args = ap.parse_args()

    cfg, params, engine = _engine()
    t0 = time.perf_counter()
    _warmup(cfg, engine)
    print(f"warmup (compiles): {time.perf_counter() - t0:.1f}s")

    if args.obs_only:
        obs_bench(cfg, engine, args.obs_out)
        return

    reqs = build_requests(cfg, N_REQUESTS, PROMPT_LEN, GEN)
    total_new = sum(r.max_new_tokens for r in reqs)
    rows = []

    def record(name, makespan, extra=""):
        tps = total_new / makespan
        rows.append(
            {
                "name": name,
                "tokens_per_s": round(tps, 2),
                "makespan_s": round(makespan, 3),
                "derived": f"requests={N_REQUESTS};prompt={PROMPT_LEN};gen={GEN};"
                f"q=4;g=128{extra}",
            }
        )
        print(f"{name}: {tps:.1f} tok/s (makespan {makespan:.2f}s)")
        return tps

    # -- burst regime: everything queued at t=0 ------------------------------
    zeros = np.zeros(N_REQUESTS)
    _, seq_dt = drive_sequential(engine, reqs, zeros)
    seq_tps = record("serve/sequential_oneshot/burst", seq_dt)

    cont_tps = {}
    for n_slots in SLOTS:
        sched, done, dt = drive_continuous(
            engine, reqs, zeros, n_slots=n_slots, chunk=CHUNK
        )
        util = sched.steps_active / max(1, sched.decode_steps * sched.n_slots)
        cont_tps[n_slots] = record(
            f"serve/continuous_slots{n_slots}/burst", dt,
            extra=f";chunk={CHUNK};slot_util={util:.2f}",
        )

    # -- poisson regime: arrivals at ~2x the sequential service rate ---------
    rate = 2.0 * N_REQUESTS / seq_dt
    arrivals = poisson_arrivals(N_REQUESTS, rate, seed=1)
    _, seq_p_dt = drive_sequential(engine, reqs, arrivals)
    record(f"serve/sequential_oneshot/poisson_{rate:.1f}rps", seq_p_dt)
    sched, done, dt = drive_continuous(
        engine, reqs, arrivals, n_slots=4, chunk=CHUNK
    )
    util = sched.steps_active / max(1, sched.decode_steps * sched.n_slots)
    record(
        f"serve/continuous_slots4/poisson_{rate:.1f}rps", dt,
        extra=f";chunk={CHUNK};slot_util={util:.2f}",
    )

    speedup = cont_tps[4] / seq_tps
    rows.append(
        {
            "name": "serve/speedup_continuous4_vs_sequential/burst",
            "tokens_per_s": None,
            "makespan_s": None,
            "derived": f"speedup={speedup:.2f}x",
        }
    )
    print(f"continuous(4 slots) vs sequential: {speedup:.2f}x")
    assert speedup > 1.0, (
        "acceptance: continuous batching must beat sequential one-shot "
        f"generate at >=4 slots (got {speedup:.2f}x)"
    )

    def pct_row(name, sched, extra=""):
        """TTFT/TPOT percentiles from the scheduler's lifecycle records."""
        s = sched.summary()
        ttft, tpot = s["ttft_s"], s["tpot_s"]
        by = ";".join(f"{k}={v}" for k, v in sorted(s["by_state"].items()))
        rows.append(
            {
                "name": name,
                "tokens_per_s": None,
                "makespan_s": None,
                "derived": (
                    f"ttft_p50={ttft['p50']:.3f}s;ttft_p95={ttft['p95']:.3f}s;"
                    f"ttft_p99={ttft['p99']:.3f}s;tpot_p50={tpot['p50'] * 1e3:.1f}ms;"
                    f"tpot_p95={tpot['p95'] * 1e3:.1f}ms;{by}{extra}"
                ),
            }
        )
        print(f"{name}: ttft p50/p95/p99 = {ttft['p50']:.3f}/"
              f"{ttft['p95']:.3f}/{ttft['p99']:.3f}s ({by}{extra})")

    # -- heavy-tail (Lomax) arrivals at the same mean rate as the Poisson
    # trace: bursty clumps + long gaps is where tail latency lives ----------
    arrivals_ht = pareto_arrivals(N_REQUESTS, rate, alpha=1.5, seed=2)
    sched, done, dt, _ = drive_hardened(
        engine, build_requests(cfg, N_REQUESTS, PROMPT_LEN, GEN),
        arrivals_ht, n_slots=4, chunk=CHUNK,
    )
    record(f"serve/continuous_slots4/heavytail_{rate:.1f}rps", dt,
           extra=f";chunk={CHUNK};alpha=1.5")
    pct_row(f"serve/latency_slots4/heavytail_{rate:.1f}rps", sched)

    # -- cancellation-rate sweep: cancel right after the first token --------
    for frac in (0.25, 0.5):
        n_cancel = int(N_REQUESTS * frac)
        sched, done, dt, _ = drive_hardened(
            engine, build_requests(cfg, N_REQUESTS, PROMPT_LEN, GEN),
            np.zeros(N_REQUESTS), n_slots=4, chunk=CHUNK,
            cancel_idx=set(range(0, N_REQUESTS, max(1, N_REQUESTS // n_cancel)))
            if n_cancel else set(),
        )
        served = sum(len(c.new_tokens) for c in done)
        tps = served / dt
        rows.append(
            {
                "name": f"serve/cancel_sweep_{int(frac * 100)}pct/burst",
                "tokens_per_s": round(tps, 2),
                "makespan_s": round(dt, 3),
                "derived": (
                    f"cancelled={sched.counters['cancelled']};survivors="
                    f"{len(done)};survivor_tokens={served};chunk={CHUNK}"
                ),
            }
        )
        print(f"cancel {int(frac * 100)}%: {tps:.1f} survivor tok/s, "
              f"{sched.counters['cancelled']} cancelled, makespan {dt:.2f}s")

    # -- bounded admission queue under sustained 2x overload with tight TTFT
    # deadlines: loud rejects when the queue is full, deadline-aware shedding
    # of entries that aged out while waiting, and the served remainder keeps
    # its latency ------------------------------------------------------------
    over = build_requests(cfg, 2 * N_REQUESTS, PROMPT_LEN, GEN)
    ttft_deadline = 0.35
    for r in over:
        r.ttft_deadline_s = ttft_deadline
    # arrivals at ~2x the measured continuous service rate: the queue
    # saturates gradually, so both overflow AND aging are exercised (a t=0
    # burst would only ever reject)
    overload_rps = 2.0 * cont_tps[4] / GEN
    arrivals_ov = poisson_arrivals(len(over), overload_rps, seed=5)
    sched, done, dt, rejected = drive_hardened(
        engine, over, arrivals_ov, n_slots=4, chunk=CHUNK,
        max_queue=N_REQUESTS // 2,
    )
    c = sched.counters
    n_timeout = c["timed_out"]
    rows.append(
        {
            "name": f"serve/bounded_queue_overload_{overload_rps:.1f}rps",
            "tokens_per_s": round(sum(len(x.new_tokens) for x in done) / dt, 2),
            "makespan_s": round(dt, 3),
            "derived": (
                f"offered={len(over)};max_queue={N_REQUESTS // 2};"
                f"rejected={rejected};shed={c['shed']};timed_out={n_timeout};"
                f"finished={len(done)};ttft_deadline={ttft_deadline}s"
            ),
        }
    )
    print(f"bounded queue @{overload_rps:.1f}rps: {rejected} rejected, "
          f"{c['shed']} shed, {n_timeout} timed out, {len(done)} finished "
          f"in {dt:.2f}s")
    pct_row("serve/latency_bounded_queue_overload", sched)
    assert rejected + c["shed"] + n_timeout + len(done) == len(over), (
        "lifecycle leak: every offered request must be rejected, shed, "
        "timed out or finished"
    )

    prefix_bench(rows)

    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
        f.write("\n")
    print("wrote", out)

    obs_bench(cfg, engine, args.obs_out)


if __name__ == "__main__":
    main()
