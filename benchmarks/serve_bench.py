"""Serving-throughput benchmark: continuous batching vs one-shot generate.

Measures the ISSUE 2 acceptance number: at >=4 concurrent requests the
continuous-batching scheduler must sustain higher tokens/sec than serving the
same workload as sequential one-shot scanned ``Engine.generate`` calls (the
PR 1 fast path). Two arrival regimes:

- ``burst``  — all requests queued at t=0 (pure throughput / makespan);
- ``poisson``— Poisson arrivals at ~2x the sequential service rate, the
  regime the paper's serving workload (§V, OPT token generation) lives in:
  the queue stays non-empty, so the win is batch-feeding, not queueing tricks.

Both paths are warmed first so XLA compiles (per prompt-length/budget shape)
stay out of the timings. CPU-host numbers are functional sanity, not TPU
claims (benchmarks/common.py).

PYTHONPATH=src python benchmarks/serve_bench.py [--out BENCH_serve.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.infer import Engine, Scheduler
from repro.launch.serve import (
    build_requests,
    drive_continuous,
    drive_sequential,
    poisson_arrivals,
)
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params

N_REQUESTS = 12
PROMPT_LEN = 16
GEN = 24
SLOTS = (4, 8)
CHUNK = 8


def _engine():
    cfg = reduced(get_config("llama3.2-3b"), d_model=256, n_kv_heads=4, d_ff=512)
    params = quantize_params(
        init_params(jax.random.PRNGKey(0), cfg), QuantPolicy(q=4, g=128, iters=4)
    )
    return cfg, Engine(cfg, params, max_seq=PROMPT_LEN + GEN + 8)


def _warmup(cfg, engine):
    """Compile every shape both paths will hit: the (PROMPT_LEN, GEN) scan
    generate, the batch-1 prefill, the admit install, and one decode chunk
    per slot width."""
    reqs = build_requests(cfg, 2, PROMPT_LEN, GEN)
    engine.generate(reqs[0].prompt[None], GEN, temperature=1.0, seed=0)
    engine.generate(reqs[0].prompt[None], GEN, temperature=0.0, seed=0)
    for n_slots in SLOTS:
        sched = Scheduler(engine, n_slots=n_slots, chunk=CHUNK)
        for r in reqs:
            sched.submit(r)
        sched.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json"),
    )
    args = ap.parse_args()

    cfg, engine = _engine()
    t0 = time.perf_counter()
    _warmup(cfg, engine)
    print(f"warmup (compiles): {time.perf_counter() - t0:.1f}s")

    reqs = build_requests(cfg, N_REQUESTS, PROMPT_LEN, GEN)
    total_new = sum(r.max_new_tokens for r in reqs)
    rows = []

    def record(name, makespan, extra=""):
        tps = total_new / makespan
        rows.append(
            {
                "name": name,
                "tokens_per_s": round(tps, 2),
                "makespan_s": round(makespan, 3),
                "derived": f"requests={N_REQUESTS};prompt={PROMPT_LEN};gen={GEN};"
                f"q=4;g=128{extra}",
            }
        )
        print(f"{name}: {tps:.1f} tok/s (makespan {makespan:.2f}s)")
        return tps

    # -- burst regime: everything queued at t=0 ------------------------------
    zeros = np.zeros(N_REQUESTS)
    _, seq_dt = drive_sequential(engine, reqs, zeros)
    seq_tps = record("serve/sequential_oneshot/burst", seq_dt)

    cont_tps = {}
    for n_slots in SLOTS:
        sched, done, dt = drive_continuous(
            engine, reqs, zeros, n_slots=n_slots, chunk=CHUNK
        )
        util = sched.steps_active / max(1, sched.decode_steps * sched.n_slots)
        cont_tps[n_slots] = record(
            f"serve/continuous_slots{n_slots}/burst", dt,
            extra=f";chunk={CHUNK};slot_util={util:.2f}",
        )

    # -- poisson regime: arrivals at ~2x the sequential service rate ---------
    rate = 2.0 * N_REQUESTS / seq_dt
    arrivals = poisson_arrivals(N_REQUESTS, rate, seed=1)
    _, seq_p_dt = drive_sequential(engine, reqs, arrivals)
    record(f"serve/sequential_oneshot/poisson_{rate:.1f}rps", seq_p_dt)
    sched, done, dt = drive_continuous(
        engine, reqs, arrivals, n_slots=4, chunk=CHUNK
    )
    util = sched.steps_active / max(1, sched.decode_steps * sched.n_slots)
    record(
        f"serve/continuous_slots4/poisson_{rate:.1f}rps", dt,
        extra=f";chunk={CHUNK};slot_util={util:.2f}",
    )

    speedup = cont_tps[4] / seq_tps
    rows.append(
        {
            "name": "serve/speedup_continuous4_vs_sequential/burst",
            "tokens_per_s": None,
            "makespan_s": None,
            "derived": f"speedup={speedup:.2f}x",
        }
    )
    print(f"continuous(4 slots) vs sequential: {speedup:.2f}x")
    assert speedup > 1.0, (
        "acceptance: continuous batching must beat sequential one-shot "
        f"generate at >=4 slots (got {speedup:.2f}x)"
    )

    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
        f.write("\n")
    print("wrote", out)


if __name__ == "__main__":
    main()
