"""Fault tolerance: atomic checkpoints, resume, retention, async, preemption."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C
from repro.train.loop import LoopConfig, PreemptionGuard, StragglerDetector, train_loop


def _state(x=1.0):
    return {
        "params": {"w": jnp.full((4, 4), x, jnp.float32), "b": jnp.zeros((4,), jnp.bfloat16)},
        "opt": {"step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    s = _state(3.5)
    C.save(str(tmp_path), 10, s)
    out = C.restore(str(tmp_path), 10, jax.tree.map(jnp.zeros_like, s))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_and_retention(tmp_path):
    for step in (1, 2, 3, 4, 5):
        C.save(str(tmp_path), step, _state(step), keep=3)
    assert C.latest_step(str(tmp_path)) == 5
    assert sorted(C.all_steps(str(tmp_path))) == [3, 4, 5]


def test_atomic_no_partial_checkpoints(tmp_path):
    C.save(str(tmp_path), 1, _state())
    # a leftover tmp dir from a crashed writer must be invisible
    os.makedirs(tmp_path / "tmp.99")
    assert C.latest_step(str(tmp_path)) == 1
    # a step dir without manifest (partial copy) is ignored
    os.makedirs(tmp_path / "step_50")
    assert C.latest_step(str(tmp_path)) == 1


def test_restore_shape_mismatch_raises(tmp_path):
    C.save(str(tmp_path), 1, _state())
    bad = {"params": {"w": jnp.zeros((2, 2)), "b": jnp.zeros((4,), jnp.bfloat16)},
           "opt": {"step": jnp.int32(0)}}
    with pytest.raises(ValueError):
        C.restore(str(tmp_path), 1, bad)


def test_async_checkpointer(tmp_path):
    ck = C.AsyncCheckpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3):
        ck.submit(step, _state(step))
    ck.close()
    assert C.latest_step(str(tmp_path)) == 3


def _fake_step(params, opt, batch):
    params = jax.tree.map(lambda p: p + 1, params)
    return params, opt, {"loss": jnp.float32(1.0)}


def _batches():
    while True:
        yield {}


def test_train_loop_resume(tmp_path):
    params, opt = {"w": jnp.zeros(())}, {"m": jnp.zeros(())}
    cfg = LoopConfig(total_steps=5, ckpt_dir=str(tmp_path), ckpt_every=2, log_every=100)
    p1, _, _ = train_loop(_fake_step, params, opt, _batches(), cfg, log=lambda s: None)
    assert float(p1["w"]) == 5
    # resume: checkpoint at step 5 exists → no more steps run
    p2, _, _ = train_loop(_fake_step, params, opt, _batches(), cfg, log=lambda s: None)
    assert float(p2["w"]) == 5
    # extend: resumes from 5 and runs 3 more
    cfg2 = LoopConfig(total_steps=8, ckpt_dir=str(tmp_path), ckpt_every=2, log_every=100)
    p3, _, _ = train_loop(_fake_step, params, opt, _batches(), cfg2, log=lambda s: None)
    assert float(p3["w"]) == 8


def test_preemption_checkpoints_and_exits(tmp_path):
    params, opt = {"w": jnp.zeros(())}, {"m": jnp.zeros(())}
    guard = PreemptionGuard(install=False)

    calls = {"n": 0}

    def step(p, o, b):
        calls["n"] += 1
        if calls["n"] == 3:
            guard.requested = True  # simulated SIGTERM mid-run
        return _fake_step(p, o, b)

    cfg = LoopConfig(total_steps=100, ckpt_dir=str(tmp_path), ckpt_every=1000)
    p, _, _ = train_loop(step, params, opt, _batches(), cfg, log=lambda s: None,
                         guard=guard)
    assert calls["n"] == 3  # stopped promptly
    assert C.latest_step(str(tmp_path)) == 3  # final checkpoint written


def test_straggler_detector():
    d = StragglerDetector(factor=3.0, warmup=2)
    for _ in range(5):
        assert not d.observe(0.1)
    assert d.observe(1.0)  # 10x EMA → anomaly
    assert d.anomalies == 1
    assert not d.observe(0.1)  # recovers


def test_elastic_restore_resharding(tmp_path):
    """Checkpoints store full logical arrays → restore onto a different
    sharding/layout (here: a 1-device mesh) works leaf-by-leaf."""
    s = _state(2.0)
    C.save(str(tmp_path), 4, s)
    mesh = jax.make_mesh((1,), ("data",))
    shd = jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=shd)
        if x.ndim >= 1 else x,
        s,
    )
    out = C.restore(str(tmp_path), 4, template)
    np.testing.assert_allclose(np.asarray(out["params"]["w"]), 2.0)
    assert out["params"]["w"].sharding == shd
