"""Unit + property tests for the group-wise BCQ quantizer (paper §III.A)."""

import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional test extra (see pyproject [test]); the property
# tests below importorskip it per-test so the rest of the module always runs
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

from repro.core import (
    bcq_error,
    compression_ratio,
    dequantize,
    pack_signs,
    quantize_bcq,
    quantize_bcq_greedy,
    unpack_signs,
)


def _w(rng, k=128, o=32):
    return jnp.asarray(rng.standard_normal((k, o)), jnp.float32)


def test_shapes(rng):
    w = _w(rng, 128, 32)
    scales, binary = quantize_bcq_greedy(w, q=3, g=16)
    assert scales.shape == (3, 8, 32)
    assert binary.shape == (3, 128, 32)
    assert set(np.unique(np.asarray(binary))) <= {-1, 1}


def test_q1_rowwise_is_optimal_sign_scale(rng):
    """q=1 greedy = sign(w)·mean|w| per group — the analytic optimum."""
    w = _w(rng, 64, 8)
    scales, binary = quantize_bcq_greedy(w, q=1, g=64)
    np.testing.assert_allclose(
        np.asarray(binary[0]), np.sign(np.asarray(w)), rtol=0, atol=0
    )
    np.testing.assert_allclose(
        np.asarray(scales[0, 0]), np.abs(np.asarray(w)).mean(0), rtol=1e-5
    )


def test_exact_recovery_of_bcq_representable(rng):
    """A matrix that IS a 1-bit code times a scale quantizes losslessly."""
    signs = jnp.asarray(np.sign(rng.standard_normal((64, 16))), jnp.float32)
    w = 0.37 * signs
    scales, binary = quantize_bcq_greedy(w, q=1, g=8)
    assert float(bcq_error(w, scales, binary, 8)) < 1e-6


def test_error_decreases_with_q(rng):
    w = _w(rng)
    errs = []
    for q in (1, 2, 3, 4):
        s, b = quantize_bcq(w, q=q, g=32, iters=5)
        errs.append(float(bcq_error(w, s, b, 32)))
    assert all(e1 > e2 for e1, e2 in zip(errs, errs[1:])), errs


def test_error_decreases_with_smaller_g(rng):
    """Paper §III.A(b): smaller group size → lower quantization error."""
    w = _w(rng)
    errs = []
    for g in (128, 32, 8):
        s, b = quantize_bcq_greedy(w, q=2, g=g)
        errs.append(float(bcq_error(w, s, b, g)))
    assert errs[0] > errs[1] > errs[2], errs


def test_alternating_beats_greedy(rng):
    w = _w(rng)
    sg, bg = quantize_bcq_greedy(w, q=3, g=32)
    sa, ba = quantize_bcq(w, q=3, g=32, iters=8)
    assert float(bcq_error(w, sa, ba, 32)) <= float(bcq_error(w, sg, bg, 32)) + 1e-6


def test_gaussian_q1_error_matches_theory(rng):
    """Row-wise 1-bit error on N(0,1) is sqrt(1 - 2/pi) ≈ 0.6028."""
    w = jnp.asarray(rng.standard_normal((4096, 64)), jnp.float32)
    s, b = quantize_bcq_greedy(w, q=1, g=4096)
    err = float(bcq_error(w, s, b, 4096))
    assert abs(err - np.sqrt(1 - 2 / np.pi)) < 0.01


def test_bad_args(rng):
    w = _w(rng)
    with pytest.raises(ValueError):
        quantize_bcq_greedy(w, q=0, g=32)
    with pytest.raises(ValueError):
        quantize_bcq_greedy(w, q=2, g=4)  # g < 8
    with pytest.raises(ValueError):
        quantize_bcq_greedy(w, q=2, g=48)  # g does not divide k


# property bodies shared by the hypothesis sweep and the deterministic
# fallback (minimal installs), so the two branches cannot drift


def _check_pack_unpack_roundtrip(kc, o, q, seed):
    r = np.random.default_rng(seed)
    binary = jnp.asarray(r.choice([-1, 1], size=(q, kc * 8, o)), jnp.int8)
    assert (unpack_signs(pack_signs(binary)) == binary).all()


def _check_reconstruction_error_bounded(g_exp, q, seed):
    """Property: relative error is always in [0, 1] and greedy error shrinks
    monotonically in q for the SAME matrix (residual property)."""
    r = np.random.default_rng(seed)
    g = 2**g_exp
    w = jnp.asarray(r.standard_normal((128, 16)), jnp.float32)
    s, b = quantize_bcq_greedy(w, q=q, g=g)
    err = float(bcq_error(w, s, b, g))
    assert 0.0 <= err <= 1.0 + 1e-6
    if q > 1:
        s2, b2 = quantize_bcq_greedy(w, q=q - 1, g=g)
        assert err <= float(bcq_error(w, s2, b2, g)) + 1e-6


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        kc=st.integers(1, 8),
        o=st.integers(1, 40),
        q=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_pack_unpack_roundtrip(kc, o, q, seed):
        _check_pack_unpack_roundtrip(kc, o, q, seed)

    @settings(max_examples=15, deadline=None)
    @given(
        g_exp=st.integers(3, 6),
        q=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_dequantize_reconstruction_error_bounded(g_exp, q, seed):
        _check_reconstruction_error_bounded(g_exp, q, seed)

else:

    @pytest.mark.parametrize("kc,o,q,seed", [(1, 1, 1, 0), (4, 17, 3, 1), (8, 40, 4, 2)])
    def test_pack_unpack_roundtrip(kc, o, q, seed):
        _check_pack_unpack_roundtrip(kc, o, q, seed)

    @pytest.mark.parametrize("g_exp,q,seed", [(3, 1, 0), (4, 2, 1), (6, 4, 2)])
    def test_dequantize_reconstruction_error_bounded(g_exp, q, seed):
        _check_reconstruction_error_bounded(g_exp, q, seed)


def test_compression_ratio_eq3():
    # paper Eq. (3): q bits + scale_bits/g per weight
    assert compression_ratio(4, 128, base_bits=32, scale_bits=32) == pytest.approx(
        32 / (4 * (1 + 32 / 128))
    )
    # row-wise large-g limit → base/q
    assert compression_ratio(2, 10**9, base_bits=16, scale_bits=16) == pytest.approx(
        8.0, rel=1e-6
    )


def test_dequantize_leading_dims(rng):
    w = _w(rng, 64, 16)
    s, b = quantize_bcq_greedy(w, q=2, g=16)
    stacked_s = jnp.stack([s, s])
    stacked_b = jnp.stack([b, b])
    out = dequantize(stacked_s, stacked_b, 16)
    assert out.shape == (2, 64, 16)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]))
