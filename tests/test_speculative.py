"""Self-speculative decoding (ISSUE 3 tentpole): nested BCQ truncation
properties, the greedy exactness invariant (speculative output token-identical
to plain decode for dense/BCQ/ring-window/recurrent configs), distribution
preservation under temperature sampling, cache rollback, and the speculative
continuous-batching scheduler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import quantize_tensor
from repro.core.qtensor import QuantizedTensor
from repro.data import MarkovCorpus
from repro.infer import Engine, Request, Scheduler, SpecConfig
from repro.infer import speculative as S
from repro.models import forward, init_cache, init_params, reduced
from repro.models import layers as L
from repro.quant import QuantPolicy, quantize_params, truncate_params

KEY = jax.random.PRNGKey(0)


def _quantizable(arch, **overrides):
    """A reduced config whose linears clear the quantizer's 128-dim floor."""
    base = dict(d_model=128, d_ff=256, vocab=512, n_kv_heads=2)
    base.update(overrides)
    return reduced(get_config(arch), **base)


# ---------------------------------------------------------------------------
# QuantizedTensor.truncate / truncate_params properties
# ---------------------------------------------------------------------------


def test_truncate_matches_greedy_prefix(rng):
    """The nested property: truncate(q') of a greedy q-bit solve is
    bit-identical to the greedy solver's own q'-bit output."""
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    full = quantize_tensor(w, q=4, g=64, method="greedy")
    for q_new in (1, 2, 3):
        nested = full.truncate(q_new)
        solo = quantize_tensor(w, q=q_new, g=64, method="greedy")
        np.testing.assert_array_equal(
            np.asarray(nested.packed), np.asarray(solo.packed)
        )
        np.testing.assert_array_equal(
            np.asarray(nested.scales), np.asarray(solo.scales)
        )
        assert (nested.q, nested.k, nested.o, nested.g) == (q_new, 256, 128, 64)


def test_truncate_error_monotone(rng):
    """Greedy planes are successive residual refinements: reconstruction
    error is monotone non-increasing in q'."""
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    full = quantize_tensor(w, q=6, g=64, method="greedy", scale_dtype=jnp.float32)
    errs = [
        float(jnp.linalg.norm(full.truncate(qn).dequantize() - w))
        for qn in range(1, 7)
    ]
    for lo, hi in zip(errs[1:], errs[:-1]):
        assert lo <= hi + 1e-6, f"error not monotone: {errs}"


def test_truncate_validation(rng):
    w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    qt = quantize_tensor(w, q=3, g=32, method="greedy")
    assert qt.truncate(3) is qt
    with pytest.raises(ValueError):
        qt.truncate(0)
    with pytest.raises(ValueError):
        qt.truncate(4)


def test_truncate_params_shares_unquantized():
    """truncate_params slices every QuantizedTensor leaf and shares all other
    leaves (norms/embeddings/dense linears) by reference."""
    cfg = _quantizable("llama3.2-3b")
    params = quantize_params(
        init_params(KEY, cfg), QuantPolicy(q=4, g=64, method="greedy")
    )
    draft = truncate_params(params, 2)
    is_qt = lambda x: isinstance(x, QuantizedTensor)
    full_leaves = jax.tree.leaves(params, is_leaf=is_qt)
    draft_leaves = jax.tree.leaves(draft, is_leaf=is_qt)
    n_qt = 0
    for f, d in zip(full_leaves, draft_leaves):
        if is_qt(f):
            n_qt += 1
            assert f.q == 4 and d.q == 2
            assert (d.k, d.o, d.g) == (f.k, f.o, f.g)
        else:
            assert d is f  # shared, not copied
    assert n_qt > 0
    # q_draft beyond a leaf's q clamps to the leaf's q
    same = truncate_params(params, 9)
    for f, d in zip(full_leaves, jax.tree.leaves(same, is_leaf=is_qt)):
        if is_qt(f):
            assert d.q == f.q


# ---------------------------------------------------------------------------
# greedy exactness invariant: speculative == plain, per family
# ---------------------------------------------------------------------------


def _prompts(cfg, b, plen, seed=7):
    c = MarkovCorpus(cfg.vocab, seed=3)
    return c.sample(b, plen, seed=seed).astype(np.int32)[:, :plen]


@pytest.mark.parametrize("quantized", [False, True], ids=["dense", "bcq_q4"])
def test_spec_greedy_identical_llama(quantized):
    """The big invariant on the attention family, batch>1: speculative greedy
    output is token-identical to plain greedy scanned decode. The quantized
    case uses a REAL nested draft (q'=2 of 4), so acceptance < 100% and the
    correction path is exercised."""
    cfg = _quantizable("llama3.2-3b")
    params = init_params(KEY, cfg)
    if quantized:
        params = quantize_params(params, QuantPolicy(q=4, g=64, method="greedy"))
    eng = Engine(cfg, params, max_seq=64)
    prompts = _prompts(cfg, 2, 8)
    plain = eng.generate(prompts, 16)
    spec = eng.generate(prompts, 16, speculate=SpecConfig(q_draft=2, gamma=4))
    np.testing.assert_array_equal(plain.tokens, spec.tokens)
    st = spec.spec_stats
    assert st["proposed"] > 0 and 0.0 <= st["accept_rate"] <= 1.0
    if not quantized:
        # dense draft IS the target: every proposal must be accepted
        assert st["accept_rate"] == 1.0


@pytest.mark.parametrize(
    "arch", ["recurrentgemma-9b", "xlstm-125m"],
    ids=["ring_window+rglru", "mlstm+slstm"],
)
def test_spec_greedy_identical_recurrent_and_window(arch):
    """Exactness through recurrent-state snapshots and ring-buffer restore.
    The hybrid config's window (16) is smaller than the decoded length, so
    the ring genuinely wraps and rejected writes clobber live entries —
    the rollback contract's hard case."""
    cfg = reduced(get_config(arch))
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=48)
    prompts = _prompts(cfg, 2, 6)
    plain = eng.generate(prompts, 20)
    spec = eng.generate(prompts, 20, speculate=SpecConfig(q_draft=1, gamma=3))
    np.testing.assert_array_equal(plain.tokens, spec.tokens)


def test_spec_greedy_identical_low_acceptance():
    """q'=1 of a random-weight quantized model: acceptance near zero, so
    nearly every token comes from the correction path — still exact."""
    cfg = _quantizable("llama3.2-3b")
    params = quantize_params(
        init_params(KEY, cfg), QuantPolicy(q=4, g=64, iters=2)
    )
    eng = Engine(cfg, params, max_seq=64)
    prompts = _prompts(cfg, 1, 8)
    plain = eng.generate(prompts, 16)
    spec = eng.generate(prompts, 16, speculate=SpecConfig(q_draft=1, gamma=4))
    np.testing.assert_array_equal(plain.tokens, spec.tokens)
    assert spec.spec_stats["accept_rate"] < 0.9  # the draft really is worse


# ---------------------------------------------------------------------------
# temperature sampling preserves the target distribution
# ---------------------------------------------------------------------------


def test_spec_sampling_preserves_target_distribution():
    """Rejection sampling invariant on a toy vocab: the marginal of the token
    emitted AFTER the first speculative chunk matches plain sampling. Rows
    are iid samples (per-row PRNG streams over identical prompts), so one
    wide batch gives the statistics in two dispatches."""
    cfg = _quantizable("llama3.2-3b", vocab=16, d_model=128, d_ff=256)
    params = quantize_params(
        init_params(KEY, cfg), QuantPolicy(q=4, g=64, iters=2)
    )
    eng = Engine(cfg, params, max_seq=32)
    n = 1024
    prompts = np.tile(_prompts(cfg, 1, 6), (n, 1))
    # token index 1 of the generation = first token decided by draft/verify/
    # accept (index 0 comes directly from the prefill logits in both paths)
    plain = eng.generate(prompts, 2, temperature=1.0, seed=5)
    spec = eng.generate(
        prompts, 2, temperature=1.0, seed=5,
        speculate=SpecConfig(q_draft=1, gamma=2),
    )
    assert 0.0 < spec.spec_stats["accept_rate"] < 1.0  # both accept AND reject
    p_hist = np.bincount(plain.tokens[:, 7], minlength=cfg.vocab) / n
    s_hist = np.bincount(spec.tokens[:, 7], minlength=cfg.vocab) / n
    tv = 0.5 * np.abs(p_hist - s_hist).sum()
    assert tv < 0.10, f"total variation {tv:.3f} too large for n={n}"


# ---------------------------------------------------------------------------
# cache rollback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["llama3.2-3b", "recurrentgemma-9b"], ids=["dense", "ring+rglru"]
)
def test_rejected_chunk_leaves_no_trace(arch):
    """Rollback unit test: decode a chunk of junk tokens through the chunked
    verify path, rewind it completely, and the next real decode step must
    produce logits identical to never having decoded the junk."""
    cfg = reduced(get_config(arch))
    params = init_params(KEY, cfg)
    prompts = _prompts(cfg, 2, 20)  # > window(16): hybrid ring already wrapped
    b, s = prompts.shape
    gamma = 3
    collect = S.has_recurrent_state(cfg)
    cache0 = init_cache(cfg, b, 40)
    _, cache0, _ = forward(
        cfg, params, tokens=jnp.asarray(prompts), cache=cache0,
        pos=jnp.int32(0), logits_mode="last",
    )
    pos = jnp.full((b,), s, jnp.int32)

    junk = jnp.asarray([[3, 5, 7, 11], [13, 2, 4, 8]], jnp.int32)
    snap = S.snapshot_rows(cache0, pos, gamma + 1)
    _, vcache, _ = forward(
        cfg, params, tokens=junk, cache=cache0, pos=pos, logits_mode="all",
        chunked_decode=True, collect_states=collect,
    )
    # full rejection: keep zero rows, recurrent state back to the chunk start
    restored = S.restore_rows(vcache, snap, pos, gamma + 1, jnp.zeros((b,), jnp.int32))
    restored = jax.tree_util.tree_map_with_path(
        lambda p, leaf, orig: (
            orig if S._leaf_name(p) in L.RECURRENT_CACHE_LEAVES else leaf
        ),
        restored, cache0,
    )

    tok = _prompts(cfg, b, s + 1, seed=9)[:, -1:]
    ref_logits, _, _ = forward(
        cfg, params, tokens=jnp.asarray(tok), cache=cache0, pos=pos,
        logits_mode="last",
    )
    got_logits, _, _ = forward(
        cfg, params, tokens=jnp.asarray(tok), cache=restored, pos=pos,
        logits_mode="last",
    )
    np.testing.assert_array_equal(np.asarray(ref_logits), np.asarray(got_logits))


def test_chunked_decode_matches_step_decode():
    """The verify forward itself: feeding s tokens chunked against a filled
    cache computes the same logits as s single-token decode steps."""
    for arch in ("llama3.2-3b", "recurrentgemma-9b", "xlstm-125m"):
        cfg = reduced(get_config(arch))
        params = init_params(KEY, cfg)
        toks = _prompts(cfg, 2, 26)
        b = 2
        prompt, rest = toks[:, :20], toks[:, 20:]
        cache = init_cache(cfg, b, 40)
        logits, cache, _ = forward(
            cfg, params, tokens=jnp.asarray(prompt), cache=cache,
            pos=jnp.int32(0), logits_mode="last",
        )
        step_cache = cache
        step_logits = []
        for t in range(rest.shape[1]):
            lg, step_cache, _ = forward(
                cfg, params, tokens=jnp.asarray(rest[:, t : t + 1]),
                cache=step_cache, pos=jnp.int32(20 + t), logits_mode="last",
            )
            step_logits.append(np.asarray(lg[:, 0]))
        chunk_logits, _, _ = forward(
            cfg, params, tokens=jnp.asarray(rest), cache=cache,
            pos=jnp.full((b,), 20, jnp.int32), logits_mode="all",
            chunked_decode=True,
        )
        np.testing.assert_allclose(
            np.asarray(chunk_logits), np.stack(step_logits, axis=1),
            rtol=2e-5, atol=2e-5,
            err_msg=f"{arch}: chunked decode diverged from step decode",
        )


# ---------------------------------------------------------------------------
# speculative continuous batching
# ---------------------------------------------------------------------------


def test_spec_scheduler_token_identical():
    """Speculative slots: greedy rows and per-request opt-outs (including a
    SAMPLED opt-out, whose PRNG stream must match plain decode bit-for-bit)
    are token-identical to solo plain generate; all budgets exact."""
    cfg = _quantizable("llama3.2-3b")
    params = quantize_params(
        init_params(KEY, cfg), QuantPolicy(q=4, g=64, method="greedy")
    )
    eng = Engine(cfg, params, max_seq=64)
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    rng = np.random.default_rng(0)
    reqs = []
    for i, (temp, spec_in) in enumerate(
        [(0.0, True), (1.0, False), (0.0, True), (0.7, False), (0.0, False), (1.0, True)]
    ):
        plen = int(rng.integers(4, 10))
        reqs.append(
            Request(
                prompt=corpus.sample(1, plen, seed=100 + i)[0, :plen].astype(np.int32),
                max_new_tokens=int(rng.integers(3, 12)),
                temperature=temp,
                seed=10 + i,
                speculate=spec_in,
            )
        )

    sched = Scheduler(eng, n_slots=3, chunk=2, speculate=SpecConfig(q_draft=2, gamma=3))
    for r in reqs:
        sched.submit(r)
    done = {c.rid: c for c in sched.run()}
    assert len(done) == len(reqs)
    for r in reqs:
        assert done[r.rid].new_tokens.shape == (r.max_new_tokens,)
        if r.temperature == 0.0 or r.speculate is False:
            solo = eng.generate(
                r.prompt[None], r.max_new_tokens,
                temperature=r.temperature, seed=r.seed,
            )
            np.testing.assert_array_equal(
                solo.tokens[0, r.prompt.size :], done[r.rid].new_tokens,
                err_msg=f"request {r.rid} diverged from solo plain generate",
            )


def test_spec_scheduler_budget_one_completes_at_admission():
    """In spec mode the first token is emitted at admission: a budget-1
    request must complete immediately and free its slot for the same round."""
    cfg = _quantizable("llama3.2-3b")
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=48)
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    sched = Scheduler(eng, n_slots=1, chunk=2, speculate=SpecConfig(2, 2))
    p = corpus.sample(1, 5, seed=1)[0, :5].astype(np.int32)
    a = sched.submit(Request(prompt=p, max_new_tokens=1))
    b = sched.submit(Request(prompt=p, max_new_tokens=4))
    done = {c.rid: c for c in sched.run()}
    assert done[a].new_tokens.shape == (1,)
    assert done[b].new_tokens.shape == (4,)
    solo = eng.generate(p[None], 4)
    np.testing.assert_array_equal(solo.tokens[0, 5:6], done[a].new_tokens)
    np.testing.assert_array_equal(solo.tokens[0, 5:], done[b].new_tokens)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_spec_validation():
    with pytest.raises(ValueError):
        SpecConfig(q_draft=0, gamma=4)
    with pytest.raises(ValueError):
        SpecConfig(q_draft=2, gamma=0)
    assert SpecConfig.parse("2:4") == SpecConfig(q_draft=2, gamma=4)
    with pytest.raises(ValueError):
        SpecConfig.parse("nope")

    # MoE: shared expert capacity couples the verified chunk — rejected
    cfg = reduced(get_config("olmoe-1b-7b"))
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=48)
    prompts = _prompts(cfg, 1, 6)
    with pytest.raises(ValueError):
        eng.generate(prompts, 4, speculate=SpecConfig(2, 2))

    # gamma must fit inside the ring window
    cfg = reduced(get_config("recurrentgemma-9b"))  # window 16
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=48)
    prompts = _prompts(cfg, 1, 6)
    with pytest.raises(ValueError):
        eng.generate(prompts, 4, speculate=SpecConfig(q_draft=1, gamma=15))

    # cache headroom: prompt + n_steps + gamma must fit
    cfg = reduced(get_config("llama3.2-3b"))
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=16)
    with pytest.raises(ValueError):
        eng.generate(_prompts(cfg, 1, 8), 8, speculate=SpecConfig(2, 4))
