"""Async streaming serve front end (launch/server.py, DESIGN.md §9).

The ServeSession core is transport-agnostic and is exercised directly with
asyncio (no sockets): submit/stream/drain, mid-flight cancellation, loud
queue-full rejection, invalid-request rejection, and the bounded-buffer
slow-client policy. One end-to-end WebSocket smoke test (ephemeral port)
covers the aiohttp transport — submit frame, streamed token frames, cancel
frame, disconnect-as-cancel, and the metrics endpoint — and skips cleanly
when aiohttp is absent (the minimal CI leg)."""

import asyncio
import functools
import gc

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MarkovCorpus
from repro.infer import Engine, FaultPlan, Request, RequestState
from repro.launch.server import ServeSession, StreamEvent, request_from_json
from repro.models import init_params, reduced

KEY = jax.random.PRNGKey(0)
MAX_SEQ = 64


@pytest.fixture(scope="module", autouse=True)
def _release_module_state():
    """Same rationale as test_prefix_cache: drop the module's pinned engines
    and compiled executables at teardown so accumulated JIT state can't
    destabilise XLA's compiler later in the serial suite."""
    yield
    _engine.cache_clear()
    jax.clear_caches()
    gc.collect()


def _cfg():
    return reduced(get_config("llama3.2-3b"), d_model=128, n_kv_heads=4, d_ff=256)


@functools.lru_cache(maxsize=None)
def _engine() -> Engine:
    return Engine(_cfg(), init_params(KEY, _cfg()), max_seq=MAX_SEQ)


def _prompt(i: int = 0, plen: int = 5) -> np.ndarray:
    corpus = MarkovCorpus(_cfg().vocab, seed=3)
    return corpus.sample(1, plen, seed=200 + i)[0, :plen].astype(np.int32)


def _go(coro, timeout=120.0):
    return asyncio.run(asyncio.wait_for(coro, timeout))


async def _await_true(predicate, *, timeout=30.0, every=0.005):
    waited = 0.0
    while not predicate():
        await asyncio.sleep(every)
        waited += every
        if waited > timeout:
            raise AssertionError("condition not reached in time")


# ---------------------------------------------------------------------------
# session core (no sockets)
# ---------------------------------------------------------------------------


def test_session_streams_match_solo_generate():
    eng = _engine()
    p0, p1 = _prompt(0), _prompt(1, plen=6)
    solo0 = eng.generate(p0[None], 8)
    solo1 = eng.generate(p1[None], 8, temperature=0.8, seed=7)

    async def run():
        async with ServeSession(eng, n_slots=2, chunk=3) as sess:
            s0 = await sess.submit_stream(Request(prompt=p0, max_new_tokens=8))
            s1 = await sess.submit_stream(
                Request(prompt=p1, max_new_tokens=8, temperature=0.8, seed=7)
            )
            (t0, last0), (t1, last1) = await asyncio.gather(
                s0.drain(), s1.drain()
            )
            m = sess.metrics()
        return t0, last0, t1, last1, m

    t0, last0, t1, last1, m = _go(run())
    assert last0.kind == "done" and last0.status == "finished"
    assert last1.kind == "done" and last1.n_tokens == 8
    np.testing.assert_array_equal(np.asarray(t0), solo0.tokens[0, p0.size :])
    np.testing.assert_array_equal(np.asarray(t1), solo1.tokens[0, p1.size :])
    assert m["by_state"] == {"finished": 2}
    assert m["ttft_s"]["n"] == 2
    assert m["server"] == {"overflow_cancelled": 0, "rejected": 0}


def test_session_cancel_midflight_survivor_exact():
    eng = _engine()
    p0, p1 = _prompt(2), _prompt(3)
    solo1 = eng.generate(p1[None], 10)

    async def run():
        async with ServeSession(eng, n_slots=2, chunk=2) as sess:
            victim = await sess.submit_stream(
                Request(prompt=p0, max_new_tokens=24)
            )
            survivor = await sess.submit_stream(
                Request(prompt=p1, max_new_tokens=10)
            )
            # wait for the victim's first tokens so the cancel is mid-flight
            first = None
            async for ev in victim:
                if ev.kind == "tokens":
                    first = ev
                    break
            victim.cancel("user hit stop")
            _, vlast = await victim.drain()
            stoks, slast = await survivor.drain()
            m = sess.metrics()
        return first, vlast, stoks, slast, m

    first, vlast, stoks, slast, m = _go(run())
    assert first is not None and len(first.tokens) > 0
    assert vlast.kind == "error" and vlast.status == "cancelled"
    assert vlast.reason == "user hit stop"
    assert slast.kind == "done"
    np.testing.assert_array_equal(np.asarray(stoks), solo1.tokens[0, p1.size :])
    assert m["by_state"]["cancelled"] == 1
    assert m["counters"]["cancelled"] == 1


def test_session_queue_full_surfaces_as_rejected_event():
    eng = _engine()

    async def run():
        async with ServeSession(eng, n_slots=1, chunk=2, max_queue=1) as sess:
            streams = [
                await sess.submit_stream(
                    Request(prompt=_prompt(i), max_new_tokens=6)
                )
                for i in range(6)
            ]
            results = await asyncio.gather(*(s.drain() for s in streams))
            m = sess.metrics()
        return results, m

    results, m = _go(run())
    kinds = [last.kind for _, last in results]
    assert kinds.count("rejected") >= 1, kinds
    assert kinds.count("done") >= 1  # whatever was admitted still serves
    rej = next(last for _, last in results if last.kind == "rejected")
    assert "admission queue full" in rej.reason
    assert m["server"]["rejected"] == kinds.count("rejected")
    assert m["counters"]["rejected_queue_full"] == kinds.count("rejected")


def test_session_invalid_request_rejected_not_fatal():
    eng = _engine()

    async def run():
        async with ServeSession(eng, n_slots=1, chunk=2) as sess:
            too_long = await sess.submit_stream(
                Request(prompt=_prompt(0), max_new_tokens=MAX_SEQ * 2)
            )
            _, bad = await too_long.drain()
            ok = await sess.submit_stream(
                Request(prompt=_prompt(1), max_new_tokens=4)
            )
            toks, last = await ok.drain()
        return bad, toks, last

    bad, toks, last = _go(run())
    assert bad.kind == "rejected" and "max_seq" in bad.reason
    assert last.kind == "done" and len(toks) == 4  # the pump survived


def test_session_slow_client_overflow_cancelled():
    eng = _engine()

    async def run():
        async with ServeSession(
            eng, n_slots=1, chunk=1, max_buffer=2
        ) as sess:
            stream = await sess.submit_stream(
                Request(prompt=_prompt(4), max_new_tokens=40)
            )
            # never read: the per-stream buffer (2 events) must overflow and
            # the session must cancel the request instead of buffering 40
            await _await_true(lambda: sess.counters["overflow_cancelled"] >= 1)
            toks, last = await stream.drain()
            m = sess.metrics()
        return toks, last, m

    toks, last, m = _go(run())
    assert last.kind == "error" and last.status == "cancelled"
    assert "slow client" in last.reason and "overflowed" in last.reason
    assert len(toks) <= 2  # only what fit in the bounded buffer
    assert m["by_state"].get("cancelled") == 1


def test_session_stop_cancels_inflight_with_terminal_events():
    eng = _engine()

    async def run():
        sess = ServeSession(eng, n_slots=1, chunk=2)
        async with sess:
            stream = await sess.submit_stream(
                Request(prompt=_prompt(5), max_new_tokens=48)
            )
            async for ev in stream:
                if ev.kind == "tokens":
                    break
        # __aexit__ stopped the pump; in-flight work was cancelled and the
        # stream still got its terminal event (no hanging consumers)
        _, last = await stream.drain()
        return last

    last = _go(run())
    assert last.terminal and last.status == "cancelled"
    assert "shutting down" in last.reason


def test_session_client_stall_fault_still_correct():
    eng = _engine()
    p = _prompt(6)
    solo = eng.generate(p[None], 6)

    async def run():
        plan = FaultPlan(client_stall={0: 0.01})
        async with ServeSession(eng, n_slots=1, chunk=2, faults=plan) as sess:
            stream = await sess.submit_stream(
                Request(prompt=p, max_new_tokens=6, rid=0)
            )
            return await stream.drain()

    toks, last = _go(run())
    assert last.kind == "done"
    np.testing.assert_array_equal(np.asarray(toks), solo.tokens[0, p.size :])


def test_request_from_json_roundtrip():
    req = request_from_json(
        {
            "prompt": [1, 2, 3],
            "max_new_tokens": 5,
            "temperature": 0.5,
            "seed": 9,
            "stop_tokens": [2],
            "deadline_s": 30.0,
        }
    )
    assert req.max_new_tokens == 5 and req.temperature == 0.5
    assert req.stop_tokens == (2,) and req.deadline_s == 30.0
    with pytest.raises(KeyError):
        request_from_json({"max_new_tokens": 5})  # prompt is required
    with pytest.raises(ValueError, match="integer token ids"):
        request_from_json({"prompt": [0.5]})


def test_stream_event_json_shapes():
    done = StreamEvent(kind="done", rid=3, status="finished", n_tokens=7)
    assert done.terminal
    assert done.to_json() == {
        "type": "done", "rid": 3, "status": "finished", "n_tokens": 7,
    }
    toks = StreamEvent(kind="tokens", rid=3, tokens=[1, 2])
    assert not toks.terminal
    assert toks.to_json() == {"type": "tokens", "rid": 3, "tokens": [1, 2]}


# ---------------------------------------------------------------------------
# aiohttp websocket transport (end-to-end over a real socket)
# ---------------------------------------------------------------------------


def test_websocket_end_to_end_stream_cancel_disconnect_metrics():
    aiohttp = pytest.importorskip("aiohttp")
    from repro.launch.server import bound_port, run_server

    eng = _engine()
    p = _prompt(7)
    solo = eng.generate(p[None], 8)
    expect = [int(t) for t in solo.tokens[0, p.size :]]

    async def run():
        session = ServeSession(eng, n_slots=2, chunk=3)
        async with session:
            runner = await run_server(session, port=0)
            port = bound_port(runner)
            base = f"http://127.0.0.1:{port}"
            try:
                async with aiohttp.ClientSession() as client:
                    # 1) health
                    async with client.get(f"{base}/healthz") as r:
                        assert (await r.json()) == {"ok": True}

                    # 2) full stream: submit -> accepted -> tokens -> done
                    async with client.ws_connect(f"{base}/v1/stream") as ws:
                        await ws.send_json(
                            {"prompt": [int(t) for t in p],
                             "max_new_tokens": 8}
                        )
                        got, done_frame = [], None
                        while True:
                            frame = await ws.receive_json()
                            if frame["type"] == "accepted":
                                continue
                            if frame["type"] == "tokens":
                                got.extend(frame["tokens"])
                                continue
                            done_frame = frame
                            break
                    assert done_frame["type"] == "done"
                    assert done_frame["status"] == "finished"
                    assert got == expect

                    # 3) explicit cancel frame mid-flight
                    async with client.ws_connect(f"{base}/v1/stream") as ws:
                        await ws.send_json(
                            {"prompt": [int(t) for t in p],
                             "max_new_tokens": 48}
                        )
                        while True:
                            frame = await ws.receive_json()
                            if frame["type"] == "tokens":
                                break
                        await ws.send_json({"type": "cancel"})
                        while True:
                            frame = await ws.receive_json()
                            if frame["type"] in ("error", "done"):
                                break
                    assert frame["type"] == "error"
                    assert frame["status"] == "cancelled"
                    assert "cancel frame" in frame["reason"]

                    # 4) disconnect-as-cancel: drop the socket mid-flight
                    ws = await client.ws_connect(f"{base}/v1/stream")
                    await ws.send_json(
                        {"prompt": [int(t) for t in p], "max_new_tokens": 48}
                    )
                    while True:
                        frame = await ws.receive_json()
                        if frame["type"] == "tokens":
                            break
                    await ws.close()
                    await _await_true(
                        lambda: session.sched.counters["cancelled"] >= 2
                    )

                    # 5) bad first frame -> rejected, socket closed politely
                    async with client.ws_connect(f"{base}/v1/stream") as ws:
                        await ws.send_json({"max_new_tokens": 4})
                        frame = await ws.receive_json()
                    assert frame["type"] == "rejected"
                    assert "bad request" in frame["reason"]

                    # 6) metrics endpoint
                    async with client.get(f"{base}/v1/metrics") as r:
                        m = await r.json()
            finally:
                await runner.cleanup()
        return m

    m = _go(run(), timeout=180.0)
    assert m["by_state"]["finished"] == 1
    assert m["by_state"]["cancelled"] == 2
    assert m["ttft_s"]["n"] == 1
    assert m["counters"]["cancelled"] == 2


# ---------------------------------------------------------------------------
# HTTP SSE transport (same session core + frame schema as the WS endpoint)
# ---------------------------------------------------------------------------


async def _sse_frames(resp):
    """Parse an SSE body into the JSON frames it carries."""
    import json

    frames = []
    async for line in resp.content:
        line = line.decode("utf-8").strip()
        if line.startswith("data: "):
            frames.append(json.loads(line[len("data: "):]))
    return frames


def test_sse_generate_end_to_end():
    """POST /v1/generate streams the SAME frame schema the WS endpoint uses,
    one frame per ``data:`` line: accepted -> tokens* -> done, token-identical
    to solo generate; a malformed body is a 400 with a rejected frame."""
    aiohttp = pytest.importorskip("aiohttp")
    from repro.launch.server import bound_port, run_server

    eng = _engine()
    p = _prompt(8)
    solo = eng.generate(p[None], 8)
    expect = [int(t) for t in solo.tokens[0, p.size :]]

    async def run():
        session = ServeSession(eng, n_slots=2, chunk=3)
        async with session:
            runner = await run_server(session, port=0)
            base = f"http://127.0.0.1:{bound_port(runner)}"
            try:
                async with aiohttp.ClientSession() as client:
                    body = {"prompt": [int(t) for t in p],
                            "max_new_tokens": 8}
                    async with client.post(
                        f"{base}/v1/generate", json=body
                    ) as r:
                        assert r.status == 200
                        assert r.headers["Content-Type"].startswith(
                            "text/event-stream"
                        )
                        frames = await _sse_frames(r)
                    async with client.post(
                        f"{base}/v1/generate", json={"max_new_tokens": 4}
                    ) as r:
                        bad_status, bad = r.status, await r.json()
            finally:
                await runner.cleanup()
        return frames, bad_status, bad

    frames, bad_status, bad = _go(run(), timeout=180.0)
    assert frames[0]["type"] == "accepted"
    got = [t for f in frames if f["type"] == "tokens" for t in f["tokens"]]
    assert got == expect
    assert frames[-1]["type"] == "done"
    assert frames[-1]["status"] == "finished" and frames[-1]["n_tokens"] == 8
    assert bad_status == 400 and bad["type"] == "rejected"
    assert "bad request" in bad["reason"]


def test_sse_disconnect_cancels_request():
    """Dropping the SSE connection mid-stream cancels the request at the
    next chunk boundary — disconnect-as-cancel, same contract as WS."""
    aiohttp = pytest.importorskip("aiohttp")
    from repro.launch.server import bound_port, run_server

    eng = _engine()
    p = _prompt(9)

    async def run():
        session = ServeSession(eng, n_slots=1, chunk=1)
        async with session:
            runner = await run_server(session, port=0)
            base = f"http://127.0.0.1:{bound_port(runner)}"
            try:
                async with aiohttp.ClientSession() as client:
                    resp = await client.post(
                        f"{base}/v1/generate",
                        json={"prompt": [int(t) for t in p],
                              "max_new_tokens": 48},
                    )
                    # read until the first token frame, then hang up
                    async for line in resp.content:
                        if b'"tokens"' in line:
                            break
                    resp.close()
                    await _await_true(
                        lambda: session.sched.counters["cancelled"] >= 1
                    )
                    m = session.metrics()
            finally:
                await runner.cleanup()
        return m

    m = _go(run(), timeout=180.0)
    assert m["by_state"].get("cancelled") == 1


def test_session_prefix_cache_chunked_prefill_identity():
    """The serving session wires prefill_chunk through to the scheduler and
    the engine's prefix cache serves warm requests bit-identically — the §12
    invariant holds end-to-end through the async front end."""
    from repro.infer import PrefixCache

    eng = Engine(
        _cfg(), init_params(KEY, _cfg()), max_seq=MAX_SEQ,
        prefix_cache=PrefixCache(block_tokens=4),
    )
    p = _prompt(10, plen=12)
    solo = _engine().generate(p[None], 8)
    expect = [int(t) for t in solo.tokens[0, p.size :]]

    async def run():
        async with ServeSession(eng, n_slots=2, chunk=3,
                                prefill_chunk=4) as sess:
            cold = await sess.submit_stream(Request(prompt=p, max_new_tokens=8))
            t_cold, last_cold = await cold.drain()
            warm = await sess.submit_stream(Request(prompt=p, max_new_tokens=8))
            t_warm, last_warm = await warm.drain()
        return t_cold, last_cold, t_warm, last_warm

    t_cold, last_cold, t_warm, last_warm = _go(run())
    assert last_cold.kind == "done" and last_warm.kind == "done"
    assert list(t_cold) == expect and list(t_warm) == expect
    st = eng.prefix_cache.stats()
    assert st["hits"] >= 1 and st["pinned"] == 0
    assert st["hits"] + st["misses"] == st["commits"] + st["aborts"]
