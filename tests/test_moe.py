"""MoE sort-based capacity dispatch vs a dense per-token oracle."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.moe import _capacity, init_moe, moe_apply


def _cfg(e=8, k=2, cf=8.0, shared=False):
    return ModelConfig(
        name="t", family="moe", n_layers=2, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=32, vocab=64, n_experts=e, top_k=k, capacity_factor=cf,
        shared_expert=shared, moe_d_ff=32,
        param_dtype="float32", compute_dtype="float32",
    )


def _dense_oracle(p, cfg, x):
    """Route every token through its top-k experts without capacity."""
    b, s, d = x.shape
    xf = np.asarray(x.reshape(-1, d), np.float64)
    router = np.asarray(p["router"], np.float64)
    wg = np.asarray(p["w_gate"], np.float64)
    wu = np.asarray(p["w_up"], np.float64)
    wd = np.asarray(p["w_down"], np.float64)
    logits = xf @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[: cfg.top_k]
        w = probs[t][top]
        w = w / w.sum()
        for e, wt in zip(top, w):
            g = xf[t] @ wg[e]
            u = xf[t] @ wu[e]
            silu = g / (1 + np.exp(-g))
            out[t] += wt * ((silu * u) @ wd[e])
    return out.reshape(b, s, d)


def test_moe_matches_dense_oracle_at_no_drop():
    cfg = _cfg(e=8, k=2, cf=4.0)  # cap >= T*k/e guaranteed no drops for T=32
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 16)), jnp.float32)
    y, aux = moe_apply(p, cfg, x)
    y_ref = _dense_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_capacity_drops_tokens_gracefully():
    cfg = _cfg(e=8, k=2, cf=0.1)  # tiny capacity → most assignments dropped
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 32, 16)), jnp.float32)
    y, _ = moe_apply(p, cfg, x)
    assert not bool(jnp.isnan(y).any())
    # dropped-token output is strictly smaller in norm than the no-drop one
    cfg2 = _cfg(e=8, k=2, cf=8.0)
    y2, _ = moe_apply(p, cfg2, x)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y2))


def test_capacity_rounding():
    cfg = _cfg(e=8, k=2, cf=1.25)
    c = _capacity(cfg, 64)
    assert c % 8 == 0 and c >= 1.25 * 64 * 2 / 8


def test_shared_expert_added():
    cfg_s = _cfg(shared=True)
    p = init_moe(jax.random.PRNGKey(0), cfg_s)
    assert "shared" in p
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1, 8, 16)), jnp.float32)
    y, _ = moe_apply(p, cfg_s, x)
    assert y.shape == x.shape


def test_quantized_experts():
    from repro.quant import QuantPolicy, quantize_params

    cfg = _cfg(e=4, k=1, cf=4.0)
    cfg = ModelConfig(**{**cfg.__dict__, "d_model": 128, "moe_d_ff": 128, "d_ff": 128,
                         "stages": None, "name": "tq"})
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((1, 8, 128)), jnp.float32)
    y_dense, _ = moe_apply(p, cfg, x)
    qp = quantize_params({"mlp": p}, QuantPolicy(q=4, g=64, method="greedy"))["mlp"]
    y_q, _ = moe_apply(qp, cfg, x)
    # quantized output close-ish (q=4 greedy) and finite
    assert not bool(jnp.isnan(y_q).any())
    rel = float(jnp.linalg.norm(y_q - y_dense) / jnp.linalg.norm(y_dense))
    assert rel < 0.5, rel
