"""Pallas kernel validation: shape/dtype sweep vs the pure-jnp oracle
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize_tensor
from repro.kernels import (
    bcq_mm,
    bcq_mm_ref,
    lutgemm,
    lutgemm_tablewise_ref,
    quantized_matmul,
)

SWEEP = [
    # (B, k, o, q, g, block_k, block_o)
    (1, 512, 256, 2, 64, 512, 256),  # single-batch decode matvec
    (8, 512, 128, 4, 512, 512, 128),  # g == block_k
    (8, 1024, 256, 3, 128, 512, 128),  # multi k-block accumulation
    (16, 512, 384, 1, 8, 256, 128),  # minimum group size
    (4, 1024, 128, 5, 1024, 512, 128),  # row-wise g spanning blocks
    (2, 2048, 256, 2, 2048, 512, 256),  # row-wise, 4 k-blocks per group
]


def _make(rng, B, k, o, q, g, dtype=jnp.float32):
    w = jnp.asarray(rng.standard_normal((k, o)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, k)), dtype)
    qt = quantize_tensor(w, q, g, iters=2, scale_dtype=jnp.float32)
    return x, qt


@pytest.mark.parametrize("B,k,o,q,g,bk,bo", SWEEP)
def test_bcq_mm_matches_oracle(rng, B, k, o, q, g, bk, bo):
    x, qt = _make(rng, B, k, o, q, g)
    y = bcq_mm(x, qt.packed, qt.scales, g=g, block_k=bk, block_o=bo, interpret=True)
    y_ref = bcq_mm_ref(x, qt.packed, qt.scales, g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,k,o,q,g,bk,bo", SWEEP)
def test_lutgemm_matches_oracle(rng, B, k, o, q, g, bk, bo):
    x, qt = _make(rng, B, k, o, q, g)
    y = lutgemm(x, qt.packed, qt.scales, g=g, block_k=bk, block_o=min(bo, 128),
                interpret=True)
    y_ref = bcq_mm_ref(x, qt.packed, qt.scales, g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(rng, dtype):
    x, qt = _make(rng, 4, 512, 128, 3, 64, dtype=dtype)
    y = bcq_mm(x, qt.packed, qt.scales, g=64, interpret=True, block_o=128)
    y_ref = bcq_mm_ref(x, qt.packed, qt.scales, 64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=tol, atol=tol)


def test_lut_algorithm_is_exact_emulation(rng):
    """The tablewise numpy emulation of the paper's algorithm (build 2^mu LUT,
    key by packed byte, scale per group) equals the dense reconstruction."""
    x, qt = _make(rng, 3, 256, 64, 3, 32)
    y_tbl = lutgemm_tablewise_ref(
        np.asarray(x), np.asarray(qt.packed), np.asarray(qt.scales), 32
    )
    y_ref = bcq_mm_ref(x, qt.packed, qt.scales, 32)
    np.testing.assert_allclose(y_tbl, np.asarray(y_ref), rtol=1e-4, atol=1e-4)


def test_wrapper_padding_paths(rng):
    # o not divisible by any lane block; B not a sublane multiple; odd g
    w = jnp.asarray(rng.standard_normal((768, 200)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((3, 768)), jnp.float32)
    qt = quantize_tensor(w, 3, 96, iters=1, scale_dtype=jnp.float32)
    for impl in ("bcq_mm", "lutgemm"):
        y = quantized_matmul(x, qt, impl=impl, interpret=True)
        y_ref = quantized_matmul(x, qt, impl="ref")
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4
        )


def test_wrapper_leading_dims(rng):
    w = jnp.asarray(rng.standard_normal((512, 128)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 5, 512)), jnp.float32)
    qt = quantize_tensor(w, 2, 64, iters=1)
    y = quantized_matmul(x, qt, impl="ref")
    assert y.shape == (2, 5, 128)


def test_kernel_rejects_bad_tiling(rng):
    x, qt = _make(rng, 4, 512, 128, 2, 64)
    with pytest.raises(ValueError):
        bcq_mm(x, qt.packed, qt.scales, g=64, block_k=300, interpret=True)
    with pytest.raises(ValueError):
        lutgemm(x, qt.packed, qt.scales, g=12, interpret=True)  # g % 8 != 0
