"""Request-lifecycle hardening (ISSUE 6 tentpole): the differential
robustness suite. The §4 scheduler contract said interleaving is invisible;
the §9 contract extends it to the unhappy path — **survivor invariance**:
with any subset of requests cancelled, timed out, or failed via injected
faults mid-flight, every *surviving* request's tokens are bit-identical to
the same request in an undisturbed run. Asserted across dense/BCQ ×
plain/speculative × tp ∈ {1, 2}, plus state-machine, validation,
backpressure, deadline, retry and stop-token unit tests."""

import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MarkovCorpus
from repro.infer import (
    Engine,
    FaultPlan,
    QueueFullError,
    Request,
    RequestLifecycle,
    RequestState,
    Scheduler,
    SpecConfig,
    StepClock,
    TransitionError,
)
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params

KEY = jax.random.PRNGKey(0)
MAX_SEQ = 64
# d_model=128 so quantization bites; g=32 keeps (k/g) divisible by tp=2 for
# the row-parallel leaves (same shapes the TP differential suite uses)
Q_GROUP = 32
SPEC = SpecConfig(q_draft=2, gamma=3)


def _cfg():
    return reduced(get_config("llama3.2-3b"), d_model=128, n_kv_heads=4, d_ff=256)


@functools.lru_cache(maxsize=None)
def _params(q: int):
    params = init_params(KEY, _cfg())
    if q:
        params = quantize_params(params, QuantPolicy(q=q, g=Q_GROUP, iters=2))
    return params


@functools.lru_cache(maxsize=None)
def _engine(q: int, tp: int = 0) -> Engine:
    mesh = None
    if tp:
        from repro.parallel.tp import make_tp_mesh

        mesh = make_tp_mesh(tp)
    return Engine(_cfg(), _params(q), max_seq=MAX_SEQ, mesh=mesh)


def _requests(n, *, gen=8, seed0=0, **kw):
    """Fresh Request objects every call — submit() assigns rids and tenants
    mutate nothing, but reusing a Request across schedulers is an error."""
    cfg = _cfg()
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    out = []
    for i in range(n):
        plen = 4 + (i % 3)
        prompt = corpus.sample(1, plen, seed=100 + i)[0, :plen].astype(np.int32)
        out.append(
            Request(
                prompt=prompt,
                max_new_tokens=gen,
                temperature=[0.0, 1.0, 0.7][i % 3],
                seed=seed0 + 10 + i,
                **kw,
            )
        )
    return out


def _run(engine, reqs, *, speculate=None, n_slots=2, chunk=3, **sched_kw):
    sched = Scheduler(engine, n_slots=n_slots, chunk=chunk, speculate=speculate,
                      **sched_kw)
    rids = [sched.submit(r) for r in reqs]
    done = {c.rid: c for c in sched.run()}
    return sched, rids, done


# ---------------------------------------------------------------------------
# state machine
# ---------------------------------------------------------------------------


def test_state_machine_legal_chain():
    rec = RequestLifecycle(rid=0, submitted_at=1.0)
    rec.transition(RequestState.PREFILLING, 2.0)
    assert rec.admitted_at == 2.0
    rec.transition(RequestState.DECODING, 3.0)
    rec.transition(RequestState.FINISHED, 4.0)
    assert rec.state.terminal and rec.finished_at == 4.0
    assert [s for s, _ in rec.history] == [
        RequestState.PREFILLING,
        RequestState.DECODING,
        RequestState.FINISHED,
    ]


@pytest.mark.parametrize(
    "chain, bad",
    [
        ([], RequestState.DECODING),  # queued can't skip prefill
        ([], RequestState.FINISHED),
        # PREFILLING -> CANCELLED/TIMED_OUT became legal with chunked prefill
        # (DESIGN.md §12); SHED stays queue-only
        ([RequestState.PREFILLING], RequestState.SHED),
        ([RequestState.SHED], RequestState.PREFILLING),  # terminal is terminal
        (
            [RequestState.PREFILLING, RequestState.DECODING, RequestState.FINISHED],
            RequestState.FAILED,
        ),
        (
            [RequestState.PREFILLING, RequestState.DECODING, RequestState.CANCELLED],
            RequestState.FINISHED,
        ),
    ],
)
def test_state_machine_illegal_transitions(chain, bad):
    rec = RequestLifecycle(rid=7)
    for s in chain:
        rec.transition(s, 0.0)
    with pytest.raises(TransitionError, match="illegal transition"):
        rec.transition(bad, 1.0)


def test_cancel_unknown_or_terminal_rid_is_noop():
    eng = _engine(0)
    sched = Scheduler(eng, n_slots=2, chunk=2)
    assert not sched.cancel(12345)
    (req,) = _requests(1, gen=2)
    rid = sched.submit(req)
    sched.run()
    assert sched.outcomes[rid].state is RequestState.FINISHED
    assert not sched.cancel(rid)  # already terminal


# ---------------------------------------------------------------------------
# validation (satellite bugfix)
# ---------------------------------------------------------------------------


def test_generate_rejects_prompt_past_cache():
    eng = _engine(0)
    cfg = _cfg()
    long_prompt = np.zeros((1, MAX_SEQ - 2), np.int32)
    with pytest.raises(ValueError, match=r"max_seq"):
        eng.generate(long_prompt, 8)  # 62 + 8 > 64
    # boundary is fine
    ok = np.zeros((1, 4), np.int32)
    eng.generate(ok, 2)
    with pytest.raises(ValueError, match=rf"vocab={cfg.vocab}"):
        eng.generate(np.full((1, 4), cfg.vocab, np.int32), 2)


def test_request_validation_loud():
    with pytest.raises(ValueError, match="integer token ids"):
        Request(prompt=np.array([0.5, 1.5]), max_new_tokens=4)
    with pytest.raises(ValueError, match="seed"):
        Request(prompt=np.array([1, 2]), max_new_tokens=4, seed=1.5)
    with pytest.raises(ValueError, match="int64"):
        Request(prompt=np.array([1, 2]), max_new_tokens=4, seed=2**63)
    with pytest.raises(ValueError, match="seed"):
        Request(prompt=np.array([1, 2]), max_new_tokens=4, seed=True)
    with pytest.raises(ValueError, match="stop_tokens"):
        Request(prompt=np.array([1, 2]), max_new_tokens=4, stop_tokens=[1.5])
    with pytest.raises(ValueError, match="deadline_s"):
        Request(prompt=np.array([1, 2]), max_new_tokens=4, deadline_s=-1.0)
    with pytest.raises(ValueError, match="ttft_deadline_s"):
        Request(prompt=np.array([1, 2]), max_new_tokens=4, ttft_deadline_s=0.0)
    # negative seeds are in PRNGKey's range and stay legal
    Request(prompt=np.array([1, 2]), max_new_tokens=4, seed=-1)


def test_submit_rejects_out_of_vocab_prompt():
    eng = _engine(0)
    sched = Scheduler(eng, n_slots=1, chunk=1)
    bad = Request(prompt=np.array([0, _cfg().vocab], np.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match=rf"vocab={_cfg().vocab}"):
        sched.submit(bad)


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------


def test_bounded_queue_rejects_loudly_then_recovers():
    eng = _engine(0)
    sched = Scheduler(eng, n_slots=1, chunk=2, max_queue=2)
    for r in _requests(2, gen=3):
        sched.submit(r)
    with pytest.raises(QueueFullError, match="admission queue full"):
        sched.submit(_requests(1, gen=3, seed0=50)[0])
    assert sched.counters["rejected_queue_full"] == 1
    # draining the queue restores admission capacity
    sched.run()
    rid = sched.submit(_requests(1, gen=3, seed0=60)[0])
    done = {c.rid: c for c in sched.run()}
    assert rid in done


def test_queue_bound_validation():
    eng = _engine(0)
    with pytest.raises(ValueError, match="max_queue"):
        Scheduler(eng, n_slots=1, max_queue=0)
    with pytest.raises(ValueError, match="retries"):
        Scheduler(eng, n_slots=1, retries=-1)


# ---------------------------------------------------------------------------
# survivor invariance: cancellation
# ---------------------------------------------------------------------------


def test_cancel_queued_and_midflight_survivors_identical():
    eng = _engine(0)
    _, rids_ref, ref = _run(eng, _requests(6))

    sched = Scheduler(eng, n_slots=2, chunk=3)
    reqs = _requests(6)
    rids = [sched.submit(r) for r in reqs]
    out = sched.step()  # two admitted, first chunk done
    assert sched.cancel(rids[0])  # mid-flight: decoding in a slot
    assert sched.cancel(rids[4])  # still queued
    done = {c.rid: c for c in (out + sched.run())}

    assert sched.outcomes[rids[0]].state is RequestState.CANCELLED
    assert sched.outcomes[rids[4]].state is RequestState.CANCELLED
    assert rids[0] not in done and rids[4] not in done
    assert sched.counters["cancelled"] == 2
    # the cancelled mid-flight request kept its partial prefix
    partial = sched.outcomes[rids[0]].new_tokens
    np.testing.assert_array_equal(partial, ref[rids_ref[0]].new_tokens[: partial.size])
    for k in (1, 2, 3, 5):
        np.testing.assert_array_equal(
            done[rids[k]].new_tokens,
            ref[rids_ref[k]].new_tokens,
            err_msg=f"survivor {k} diverged after cancellations",
        )


# ---------------------------------------------------------------------------
# deadlines (injectable clock: fully deterministic)
# ---------------------------------------------------------------------------


def test_deadline_timeout_midflight_survivors_identical():
    eng = _engine(0)
    _, rids_ref, ref = _run(eng, _requests(4))

    clk = StepClock()
    sched = Scheduler(eng, n_slots=2, chunk=3, clock=clk, sleep=clk.sleep)
    reqs = _requests(4)
    reqs[1].deadline_s = 0.5  # will expire after the first chunk
    rids = [sched.submit(r) for r in reqs]
    out = sched.step()
    clk.advance(1.0)
    done = {c.rid: c for c in (out + sched.run())}

    rec = sched.outcomes[rids[1]]
    assert rec.state is RequestState.TIMED_OUT
    assert "deadline 0.5s" in rec.reason
    assert sched.counters["timed_out"] == 1
    for k in (0, 2, 3):
        np.testing.assert_array_equal(
            done[rids[k]].new_tokens, ref[rids_ref[k]].new_tokens
        )


def test_deadline_shed_in_queue_before_prefill():
    eng = _engine(0)
    clk = StepClock()
    sched = Scheduler(eng, n_slots=1, chunk=2, clock=clk, sleep=clk.sleep)
    reqs = _requests(3)
    reqs[2].ttft_deadline_s = 0.25  # queued behind a busy slot; will expire
    rids = [sched.submit(r) for r in reqs]
    sched.step()
    clk.advance(1.0)
    done = {c.rid: c for c in sched.run()}
    rec = sched.outcomes[rids[2]]
    assert rec.state is RequestState.SHED
    assert "shed in queue" in rec.reason
    assert rec.admitted_at is None  # never wasted a prefill
    assert sched.counters["shed"] == 1
    assert rids[2] not in done and rids[0] in done and rids[1] in done


def test_latency_summary_reports_percentiles():
    eng = _engine(0)
    clk = StepClock(dt=0.001)  # every clock reading advances 1ms
    sched = Scheduler(eng, n_slots=2, chunk=2, clock=clk, sleep=clk.sleep)
    for r in _requests(4, gen=6):
        sched.submit(r)
    sched.run()
    s = sched.summary()
    assert s["by_state"] == {"finished": 4}
    assert s["ttft_s"]["n"] == 4 and s["ttft_s"]["p50"] > 0
    assert s["tpot_s"]["n"] == 4 and s["tpot_s"]["p95"] >= s["tpot_s"]["p50"]
    assert s["counters"]["retries"] == 0


# ---------------------------------------------------------------------------
# fault injection: prefill / decode dispatch failures
# ---------------------------------------------------------------------------


def test_transient_prefill_fault_retries_and_recovers():
    eng = _engine(0)
    _, rids_ref, ref = _run(eng, _requests(3))

    plan = FaultPlan(fail_prefill={1: 2})  # 2 failures < 1 + 2 retries
    sched = Scheduler(eng, n_slots=2, chunk=3, retries=2, faults=plan,
                      sleep=lambda s: None)
    rids = [sched.submit(r) for r in _requests(3)]
    done = {c.rid: c for c in sched.run()}
    assert plan.fired_prefill == 2
    assert sched.counters["retries"] == 2
    for k in range(3):  # EVERY request completes identically — fault invisible
        np.testing.assert_array_equal(
            done[rids[k]].new_tokens, ref[rids_ref[k]].new_tokens
        )


def test_permanent_prefill_fault_quarantines_one_request():
    eng = _engine(0)
    _, rids_ref, ref = _run(eng, _requests(4))

    plan = FaultPlan(fail_prefill={2: -1})  # every attempt fails
    sched = Scheduler(eng, n_slots=2, chunk=3, retries=1, faults=plan,
                      sleep=lambda s: None)
    rids = [sched.submit(r) for r in _requests(4)]
    done = {c.rid: c for c in sched.run()}
    rec = sched.outcomes[rids[2]]
    assert rec.state is RequestState.FAILED
    assert "admission prefill" in rec.reason and "injected" in rec.reason
    assert rids[2] not in done
    for k in (0, 1, 3):
        np.testing.assert_array_equal(
            done[rids[k]].new_tokens, ref[rids_ref[k]].new_tokens
        )


def test_transient_decode_fault_is_invisible():
    eng = _engine(0)
    _, rids_ref, ref = _run(eng, _requests(4))

    plan = FaultPlan(fail_chunk={1: 1})  # second chunk fails once, then works
    sched = Scheduler(eng, n_slots=2, chunk=3, retries=2, faults=plan,
                      sleep=lambda s: None)
    rids = [sched.submit(r) for r in _requests(4)]
    done = {c.rid: c for c in sched.run()}
    assert plan.fired_chunk == 1 and sched.counters["retries"] == 1
    for k in range(4):
        np.testing.assert_array_equal(
            done[rids[k]].new_tokens, ref[rids_ref[k]].new_tokens
        )


def test_permanent_decode_fault_fails_active_completes_queued():
    eng = _engine(0)
    _, rids_ref, ref = _run(eng, _requests(5))

    plan = FaultPlan(fail_chunk={1: -1})
    sched = Scheduler(eng, n_slots=2, chunk=3, retries=1, faults=plan,
                      sleep=lambda s: None)
    rids = [sched.submit(r) for r in _requests(5)]
    done = {c.rid: c for c in sched.run()}
    # the two tenants active at chunk 1 fail (their device state is suspect);
    # everything still queued is served afterwards on rebuilt slot state
    failed = [r for r in rids if sched.outcomes[r].state is RequestState.FAILED]
    assert len(failed) == 2
    assert sched.counters["decode_dispatch_failures"] == 1
    survivors = [r for r in rids if r not in failed]
    assert sorted(done) == sorted(survivors)
    for rid, rid_ref in zip(rids, rids_ref):
        if rid in done:
            np.testing.assert_array_equal(
                done[rid].new_tokens, ref[rid_ref].new_tokens
            )


# ---------------------------------------------------------------------------
# NaN/inf logit guard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [0, 3], ids=["dense", "bcq_q3"])
def test_nan_row_quarantined_neighbours_untouched(q):
    eng = _engine(q)
    _, rids_ref, ref = _run(eng, _requests(4))

    plan = FaultPlan(nan_row={1: 4})  # poison rid 1 once it has >= 4 tokens
    sched = Scheduler(eng, n_slots=2, chunk=3, faults=plan)
    rids = [sched.submit(r) for r in _requests(4)]
    done = {c.rid: c for c in sched.run()}
    rec = sched.outcomes[rids[1]]
    assert rec.state is RequestState.FAILED
    assert "non-finite logits" in rec.reason
    assert plan.fired_nan == 1
    assert sched.counters["nan_quarantined"] == 1
    # the poisoned request still reports its clean partial prefix
    np.testing.assert_array_equal(
        rec.new_tokens, ref[rids_ref[1]].new_tokens[: rec.n_tokens]
    )
    assert rids[1] not in done
    # the scrubbed slot was REFILLED and its next tenant is also exact
    for k in (0, 2, 3):
        np.testing.assert_array_equal(
            done[rids[k]].new_tokens, ref[rids_ref[k]].new_tokens
        )


def test_nan_guard_off_is_an_opt_out():
    eng = _engine(0)
    plan = FaultPlan(nan_row={0: 2})
    sched = Scheduler(eng, n_slots=1, chunk=2, faults=plan, nan_guard=False)
    rid = sched.submit(_requests(1, gen=6)[0])
    sched.run()
    # without the guard the poisoned request runs to budget (emitting argmax
    # garbage after the poison point) — that's exactly why the guard defaults
    # on; here we only assert the opt-out leaves the pipeline running
    assert sched.outcomes[rid].state is RequestState.FINISHED
    assert sched.counters["nan_quarantined"] == 0


# ---------------------------------------------------------------------------
# stop tokens (satellite)
# ---------------------------------------------------------------------------


def test_stop_token_truncation_identical_to_solo():
    eng = _engine(0)
    (base,) = _requests(1, gen=10)
    solo_full = eng.generate(base.prompt[None], 10)
    stop_tok = int(solo_full.tokens[0, base.prompt.size + 4])

    solo_stop = eng.generate(base.prompt[None], 10, stop_tokens=(stop_tok,))
    assert solo_stop.stop_positions is not None
    truncated = solo_stop.generated(0)
    assert truncated[-1] == stop_tok and truncated.size <= 10

    sched = Scheduler(eng, n_slots=2, chunk=3)
    req = Request(prompt=base.prompt, max_new_tokens=10, stop_tokens=(stop_tok,))
    rid = sched.submit(req)
    done = {c.rid: c for c in sched.run()}
    np.testing.assert_array_equal(done[rid].new_tokens, truncated)
    assert done[rid].stopped
    assert sched.counters["stopped_early"] == 1
    assert sched.outcomes[rid].reason == "stop token"


def test_stop_token_frees_slot_early_for_queued_request():
    eng = _engine(0)
    (probe,) = _requests(1, gen=12)
    solo = eng.generate(probe.prompt[None], 12)
    stop_tok = int(solo.tokens[0, probe.prompt.size + 1])  # stops in chunk 1

    sched = Scheduler(eng, n_slots=1, chunk=3)
    a = sched.submit(
        Request(prompt=probe.prompt, max_new_tokens=12, stop_tokens=(stop_tok,))
    )
    tail = _requests(1, seed0=30, gen=4)[0]
    b = sched.submit(tail)
    done = {c.rid: c for c in sched.run()}
    # the stopped request ran 1 chunk, not its 12-token budget, so the queued
    # request was admitted on the freed slot well before budget exhaustion
    assert done[a].stopped and done[a].new_tokens.size <= 3
    assert done[b].admitted_at_step <= 3
    solo_tail = eng.generate(
        tail.prompt[None], 4, temperature=tail.temperature, seed=tail.seed
    )
    np.testing.assert_array_equal(
        done[b].new_tokens, solo_tail.tokens[0, tail.prompt.size :]
    )


def test_stop_token_never_emitted_runs_full_budget():
    eng = _engine(0)
    (base,) = _requests(1, gen=6)
    solo = eng.generate(base.prompt[None], 6)
    new = solo.tokens[0, base.prompt.size :]
    unused = int(
        next(t for t in range(_cfg().vocab) if t not in set(int(x) for x in new))
    )
    sched = Scheduler(eng, n_slots=1, chunk=2)
    rid = sched.submit(
        Request(prompt=base.prompt, max_new_tokens=6, stop_tokens=(unused,))
    )
    done = {c.rid: c for c in sched.run()}
    assert not done[rid].stopped
    np.testing.assert_array_equal(done[rid].new_tokens, new)


# ---------------------------------------------------------------------------
# streaming callbacks
# ---------------------------------------------------------------------------


def test_on_tokens_streams_exactly_the_completion():
    eng = _engine(0)
    seen: dict = {}
    sched = Scheduler(
        eng, n_slots=2, chunk=3,
        on_tokens=lambda rid, toks: seen.setdefault(rid, []).extend(toks),
    )
    rids = [sched.submit(r) for r in _requests(3)]
    done = {c.rid: c for c in sched.run()}
    for rid in rids:
        np.testing.assert_array_equal(np.asarray(seen[rid]), done[rid].new_tokens)


def test_on_event_fires_once_per_terminal_state():
    eng = _engine(0)
    events = []
    sched = Scheduler(
        eng, n_slots=1, chunk=2, on_event=lambda rec: events.append(rec)
    )
    rids = [sched.submit(r) for r in _requests(2, gen=4)]
    sched.cancel(rids[1])
    sched.run()
    assert sorted(e.rid for e in events) == sorted(rids)
    states = {e.rid: e.state for e in events}
    assert states[rids[0]] is RequestState.FINISHED
    assert states[rids[1]] is RequestState.CANCELLED


# ---------------------------------------------------------------------------
# the acceptance matrix: survivors bit-identical across
# dense/BCQ × plain/speculative × tp ∈ {1, 2}
# ---------------------------------------------------------------------------


def _matrix_requests():
    reqs = _requests(5)
    # the cancel target (0) and the NaN target (2) need budget headroom: a
    # speculative chunk can emit up to chunk*(gamma+1) tokens, and the
    # disturbance must land before the budget does
    reqs[0].max_new_tokens = 12
    reqs[2].max_new_tokens = 12
    return reqs


def _disturbed_vs_undisturbed(engine, *, speculate=None):
    """Run the same 5-request workload undisturbed and disturbed (one
    mid-flight cancel + one injected NaN row + one queue-shed deadline), and
    assert every survivor is bit-identical."""
    _, rids_ref, ref = _run(engine, _matrix_requests(), speculate=speculate,
                            chunk=2)

    clk = StepClock()
    plan = FaultPlan(nan_row={2: 1})
    sched = Scheduler(engine, n_slots=2, chunk=2, speculate=speculate,
                      faults=plan, clock=clk, sleep=clk.sleep)
    reqs = _matrix_requests()
    reqs[3].deadline_s = 0.5
    rids = [sched.submit(r) for r in reqs]
    out = sched.step()
    sched.cancel(rids[0])
    clk.advance(1.0)  # expires request 3's deadline
    done = {c.rid: c for c in (out + sched.run())}

    states = {i: sched.outcomes[rids[i]].state for i in range(5)}
    assert states[0] is RequestState.CANCELLED
    assert states[2] is RequestState.FAILED
    assert states[3] in (RequestState.TIMED_OUT, RequestState.SHED)
    survivors = [i for i in range(5) if states[i] is RequestState.FINISHED]
    assert survivors, "expected at least one survivor"
    for i in survivors:
        np.testing.assert_array_equal(
            done[rids[i]].new_tokens,
            ref[rids_ref[i]].new_tokens,
            err_msg=f"survivor {i} diverged in the disturbed run",
        )
    # partial prefixes of the disturbed are prefixes of the undisturbed
    for i in (0, 2):
        part = sched.outcomes[rids[i]].new_tokens
        np.testing.assert_array_equal(
            part, ref[rids_ref[i]].new_tokens[: part.size]
        )


@pytest.mark.parametrize("q", [0, 4], ids=["dense", "bcq_q4"])
def test_survivor_invariance_plain(q):
    _disturbed_vs_undisturbed(_engine(q))


def test_survivor_invariance_speculative():
    _disturbed_vs_undisturbed(_engine(4), speculate=SPEC)


@pytest.mark.needs_multidevice
@pytest.mark.parametrize("q, spec", [(0, None), (4, SPEC)],
                         ids=["tp2_dense", "tp2_bcq_spec"])
def test_survivor_invariance_tp2(q, spec):
    _disturbed_vs_undisturbed(_engine(q, tp=2), speculate=spec)
