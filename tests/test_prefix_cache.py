"""Prefix-cache KV reuse + chunked prefill (ISSUE 10 tentpole, DESIGN.md §12).

The load-bearing invariant: serving against a *warm* prefix cache is
bit-identical to serving cold and to solo ``Engine.generate`` — across
quantization formats, speculative decode, tensor parallelism, chunked vs
whole-shot prefill, and mid-flight eviction. Plus trie/refcount/eviction
unit tests and the leak-free accounting contract::

    hits + misses == commits + aborts      # every begin ends exactly once
    pinned == 0                            # refcounts drain at quiescence
"""

import functools
import gc

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MarkovCorpus
from repro.infer import (
    Engine,
    PrefixCache,
    Request,
    Scheduler,
    SpecConfig,
    model_identity,
)
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params

KEY = jax.random.PRNGKey(0)
MAX_SEQ = 64
Q_GROUP = 32  # keeps (k/g) divisible by tp=2 for row-parallel leaves


@pytest.fixture(scope="module", autouse=True)
def _release_module_state():
    """The grid fixtures pin engines — and every executable XLA compiled for
    them — for the whole process otherwise. On this CPU-only container that
    accumulated JIT state is enough to segfault XLA's compiler hundreds of
    tests later (observed in test_tp_serve), so drop it when the module ends."""
    yield
    _cold_engine.cache_clear()
    _params.cache_clear()
    jax.clear_caches()
    gc.collect()


def _cfg(arch="llama3.2-3b"):
    return reduced(get_config(arch), d_model=128, n_kv_heads=4, d_ff=256)


@functools.lru_cache(maxsize=None)
def _params(fmt: str):
    params = init_params(KEY, _cfg())
    if fmt != "dense":
        params = quantize_params(
            params, QuantPolicy(q=3, g=Q_GROUP, iters=2, fmt=fmt)
        )
    return params


def _mesh(tp: int):
    if not tp:
        return None
    from repro.parallel.tp import make_tp_mesh

    return make_tp_mesh(tp)


@functools.lru_cache(maxsize=None)
def _cold_engine(fmt: str, tp: int = 0) -> Engine:
    return Engine(_cfg(), _params(fmt), max_seq=MAX_SEQ, mesh=_mesh(tp))


def _warm_engine(fmt: str, tp: int = 0, *, block_tokens=4, max_bytes=64 << 20):
    """Fresh (never lru-cached — the cache is stateful) engine with a cache."""
    return Engine(
        _cfg(), _params(fmt), max_seq=MAX_SEQ, mesh=_mesh(tp),
        prefix_cache=PrefixCache(block_tokens=block_tokens, max_bytes=max_bytes),
    )


def _shared_prefix_requests(n, *, prefix_len=12, gen=6, seed0=0):
    """n requests sharing a ``prefix_len``-token leading system prompt with
    per-request tails of varying length — the workload the cache exists for."""
    cfg = _cfg()
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    shared = corpus.sample(1, prefix_len, seed=99)[0, :prefix_len]
    out = []
    for i in range(n):
        tlen = 2 + (i % 4)
        tail = corpus.sample(1, tlen, seed=100 + i)[0, :tlen]
        out.append(
            Request(
                prompt=np.concatenate([shared, tail]).astype(np.int32),
                max_new_tokens=gen,
                temperature=[0.0, 1.0, 0.7][i % 3],
                seed=seed0 + 10 + i,
            )
        )
    return out


def _run(engine, reqs, *, speculate=None, prefill_chunk=None, n_slots=2,
         chunk=3, **kw):
    sched = Scheduler(engine, n_slots=n_slots, chunk=chunk, speculate=speculate,
                      prefill_chunk=prefill_chunk, **kw)
    for r in reqs:
        sched.submit(r)
    return sched, {c.rid: c for c in sched.run()}


def _assert_accounting_clean(pc: PrefixCache):
    c = pc.counters
    assert c["hits"] + c["misses"] == c["commits"] + c["aborts"], c
    assert pc.pinned == 0, "refcounts must drain to zero at quiescence"
    assert pc.cached_bytes == sum(n.nbytes for n in pc._nodes)
    assert pc.cached_bytes <= pc.max_bytes


# ---------------------------------------------------------------------------
# trie / refcount / eviction units (no engine: synthetic row payloads)
# ---------------------------------------------------------------------------


def _fake_rows(nbytes=64):
    return {"k": np.zeros((1, 1, 1, nbytes), np.int8)}


def test_trie_match_and_commit_roundtrip():
    pc = PrefixCache(block_tokens=4, max_bytes=1 << 20)
    toks = np.arange(10, dtype=np.int32)
    h = pc.begin(toks, max_match=9, max_commit=10)
    assert h.length == 0 and h.new_spans == [(0, 4), (4, 8)]
    h.rows = [_fake_rows(), _fake_rows()]
    pc.complete(h)
    assert pc.n_nodes == 2 and pc.counters == {
        "hits": 0, "misses": 1, "commits": 1, "aborts": 0, "evictions": 0,
    }
    # same prompt again: both blocks match (max_match=9 admits [0,8))
    h2 = pc.begin(toks, max_match=9, max_commit=10)
    assert h2.length == 8 and h2.new_spans == []
    assert pc.counters["hits"] == 1
    pc.complete(h2)
    # a prompt diverging inside block 2 only reuses block 1
    other = toks.copy()
    other[6] = 77
    h3 = pc.begin(other, max_match=9, max_commit=8)
    assert h3.length == 4 and h3.new_spans == [(4, 8)]
    h3.rows = [_fake_rows()]
    pc.complete(h3)
    assert pc.n_nodes == 3  # sibling block under the shared first block
    _assert_accounting_clean(pc)


def test_begin_caps_match_and_commit():
    pc = PrefixCache(block_tokens=4, max_bytes=1 << 20)
    toks = np.arange(8, dtype=np.int32)
    h = pc.begin(toks, max_match=7, max_commit=8)
    h.rows = [_fake_rows(), _fake_rows()]
    pc.complete(h)
    # max_match=7 < 8: the second block may NOT be reused even though it is
    # committed (the engine must leave >= 1 token to prefill)
    h2 = pc.begin(toks, max_match=7, max_commit=8)
    assert h2.length == 4
    pc.abort(h2)
    # max_commit=0 (ring wrap guard): nothing planned, nothing committed
    h3 = pc.begin(toks, max_match=0, max_commit=0)
    assert h3.length == 0 and h3.new_spans == []
    pc.complete(h3)
    assert pc.n_nodes == 2
    _assert_accounting_clean(pc)


def test_pinned_blocks_survive_eviction():
    pc = PrefixCache(block_tokens=2, max_bytes=1 << 20)
    toks = np.arange(6, dtype=np.int32)
    h = pc.begin(toks, max_match=6, max_commit=6)
    h.rows = [_fake_rows(), _fake_rows(), _fake_rows()]
    pc.complete(h)
    pinned = pc.begin(toks, max_match=6, max_commit=6)
    assert pinned.length == 6 and pc.pinned == 3
    pc.evict_to(0)  # pinned path must survive a zero budget
    assert pc.n_nodes == 3 and pc.counters["evictions"] == 0
    pc.complete(pinned)
    pc.evict_to(0)  # now the whole chain drains leaf-first
    assert pc.n_nodes == 0 and pc.cached_bytes == 0
    assert pc.counters["evictions"] == 3
    _assert_accounting_clean(pc)


def test_lru_eviction_prefers_oldest_childless():
    pc = PrefixCache(block_tokens=2, max_bytes=1 << 30)
    old, new = np.array([1, 2], np.int32), np.array([3, 4], np.int32)
    for toks in (old, new):
        h = pc.begin(toks, max_match=2, max_commit=2)
        h.rows = [_fake_rows()]
        pc.complete(h)
    # touch `old` so `new` becomes the LRU victim
    pc.complete(pc.begin(old, max_match=2, max_commit=2))
    pc.evict_to(pc.cached_bytes - 1)  # force exactly one eviction
    assert pc.n_nodes == 1
    assert pc._nodes[0].key == old.tobytes()
    _assert_accounting_clean(pc)


def test_abort_unpins_without_commit():
    pc = PrefixCache(block_tokens=4, max_bytes=1 << 20)
    h = pc.begin(np.arange(8, dtype=np.int32), max_match=8, max_commit=8)
    h.rows = [_fake_rows()]  # captured, then admission dies
    pc.abort(h)
    pc.abort(h)  # idempotent
    assert pc.n_nodes == 0 and pc.counters["aborts"] == 1
    _assert_accounting_clean(pc)


def test_bind_refuses_mismatched_model_identity():
    pc = PrefixCache()
    pc.bind("model-a")
    pc.bind("model-a")  # same identity is fine
    with pytest.raises(ValueError, match="bound to model identity"):
        pc.bind("model-b")


def test_model_identity_distinguishes_policies():
    cfg = _cfg()
    dense = model_identity(cfg, _params("dense"))
    bcq = model_identity(cfg, _params("bcq"))
    ternary = model_identity(cfg, _params("ternary"))
    assert len({dense, bcq, ternary}) == 3
    assert model_identity(cfg, _params("bcq")) == bcq  # deterministic
    assert model_identity(cfg, _params("bcq"), _mesh(2)) != bcq


def test_refcount_eviction_property_randomized():
    """Fixed-seed random interleaving of begin/complete/abort/evict_to: the
    accounting invariants hold at every step, refs never underflow, and the
    byte ledger always matches the live node set."""
    rng = np.random.default_rng(0)
    pc = PrefixCache(block_tokens=2, max_bytes=4096)
    open_handles = []
    for step in range(300):
        op = rng.integers(0, 4)
        if op == 0:
            toks = rng.integers(0, 5, size=int(rng.integers(2, 9)))
            h = pc.begin(toks.astype(np.int32), max_match=toks.size,
                         max_commit=toks.size)
            h.rows = [_fake_rows(int(rng.integers(16, 64)))
                      for _ in h.new_spans]
            open_handles.append(h)
        elif op == 1 and open_handles:
            pc.complete(open_handles.pop(int(rng.integers(len(open_handles)))))
        elif op == 2 and open_handles:
            pc.abort(open_handles.pop(int(rng.integers(len(open_handles)))))
        elif op == 3:
            pc.evict_to(int(rng.integers(0, 4096)))
        assert pc.cached_bytes == sum(n.nbytes for n in pc._nodes)
        assert all(n.refs >= 0 for n in pc._nodes)
        assert pc.pinned == sum(len(h.matched) for h in open_handles)
    for h in open_handles:
        pc.abort(h)
    _assert_accounting_clean(pc)


# ---------------------------------------------------------------------------
# warm-vs-cold bit identity across the serving grid
# ---------------------------------------------------------------------------

GRID = [
    ("dense", None, 0),
    ("bcq", None, 0),
    ("bcq", SpecConfig(q_draft=2, gamma=3), 0),
    ("ternary", None, 0),
    ("ternary", SpecConfig(q_draft=1, gamma=2), 0),
    ("dense", None, 2),
    ("bcq", SpecConfig(q_draft=2, gamma=3), 2),
    ("ternary", None, 2),
]


@pytest.mark.parametrize(
    "fmt,spec,tp", GRID,
    ids=[f"{f}-{'spec' if s else 'plain'}-tp{t or 1}" for f, s, t in GRID],
)
def test_warm_vs_cold_bit_identity(fmt, spec, tp):
    """THE invariant: a second wave of identical prompts served against the
    now-warm cache emits exactly the tokens the cold engine emits — across
    formats, speculation and TP. Accounting is leak-free afterwards."""
    warm = _warm_engine(fmt, tp)
    reqs_a = _shared_prefix_requests(5)
    _, _ = _run(warm, reqs_a, speculate=spec)  # wave 1: populate
    hits_before = warm.prefix_cache.counters["hits"]
    reqs_b = _shared_prefix_requests(5)  # identical prompts/seeds, fresh rids
    _, warm_done = _run(warm, reqs_b, speculate=spec)
    assert warm.prefix_cache.counters["hits"] > max(hits_before, 0)

    _, cold_done = _run(_cold_engine(fmt, tp), _shared_prefix_requests(5),
                        speculate=spec)
    for r_warm, (rid_c, c_cold) in zip(reqs_b, sorted(cold_done.items())):
        np.testing.assert_array_equal(
            warm_done[r_warm.rid].new_tokens, c_cold.new_tokens,
            err_msg=f"warm-cache tokens diverged ({fmt}, tp={tp})",
        )
    # and against solo generate for one greedy request
    solo = _cold_engine(fmt, tp).generate(
        reqs_b[0].prompt[None], reqs_b[0].max_new_tokens, speculate=spec,
    )
    np.testing.assert_array_equal(
        warm_done[reqs_b[0].rid].new_tokens,
        solo.tokens[0, reqs_b[0].prompt.size:],
    )
    _assert_accounting_clean(warm.prefix_cache)


def test_chunked_vs_unchunked_identity():
    """Chunked prefill is a scheduling knob, never a semantics knob: the same
    workload through prefill_chunk=4 (with a warm cache) and through
    whole-shot cold admission emits identical tokens."""
    warm = _warm_engine("bcq")
    reqs = _shared_prefix_requests(6, prefix_len=16, gen=6)
    _, chunked_done = _run(warm, reqs, prefill_chunk=4)
    _, cold_done = _run(_cold_engine("bcq"),
                        _shared_prefix_requests(6, prefix_len=16, gen=6))
    for r, (rid_c, c_cold) in zip(reqs, sorted(cold_done.items())):
        np.testing.assert_array_equal(
            chunked_done[r.rid].new_tokens, c_cold.new_tokens
        )
    assert warm.prefix_cache.counters["hits"] > 0
    _assert_accounting_clean(warm.prefix_cache)


def test_chunked_prefill_without_cache_identity():
    """Chunked prefill with NO prefix cache attached also matches whole-shot
    (the two features are independent)."""
    eng = _cold_engine("dense")
    reqs = _shared_prefix_requests(4, prefix_len=16, gen=5)
    _, chunked = _run(eng, reqs, prefill_chunk=4)
    _, whole = _run(eng, _shared_prefix_requests(4, prefix_len=16, gen=5))
    for r, (rid_w, c_whole) in zip(reqs, sorted(whole.items())):
        np.testing.assert_array_equal(chunked[r.rid].new_tokens,
                                      c_whole.new_tokens)


def test_mid_flight_eviction_survivor_identity():
    """Evicting the entire cache between scheduler steps — while admissions
    are pinning and committing against it — never changes tokens: installs
    are copies, pinned paths survive, and evicted blocks just stop matching."""
    warm = _warm_engine("dense", block_tokens=4)
    reqs = _shared_prefix_requests(6, gen=6)
    sched = Scheduler(warm, n_slots=2, chunk=2, prefill_chunk=4)
    for r in reqs:
        sched.submit(r)
    done = {}
    while not sched.idle:
        for c in sched.step():
            done[c.rid] = c
        warm.prefix_cache.evict_to(0)       # maximum churn
        warm.prefix_cache.max_bytes = 64 << 20
    assert warm.prefix_cache.counters["evictions"] > 0
    _, cold_done = _run(_cold_engine("dense"), _shared_prefix_requests(6, gen=6))
    for r, (rid_c, c_cold) in zip(reqs, sorted(cold_done.items())):
        np.testing.assert_array_equal(done[r.rid].new_tokens, c_cold.new_tokens)
    _assert_accounting_clean(warm.prefix_cache)


def test_recurrent_arch_warm_identity():
    """RECURRENT leaves restore from the boundary snapshot (taxonomy §5): a
    recurrent-state architecture served warm matches cold bit-for-bit."""
    cfg = reduced(get_config("xlstm-125m"))
    params = init_params(KEY, cfg)
    warm = Engine(cfg, params, max_seq=48,
                  prefix_cache=PrefixCache(block_tokens=4))
    cold = Engine(cfg, params, max_seq=48)
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    shared = corpus.sample(1, 12, seed=99)[0, :12]

    def reqs():
        out = []
        for i in range(4):
            tail = corpus.sample(1, 2 + i, seed=100 + i)[0, : 2 + i]
            out.append(Request(
                prompt=np.concatenate([shared, tail]).astype(np.int32),
                max_new_tokens=5, temperature=[0.0, 0.9][i % 2], seed=7 + i,
            ))
        return out

    _run(warm, reqs())  # populate
    rb = reqs()
    _, warm_done = _run(warm, rb)
    assert warm.prefix_cache.counters["hits"] > 0
    _, cold_done = _run(cold, reqs())
    for r, (rid_c, c_cold) in zip(rb, sorted(cold_done.items())):
        np.testing.assert_array_equal(warm_done[r.rid].new_tokens,
                                      c_cold.new_tokens)
    _assert_accounting_clean(warm.prefix_cache)


def test_ring_arch_wrapped_prompts_bypass_cache():
    """A ring (local-attention) cache serves correctly with a prefix cache
    attached: prompts longer than the window bypass matching AND committing
    (their early rows are clobbered by the wrap), short prompts still reuse,
    and everything stays identical to the cold engine."""
    cfg = reduced(get_config("recurrentgemma-9b"))
    params = init_params(KEY, cfg)
    assert cfg.window and cfg.window < 48
    warm = Engine(cfg, params, max_seq=48,
                  prefix_cache=PrefixCache(block_tokens=4))
    cold = Engine(cfg, params, max_seq=48)
    corpus = MarkovCorpus(cfg.vocab, seed=3)
    shared = corpus.sample(1, 8, seed=99)[0, :8]

    def reqs():
        out = []
        for i in range(4):
            # i=3 exceeds the window -> wrapped -> must bypass the cache
            tlen = [2, 4, 6, cfg.window + 4][i]
            tail = corpus.sample(1, tlen, seed=100 + i)[0, :tlen]
            out.append(Request(
                prompt=np.concatenate([shared, tail]).astype(np.int32),
                max_new_tokens=5, seed=7 + i,
            ))
        return out

    _run(warm, reqs())
    rb = reqs()
    _, warm_done = _run(warm, rb)
    _, cold_done = _run(cold, reqs())
    for r, (rid_c, c_cold) in zip(rb, sorted(cold_done.items())):
        np.testing.assert_array_equal(warm_done[r.rid].new_tokens,
                                      c_cold.new_tokens)
    # the wrapped prompt committed nothing: no trie path spans past the window
    assert all(n.end <= min(48, cfg.window) for n in warm.prefix_cache._nodes)
    _assert_accounting_clean(warm.prefix_cache)


# ---------------------------------------------------------------------------
# observability + guards
# ---------------------------------------------------------------------------


def test_metrics_and_trace_instrumentation():
    """Counters mirror into the registry in lockstep, gauges track bytes and
    trie size, and cache_hit/evict instants land on the scheduler lane."""
    from repro.obs import MetricsRegistry, Tracer

    warm = _warm_engine("dense", block_tokens=4)
    metrics, tracer = MetricsRegistry(), Tracer()
    reqs = _shared_prefix_requests(5)
    _run(warm, reqs, metrics=metrics, tracer=tracer)
    _run(warm, _shared_prefix_requests(5), metrics=metrics, tracer=tracer)
    pc = warm.prefix_cache
    snap = metrics.snapshot()
    for key, host in pc.counters.items():
        series = snap[f"prefix_{key}_total"]["series"]
        assert sum(s["value"] for s in series) == host, key
    assert snap["prefix_cached_bytes"]["series"][0]["value"] == pc.cached_bytes
    assert snap["prefix_trie_nodes"]["series"][0]["value"] == pc.n_nodes
    assert snap["prefix_pinned_refs"]["series"][0]["value"] == 0
    names = [e["name"] for e in tracer.to_chrome()["traceEvents"]]
    assert "cache_hit" in names
    pc.evict_to(0)
    names = [e["name"] for e in tracer.to_chrome()["traceEvents"]]
    assert "evict" in names


def test_prefix_hit_tokens_stamped_on_lifecycle():
    warm = _warm_engine("dense", block_tokens=4)
    _run(warm, _shared_prefix_requests(4))
    sched, done = _run(warm, _shared_prefix_requests(4))
    hits = [sched.outcomes[rid].prefix_hit_tokens for rid in done]
    assert any(h >= 4 for h in hits)
    chunks = [sched.outcomes[rid].prefill_chunks for rid in done]
    assert all(c == 1 for c in chunks)  # sync admission = one dispatch
    # a cold cache + chunked admission: 14..17-token prompts over 4-token
    # chunks take several dispatches, and the stamp records them
    fresh = _warm_engine("dense", block_tokens=4)
    sched2, done2 = _run(fresh, _shared_prefix_requests(4), prefill_chunk=4)
    assert any(sched2.outcomes[rid].prefill_chunks > 1 for rid in done2)


def test_prefix_cache_refused_on_unsupported_arch():
    cfg = reduced(get_config("olmoe-1b-7b"))  # MoE: outside the serving gate
    params = init_params(KEY, cfg)
    with pytest.raises(ValueError, match="prefix_cache requires"):
        Engine(cfg, params, max_seq=32, prefix_cache=PrefixCache())


def test_chunked_prefill_refused_on_recurrent_arch():
    cfg = reduced(get_config("xlstm-125m"))
    eng = Engine(cfg, init_params(KEY, cfg), max_seq=48)
    assert not eng.supports_chunked_prefill
    with pytest.raises(ValueError, match="chunked prefill"):
        Scheduler(eng, n_slots=2, chunk=2, prefill_chunk=4)
