"""Static contract checker: pass-level units + golden-jaxpr census pins.

The golden tests pin the TP decode step's collective census for
representative configs — the numbers ARE the documented 2L+1 contract
(parallel/tp.py), so a refactor that changes them must change the doc (and
this file) deliberately, never silently.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.staticcheck import census, dtypeflow, lint, transfers, vmem
from repro.analysis.staticcheck.harness import (
    build_cell,
    build_injected_cell,
    expected_collectives,
)
from repro.analysis.staticcheck.jaxpr_walk import walk
from repro.configs import get_config
from repro.kernels import autotune, introspect, ops

needs4 = pytest.mark.needs_multidevice


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------


def test_walk_scan_multiplier():
    def f(x):
        def body(c, _):
            return c + jnp.sin(c), None

        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    sites = list(walk(jax.make_jaxpr(f)(jnp.ones((2,)))))
    sin_sites = [s for s in sites if s.prim == "sin"]
    assert len(sin_sites) == 1
    assert sin_sites[0].repeats == 7
    assert "scan" in sin_sites[0].stack


def test_walk_does_not_descend_pallas():
    from repro.kernels.bcq_mm import bcq_mm
    from repro.core.packing import pack_signs

    rng = np.random.default_rng(0)
    signs = np.where(rng.standard_normal((1, 128, 128)) > 0, 1, -1).astype(np.int8)
    packed = pack_signs(signs)
    scales = jnp.ones((1, 1, 128), jnp.float32)
    x = jnp.ones((8, 128), jnp.float32)
    closed = jax.make_jaxpr(
        lambda x: bcq_mm(x, packed, scales, g=128, block_k=128, block_o=128,
                         interpret=True)
    )(x)
    prims = {s.prim for s in walk(closed)}
    assert "pallas_call" in prims
    # kernel-body prims (the unpack shift/and) must NOT leak into the walk
    inner = {s.prim for s in walk(closed, descend_pallas=True)}
    assert inner - prims  # descending finds strictly more


# ---------------------------------------------------------------------------
# golden census pins (struct-traced, full-size configs)
# ---------------------------------------------------------------------------

# (arch, L) → pinned 2L+1. Changing a number here means the TP communication
# topology changed: update parallel/tp.py's docs in the same commit.
GOLDEN = {
    "llama3.2-3b": 57,  # 28 blocks
    "phi4-mini-3.8b": 65,  # 32 blocks
    "musicgen-medium": 97,  # 48 blocks
}


@needs4
@pytest.mark.parametrize("arch,pinned", sorted(GOLDEN.items()))
@pytest.mark.parametrize("fmt", ["dense", "bcq", "uniform"])
@pytest.mark.parametrize("tp", [1, 2, 4])
def test_golden_census(arch, pinned, fmt, tp):
    cell = build_cell(arch, fmt, tp)
    assert cell.expected_collectives == pinned
    assert expected_collectives(get_config(arch)) == pinned
    assert census.census_cell(cell) == []


@needs4
def test_census_catches_injected_weight_gather():
    cell = build_injected_cell("llama3.2-3b", "bcq", 2)
    violations = census.census_cell(cell)
    big = [v for v in violations if "weight/cache-shaped" in v.message]
    assert big, "injected weight all_gather was not caught"
    # provenance names the offending leaf and the gather's source line
    assert "packed" in big[0].message
    assert "all_gather at" in big[0].message
    # and the count check trips too (one extra collective)
    assert any("collective count" in v.message for v in violations)


@needs4
def test_census_skips_name_unsupported_archs():
    from repro.analysis.staticcheck.harness import build_cells

    cells, skips = build_cells(archs=["olmoe-1b-7b", "xlstm-125m"], fmts=["bcq"], tps=[2])
    assert cells == []
    assert len(skips) == 2
    assert all("tp2" in s for s in skips)


# ---------------------------------------------------------------------------
# dtype-flow
# ---------------------------------------------------------------------------


@needs4
def test_dtypeflow_deploy_clean_ref_dirty():
    from repro.analysis.staticcheck.harness import _build_tp_pieces, _step_fn

    cell = build_cell("llama3.2-3b", "bcq", 2)
    assert dtypeflow.analyze(cell.closed, cell.cell_id, cell.shape_index) == []

    cfg, tpc, structs, cache, tok, pos = _build_tp_pieces("llama3.2-3b", "bcq", 2)
    with ops.impl_mode("ref"):
        closed = jax.make_jaxpr(_step_fn(cfg, tpc))(structs, cache, tok, pos)
    violations = dtypeflow.analyze(closed, "ref", cell.shape_index)
    assert violations, "ref-mode dequantize must be flagged"
    assert "packed" in violations[0].message
    assert "convert_element_type" in violations[0].message


def test_dtypeflow_simple_program():
    # uint8 source flowing to float through plain ops is flagged with source
    def bad(p):
        return p.astype(jnp.float32).sum()

    closed = jax.make_jaxpr(bad)(jax.ShapeDtypeStruct((3, 16, 8), jnp.uint8))
    vs = dtypeflow.analyze(closed, "unit", {(3, 16, 8): "w.packed"})
    assert len(vs) == 1 and "w.packed" in vs[0].message

    # integer-only flow is clean
    def good(p):
        return (p >> 1).sum()

    closed = jax.make_jaxpr(good)(jax.ShapeDtypeStruct((3, 16, 8), jnp.uint8))
    assert dtypeflow.analyze(closed, "unit", {}) == []


def test_dtypeflow_scan_carry_fixpoint():
    # taint entering a scan carry on iteration 2+ still flags the body cast
    def f(p):
        def body(c, _):
            return c + 1, c.astype(jnp.float32)

        _, ys = jax.lax.scan(body, p, None, length=3)
        return ys

    closed = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4,), jnp.uint8))
    assert dtypeflow.analyze(closed, "unit", {}) != []


# ---------------------------------------------------------------------------
# transfers
# ---------------------------------------------------------------------------


@needs4
def test_transfer_pass_clean_and_catches_debug_print():
    cell = build_cell("llama3.2-3b", "bcq", 2)
    assert transfers.transfer_violations(cell) == []

    def noisy(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    bad = type(cell)(
        cell_id="unit", arch="-", fmt="-", tp=1,
        closed=jax.make_jaxpr(noisy)(jnp.ones((2,))),
        expected_collectives=0, shape_index={},
    )
    vs = transfers.transfer_violations(bad)
    assert vs and "host-transfer" in vs[0].message


def test_trace_once_harness():
    n, vs = transfers.trace_once_check(fmts=("dense",))
    assert n == 1 and vs == []


# ---------------------------------------------------------------------------
# vmem: estimators + table validation
# ---------------------------------------------------------------------------


def test_vmem_estimators_registered():
    assert set(introspect.known_impls()) >= {
        "bcq_mm", "lutgemm", "uniform_mm", "dequant_mm", "codebook_mm",
        "ternary_mm",
    }
    for impl in introspect.known_impls():
        small = introspect.vmem_bytes(impl, B=8, block_k=128, block_o=128, q=3, g=128)
        big = introspect.vmem_bytes(impl, B=8, block_k=1024, block_o=512, q=3, g=128)
        assert 0 < small < big
    assert introspect.fits_budget("bcq_mm", B=8, block_k=512, block_o=256, q=3, g=128)
    assert not introspect.fits_budget("bcq_mm", B=8, block_k=8192, block_o=2048, q=8, g=128)


def test_autotune_validate_entry_errors():
    ok = autotune.validate_entry("bcq_mm/cpu-interpret/B8/k768/o256/q3/g96", [768, 64])
    assert ok == (768, 64)
    with pytest.raises(ValueError, match="expected impl/backend"):
        autotune.validate_entry("bcq_mm/cpu/B8/k768", [512, 256])
    with pytest.raises(ValueError, match="not g<int>"):
        autotune.validate_entry("bcq_mm/cpu/B8/k768/o256/q3/gX", [512, 256])
    with pytest.raises(ValueError, match="tiling contract"):
        autotune.validate_entry("bcq_mm/cpu/B8/k768/o256/q3/g96", [500, 256])
    with pytest.raises(ValueError, match="pair of positive ints"):
        autotune.validate_entry("bcq_mm/cpu/B8/k768/o256/q3/g96", [768])
    with pytest.raises(ValueError, match="VMEM|budget"):
        autotune.validate_entry("bcq_mm/tpu/B8/k8192/o4096/q8/g8192", [8192, 2048])
    # interpret backends skip the budget check (no VMEM to blow)
    autotune.validate_entry(
        "bcq_mm/cpu-interpret/B8/k8192/o4096/q8/g8192", [8192, 2048]
    )
    # unknown impls skip the budget check but not divisibility
    autotune.validate_entry("future_mm/tpu/B8/k8192/o4096/q8/g8192", [8192, 2048])


def test_autotune_rejects_corrupt_table(tmp_path, monkeypatch):
    bad = tmp_path / "autotune.json"
    bad.write_text("{not json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(bad))
    autotune.clear_cache()
    with pytest.raises(ValueError, match="not valid JSON"):
        autotune._ensure_persisted_loaded()
    bad.write_text(json.dumps({"bcq_mm/cpu/B8/k768/o256/q3/g96": [500, 256]}))
    autotune.clear_cache()
    with pytest.raises(ValueError, match="tiling contract"):
        autotune._ensure_persisted_loaded()
    autotune.clear_cache()


def test_checked_in_table_validates():
    table = autotune._load_table(autotune._TABLE_PATH)
    assert table  # the defaults ship non-empty
    autotune.validate_table(table, path=autotune._TABLE_PATH)


def test_vmem_pass_runs_clean():
    res = vmem.run(archs=["llama3.2-3b"], tps=(1, 2))
    assert res.ok, [str(v) for v in res.violations]
    assert res.checked > 0


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------


def _hits(source, relpath="infer/x.py", rule=None):
    vs = lint.lint_source(source, relpath)
    if rule:
        vs = [v for v in vs if v.passname == f"lint/{rule}"]
    return vs


def test_lint_no_item():
    assert _hits("y = x.item()\n", rule="no-item")
    # no pragma escape for .item()
    assert _hits("y = x.item()  # staticcheck: host-sync(x)\n", rule="no-item")


def test_lint_host_sync_pragma():
    assert _hits("import numpy as np\ny = np.asarray(x)\n", rule="host-sync")
    assert not _hits(
        "import numpy as np\ny = np.asarray(x)  # staticcheck: host-sync(final fetch)\n",
        rule="host-sync",
    )
    assert _hits("v = float(f(x))\n", rule="host-sync")
    assert not _hits("v = float(x)\n", rule="host-sync")  # Name arg: host scalar
    # jnp.asarray is a device put, not a sync
    assert not _hits("import jax.numpy as jnp\ny = jnp.asarray(x)\n", rule="host-sync")
    # out-of-scope dirs are not linted for host syncs
    assert not _hits("import numpy as np\ny = np.asarray(x)\n", relpath="analysis/x.py",
                     rule="host-sync")


def test_lint_raw_shard_map():
    src = "from jax.experimental.shard_map import shard_map\n"
    assert _hits(src, relpath="infer/x.py", rule="raw-shard-map")
    assert not _hits(src, relpath="parallel/compat.py", rule="raw-shard-map")


def test_lint_bare_jit():
    assert _hits("import jax\nf = jax.jit(g)\n", rule="bare-jit")
    assert not _hits("import jax\nf = jax.jit(g, static_argnames=('n',))\n",
                     rule="bare-jit")
    assert not _hits(
        "import jax\nf = jax.jit(g)  # staticcheck: jit-ok(nothing static)\n",
        rule="bare-jit",
    )


def test_lint_repo_is_clean():
    res = lint.run()
    assert res.ok, "\n".join(str(v) for v in res.violations)
    assert res.checked > 50
