"""Distribution rules: spec trees mirror param/cache trees, every sharded dim
divides its axis, QT spec derivation, for all 10 archs × both meshes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import init_cache, init_params
from repro.parallel import (
    batch_specs,
    cache_specs,
    multi_pod_axes,
    param_specs,
    single_pod_axes,
)
from repro.parallel.sharding import qt_specs_like

AXES = {"single": single_pod_axes(), "multi": multi_pod_axes()}


def _check_divisible(struct_tree, spec_tree, ax, where):
    def visit(leaf, spec):
        assert isinstance(spec, P), f"{where}: spec {spec} for {leaf}"
        assert len(spec) <= len(leaf.shape), f"{where}: rank mismatch {spec} {leaf.shape}"
        for dim, axis in zip(leaf.shape, tuple(spec)):
            if axis is None:
                continue
            size = ax.size(axis if not isinstance(axis, tuple) else tuple(axis))
            assert dim % size == 0, f"{where}: dim {dim} not divisible by {axis}={size}"

    jax.tree.map(visit, struct_tree, spec_tree,
                 is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_param_specs_structure_and_divisibility(arch, mesh_kind):
    cfg = get_config(arch)
    ax = AXES[mesh_kind]
    structs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(cfg, ax)
    assert jax.tree.structure(structs) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    _check_divisible(structs, specs, ax, f"{arch}/{mesh_kind}/params")


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_cache_specs_structure_and_divisibility(arch, mesh_kind):
    cfg = get_config(arch)
    ax = AXES[mesh_kind]
    batch = 128
    structs = jax.eval_shape(lambda: init_cache(cfg, batch, 1024))
    specs = cache_specs(cfg, ax, batch)
    assert jax.tree.structure(structs) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    _check_divisible(structs, specs, ax, f"{arch}/{mesh_kind}/cache")


def test_batch_specs_fall_back_when_indivisible():
    cfg = get_config("llama3.2-3b")
    ax = multi_pod_axes()  # dp = 32
    bs = batch_specs(cfg, ax, 1)  # long_500k batch=1
    assert tuple(bs["tokens"]) == (None, None)
    bs2 = batch_specs(cfg, ax, 256)
    assert tuple(bs2["tokens"])[0] == ("pod", "data")


def test_qt_specs_like():
    from repro.core.qtensor import QuantizedTensor

    ax = single_pod_axes()
    qt = QuantizedTensor(
        packed=jax.ShapeDtypeStruct((4, 384, 8192), jnp.uint8),
        scales=jax.ShapeDtypeStruct((4, 24, 8192), jnp.bfloat16),
        g=128, k=3072, o=8192,
    )
    spec = qt_specs_like(P("data", "model"), qt, ax)
    assert tuple(spec.packed) == (None, "data", "model")
    # scales k-dim 24 not divisible by 16 → replicated on that dim
    assert tuple(spec.scales) == (None, None, "model")
    # stacked (layer) leading dim
    qt2 = QuantizedTensor(
        packed=jax.ShapeDtypeStruct((28, 4, 384, 8192), jnp.uint8),
        scales=jax.ShapeDtypeStruct((28, 4, 24, 8192), jnp.bfloat16),
        g=128, k=3072, o=8192,
    )
    spec2 = qt_specs_like(P(None, "data", "model"), qt2, ax)
    assert tuple(spec2.packed) == (None, None, "data", "model")


def test_mesh_construction_subprocess():
    """The production mesh needs 512 placeholder devices — verify in a child
    process so the test session keeps its single-device view."""
    import subprocess
    import sys

    code = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.mesh import make_production_mesh\n"
        "m1 = make_production_mesh(multi_pod=False)\n"
        "assert m1.devices.shape == (16, 16) and m1.axis_names == ('data', 'model')\n"
        "m2 = make_production_mesh(multi_pod=True)\n"
        "assert m2.devices.shape == (2, 16, 16)\n"
        "assert m2.axis_names == ('pod', 'data', 'model')\n"
        "print('MESH-OK')\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=300
    )
    assert "MESH-OK" in out.stdout, out.stderr[-2000:]
