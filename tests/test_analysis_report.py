"""Units for analysis/roofline.py and analysis/report.py (satellite of the
static-checker PR: these modules feed EXPERIMENTS.md and were untested)."""

import json

import pytest

import importlib

from repro.analysis import report

# the module, not the same-named function the package re-exports
roofline = importlib.import_module("repro.analysis.roofline")


# ---------------------------------------------------------------------------
# roofline model
# ---------------------------------------------------------------------------


def test_hw_constants_are_v5e():
    assert roofline.V5E.peak_flops == 197e12
    assert roofline.V5E.hbm_bw == 819e9
    assert roofline.V5E.ici_bw == 50e9


def test_roofline_terms_and_dominant():
    r = roofline.roofline(197e12, 819e9, 25e9, chips=4)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.bound_s == pytest.approx(1.0)
    assert r.dominant in ("compute", "memory")

    r = roofline.roofline(1e12, 819e9 * 3, 0.0, chips=1)
    assert r.dominant == "memory"
    assert r.bound_s == pytest.approx(3.0)

    r = roofline.roofline(0.0, 0.0, 100e9, chips=1)
    assert r.dominant == "collective"
    assert r.bound_s == pytest.approx(2.0)


def test_roofline_model_flops_ratios():
    # 2 chips each doing 10 TFLOP; model needs 10 TFLOP total → half the HLO
    # FLOPs are overhead (remat/dequant/redundancy)
    r = roofline.roofline(10e12, 0.0, 0.0, chips=2, model_flops=10e12)
    assert r.useful_flops_ratio == pytest.approx(0.5)
    # mfu_bound = model / (chips * peak * bound_s)
    expect = 10e12 / (2 * 197e12 * r.bound_s)
    assert r.mfu_bound == pytest.approx(expect)

    r = roofline.roofline(10e12, 0.0, 0.0, chips=2)
    assert r.useful_flops_ratio is None and r.mfu_bound is None

    d = roofline.roofline(1.0, 2.0, 3.0, chips=1).to_dict()
    assert d["dominant"] == "collective"
    assert set(d) >= {"compute_s", "memory_s", "collective_s", "bound_s",
                      "mfu_bound", "useful_flops_ratio", "chips"}
    json.dumps(d)  # the dict must stay JSON-serialisable (cell files)


def test_model_flops_estimate():
    assert roofline.model_flops_estimate(1000, 10, training=True) == 60000.0
    assert roofline.model_flops_estimate(1000, 10, training=False) == 20000.0


# ---------------------------------------------------------------------------
# report formatting helpers
# ---------------------------------------------------------------------------


def test_fmt_s():
    assert report._fmt_s(2.5) == "2.50s"
    assert report._fmt_s(1.0) == "1.00s"
    assert report._fmt_s(0.0123) == "12.3ms"
    assert report._fmt_s(1e-3) == "1.0ms"
    assert report._fmt_s(42e-6) == "42µs"


def test_fmt_b():
    assert report._fmt_b(2.5e12) == "2.5TB"
    assert report._fmt_b(3.2e9) == "3.2GB"
    assert report._fmt_b(1.5e6) == "1.5MB"
    assert report._fmt_b(2e3) == "2.0KB"
    assert report._fmt_b(512) == "512B"


# ---------------------------------------------------------------------------
# table rendering over synthetic cells
# ---------------------------------------------------------------------------


def _cell(arch="llama3.2-3b", shape="decode_32k", mesh="single", q=0, kind="decode"):
    r = roofline.roofline(
        5e12, 100e9, 10e9, chips=4, model_flops=4e12
    )
    return {
        "arch": arch,
        "shape": shape,
        "mesh": mesh,
        "quant_q": q,
        "chips": 4,
        "compile_s": 12.0,
        "meta": {"kind": kind, "weight_uses": 1},
        "roofline": r.to_dict(),
        "memory_analysis": {"argument_size": 3e9, "temp_size": 1e9},
        "trip_aware": {
            "collectives": {
                name: {"bytes": 1e6, "count": 2}
                for name in ("all-reduce", "all-gather", "reduce-scatter",
                             "all-to-all", "collective-permute")
            }
        },
    }


def test_load_cells(tmp_path):
    for i, cell in enumerate([_cell(), _cell(q=3)]):
        (tmp_path / f"c{i}.json").write_text(json.dumps(cell))
    (tmp_path / "ignore.txt").write_text("not a cell")
    cells = load = report.load_cells(str(tmp_path))
    assert len(cells) == 2
    assert {c["quant_q"] for c in load} == {0, 3}


def test_roofline_table_renders():
    cells = [_cell(), _cell(q=3), _cell(mesh="multi")]
    md = report.roofline_table(cells, "single")
    lines = md.splitlines()
    assert lines[0].startswith("| arch |")
    assert len(lines) == 2 + 2  # header + separator + 2 single-mesh rows
    assert "bf16" in lines[2] and "| 3 |" in lines[3]
    # every row has the same column count as the header
    ncols = lines[0].count("|")
    assert all(l.count("|") == ncols for l in lines[2:])


def test_dryrun_table_renders():
    md = report.dryrun_table([_cell(), _cell(shape="prefill_32k")])
    lines = md.splitlines()
    assert len(lines) == 4
    # prefill sorts before decode (shape order), both show byte columns
    assert "prefill_32k" in lines[2] and "decode_32k" in lines[3]
    assert "1.0MB" in lines[2]


def test_bottleneck_summary():
    md = report.bottleneck_summary([_cell(), _cell(mesh="multi")])
    lines = md.splitlines()
    assert len(lines) == 1  # multi-mesh cells excluded
    assert "llama3.2-3b × decode_32k" in lines[0]
    assert "-bound at" in lines[0]


def test_weight_bytes_per_chip_quantized_smaller():
    dense = report.weight_bytes_per_chip("llama3.2-3b", 0)
    q3 = report.weight_bytes_per_chip("llama3.2-3b", 3)
    assert 0 < q3 < dense
    # 3-bit packed planes + group scales vs bf16 (embeddings stay dense):
    # comfortably under half the bf16 footprint
    assert q3 < dense / 2


def test_kernel_adjusted_memory_differences_dense_sibling():
    dense = _cell(arch="llama3.2-3b", q=0)
    quant = _cell(arch="llama3.2-3b", q=3)
    adj = report.kernel_adjusted_memory([dense, quant])
    key = ("llama3.2-3b", "decode_32k", "single", 3)
    assert set(adj) == {key}
    # adjusted bytes = dense_bytes - w_dense + w_packed < dense_bytes
    assert 0 < adj[key] < dense["roofline"]["bytes_per_chip"] / 819e9
    # no dense sibling → no adjustment
    assert report.kernel_adjusted_memory([quant]) == {}
