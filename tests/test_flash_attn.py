"""Flash-attention Pallas kernel vs the jnp oracle (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention
from repro.models.layers import _sdpa, causal_mask


def _qkv(rng, b, s, h, hkv, dh, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "b,s,h,hkv,dh,bq,bk",
    [
        (2, 512, 4, 4, 64, 256, 256),  # MHA
        (1, 1024, 8, 2, 32, 256, 512),  # GQA group 4, rectangular tiles
        (2, 512, 6, 1, 64, 128, 128),  # MQA
        (1, 512, 2, 2, 128, 512, 256),  # single q tile
    ],
)
def test_flash_matches_oracle(rng, b, s, h, hkv, dh, bq, bk):
    q, k, v = _qkv(rng, b, s, h, hkv, dh)
    ref = _sdpa(q, k, v, causal_mask(s, s))
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_bf16(rng):
    q, k, v = _qkv(rng, 1, 512, 4, 2, 64, dtype=jnp.bfloat16)
    ref = _sdpa(q, k, v, causal_mask(512, 512))
    out = flash_attention(q, k, v, block_q=256, block_k=256, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2
    )


def test_flash_rejects_bad_blocks(rng):
    q, k, v = _qkv(rng, 1, 500, 2, 2, 32)
    with pytest.raises(ValueError):
        flash_attention(q, k, v, block_q=256, block_k=256, interpret=True)
