"""End-to-end system behaviour: train → checkpoint/resume → quantize → serve
(the paper's full workflow), plus the data pipeline and the engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MarkovCorpus, batch_iterator
from repro.infer import Engine
from repro.models import init_params, reduced
from repro.quant import QuantPolicy, quantize_params, quantized_bytes
from repro.train import adamw_init, make_train_step
from repro.train.loop import LoopConfig, train_loop

KEY = jax.random.PRNGKey(0)


def test_corpus_determinism_and_structure():
    c1 = MarkovCorpus(256, seed=3)
    c2 = MarkovCorpus(256, seed=3)
    s1 = c1.sample(4, 32, seed=9)
    s2 = c2.sample(4, 32, seed=9)
    np.testing.assert_array_equal(s1, s2)
    # every transition comes from the successor table
    for b in range(4):
        for t in range(32):
            assert s1[b, t + 1] in c1.successors[s1[b, t]]


def test_loader_host_sharding():
    c = MarkovCorpus(64, seed=0)
    full = next(batch_iterator(c, batch=8, seq_len=16, seed=1))
    p0 = next(batch_iterator(c, batch=8, seq_len=16, seed=1, process_index=0,
                             process_count=2))
    p1 = next(batch_iterator(c, batch=8, seq_len=16, seed=1, process_index=1,
                             process_count=2))
    np.testing.assert_array_equal(
        np.concatenate([p0["tokens"], p1["tokens"]]), full["tokens"]
    )


def test_embedding_loader():
    c = MarkovCorpus(64, seed=0)
    b = next(batch_iterator(c, batch=4, seq_len=8, embed_dim=32))
    assert b["embeddings"].shape == (4, 8, 32)
    assert b["labels"].shape == (4, 8)


@pytest.fixture(scope="module")
def trained():
    cfg = reduced(get_config("llama3.2-3b"), d_model=128, n_layers=2, vocab=512)
    params = init_params(KEY, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=2e-3))
    corpus = MarkovCorpus(cfg.vocab, seed=0)
    it = batch_iterator(corpus, batch=8, seq_len=48)
    for _ in range(25):
        b = next(it)
        params, opt, m = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
    return cfg, params, corpus


def test_full_workflow_train_quantize_serve(trained):
    cfg, params, corpus = trained
    qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=4))
    assert quantized_bytes(qp) < 0.45 * quantized_bytes(params)

    prompt = corpus.sample(2, 8, seed=42)[:, :8].astype(np.int32)
    eng_dense = Engine(cfg, params, max_seq=64)
    eng_quant = Engine(cfg, qp, max_seq=64)
    rd = eng_dense.generate(prompt, 12)
    rq = eng_quant.generate(prompt, 12)
    assert rd.tokens.shape == (2, 20)
    assert rq.tokens.shape == (2, 20)
    # greedy decode is deterministic
    rd2 = eng_dense.generate(prompt, 12)
    np.testing.assert_array_equal(rd.tokens, rd2.tokens)


def test_engine_sampling(trained):
    cfg, params, corpus = trained
    eng = Engine(cfg, params, max_seq=64)
    prompt = corpus.sample(1, 8, seed=1)[:, :8].astype(np.int32)
    r1 = eng.generate(prompt, 8, temperature=1.0, seed=0)
    r2 = eng.generate(prompt, 8, temperature=1.0, seed=1)
    assert r1.tokens.shape == r2.tokens.shape == (1, 16)


def test_engine_embedding_model_requires_embed_fn():
    cfg = reduced(get_config("musicgen-medium"), d_model=64, n_layers=2)
    params = init_params(KEY, cfg)
    eng = Engine(cfg, params, max_seq=32)
    emb = np.random.default_rng(0).standard_normal((1, 8, 64)).astype(np.float32)
    with pytest.raises(ValueError):
        eng.generate(emb, 4)
    table = np.random.default_rng(1).standard_normal((cfg.vocab, 64)).astype(np.float32)
    eng2 = Engine(cfg, params, max_seq=32,
                  embed_fn=lambda toks: table[toks[:, 0]][:, None])
    r = eng2.generate(emb, 4)
    assert r.steps == 4


def test_train_loop_with_real_model(tmp_path):
    cfg = reduced(get_config("llama3.2-3b"), d_model=64, n_layers=2, vocab=256)
    params = init_params(KEY, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    corpus = MarkovCorpus(cfg.vocab, seed=0)
    batches = (
        {k: jnp.asarray(v) for k, v in b.items()}
        for b in batch_iterator(corpus, batch=4, seq_len=32)
    )
    lcfg = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=3,
                      log_every=100)
    params, opt, hist = train_loop(step, params, opt, batches, lcfg,
                                   log=lambda s: None)
    from repro.train import checkpoint as C
    assert C.latest_step(str(tmp_path)) == 6
