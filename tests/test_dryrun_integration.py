"""Integration tests for the multi-pod dry-run machinery (subprocess: needs
512 placeholder devices, which must not leak into this test session)."""

import json
import subprocess
import sys

import pytest

_CELL_CODE = """
import json
from repro.launch.dryrun import run_cell
res = run_cell("{arch}", "{shape}", "{mesh}", {q}, verbose=False)
print("CELL-JSON:" + json.dumps({{
    "dominant": res["roofline"]["dominant"],
    "flops": res["roofline"]["flops_per_chip"],
    "bytes": res["roofline"]["bytes_per_chip"],
    "chips": res["chips"],
    "unparsed": res["trip_aware"]["unparsed_loops"],
}}))
"""


def _run_cell(arch, shape, mesh, q):
    code = _CELL_CODE.format(arch=arch, shape=shape, mesh=mesh, q=q)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("CELL-JSON:")][0]
    return json.loads(line[len("CELL-JSON:"):])


@pytest.mark.slow
def test_dryrun_decode_cell_single_pod():
    res = _run_cell("xlstm-125m", "decode_32k", "single", 4)
    assert res["chips"] == 256
    assert res["flops"] > 0 and res["bytes"] > 0
    assert res["unparsed"] == 0  # every while loop's trip count parsed


@pytest.mark.slow
def test_dryrun_train_cell_multi_pod():
    res = _run_cell("xlstm-125m", "train_4k", "multi", 0)
    assert res["chips"] == 512
    assert res["flops"] > 0


@pytest.mark.slow
def test_dryrun_long_context_skip():
    code = (
        "from repro.launch.dryrun import input_specs, SkipCell\n"
        "try:\n"
        "    input_specs('llama3.2-3b', 'long_500k', 4)\n"
        "    print('NO-SKIP')\n"
        "except SkipCell:\n"
        "    print('SKIPPED-OK')\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=300
    )
    assert "SKIPPED-OK" in out.stdout, out.stderr[-2000:]
