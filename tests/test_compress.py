"""Gradient compression with error feedback."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel import shard_map
from repro.train.compress import (
    allreduce_mean_compressed,
    compress_int8,
    decompress_int8,
)


def test_int8_roundtrip_bounded_error():
    g = jnp.asarray(np.random.default_rng(0).standard_normal((64,)), jnp.float32)
    q, scale, res = compress_int8(g, None)
    rec = decompress_int8(q, scale)
    assert float(jnp.max(jnp.abs(rec - g))) <= float(scale) * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(res), np.asarray(g - rec), rtol=1e-6)


def test_error_feedback_compensates():
    """With error feedback, the RUNNING SUM of decompressed grads tracks the
    running sum of true grads (bias does not accumulate)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal((32,)) * 0.01, jnp.float32)
    res = None
    total_sent = jnp.zeros_like(g_true)
    for step in range(50):
        q, scale, res = compress_int8(g_true, res)
        total_sent = total_sent + decompress_int8(q, scale)
    drift = float(jnp.max(jnp.abs(total_sent - 50 * g_true)))
    assert drift <= float(jnp.max(jnp.abs(g_true))) + 1e-5, drift


def test_allreduce_mean_compressed_modes():
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jnp.asarray(np.random.default_rng(2).standard_normal((8,)), jnp.float32)}

    for mode in ("none", "bf16", "int8"):
        def fn(g):
            out, _ = allreduce_mean_compressed(g, None, axis_names=("data",), mode=mode)
            return out

        res = shard_map(
            fn, mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False
        )(grads)
        tol = {"none": 1e-7, "bf16": 1e-2, "int8": 2e-2}[mode]
        np.testing.assert_allclose(
            np.asarray(res["w"]), np.asarray(grads["w"]), rtol=tol, atol=tol
        )
