"""Trip-count-aware HLO cost model — the roofline's measurement instrument."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import collective_bytes, total_collective_bytes
from repro.analysis.hlo_cost import analyze, normalize_cost_analysis, parse_module


def _compile(fn, *structs, **jit_kwargs):
    return jax.jit(fn, **jit_kwargs).lower(*structs).compile()


def test_scan_flops_scaled_by_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        c, _ = jax.lax.scan(body, x, ws)
        return c

    xs = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
    c = _compile(f, xs, ws)
    cost = analyze(c.as_text())
    assert cost.flops == pytest.approx(12 * 2 * 256**3, rel=1e-6)
    assert cost.unparsed_loops == 0
    # the builtin undercounts (body counted once) — our reason to exist
    assert normalize_cost_analysis(c.cost_analysis())["flops"] < cost.flops / 4


def test_nested_scan():
    def g(x, ws):
        def outer(c, wgrp):
            def inner(cc, w):
                return cc @ w, ()
            c2, _ = jax.lax.scan(inner, c, wgrp)
            return c2, ()
        c, _ = jax.lax.scan(outer, x, ws)
        return c

    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 128, 128), jnp.float32)
    cost = analyze(_compile(g, xs, ws).as_text())
    assert cost.flops == pytest.approx(15 * 2 * 128**3, rel=1e-6)


def test_plain_dot_flops():
    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    cost = analyze(_compile(lambda a, b: a @ b, xs, ws).as_text())
    assert cost.flops == pytest.approx(2 * 64 * 128 * 32, rel=1e-6)


def test_dus_charges_update_not_buffer():
    cs = jax.ShapeDtypeStruct((8, 4096, 256), jnp.bfloat16)
    ks = jax.ShapeDtypeStruct((8, 1, 256), jnp.bfloat16)
    c = _compile(
        lambda cache, kv: jax.lax.dynamic_update_slice(cache, kv, (0, 77, 0)),
        cs, ks, donate_argnums=0,
    )
    cost = analyze(c.as_text())
    buffer_bytes = 8 * 4096 * 256 * 2
    assert cost.bytes < buffer_bytes / 10, cost.bytes


def test_full_read_still_charged():
    cs = jax.ShapeDtypeStruct((8, 4096, 256), jnp.float32)
    qs = jax.ShapeDtypeStruct((8, 256), jnp.float32)
    c = _compile(lambda cache, q: jnp.einsum("bsd,bd->bs", cache, q), cs, qs)
    cost = analyze(c.as_text())
    assert cost.bytes >= 8 * 4096 * 256 * 4  # the cache read is real


def test_collective_parsing_from_hlo_text():
    hlo = """
ENTRY %main (p: f32[16,64]) -> f32[16,64] {
  %p = f32[16,64]{1,0} parameter(0)
  %ar = f32[16,64]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = f32[64,64]{1,0} all-gather(%ar), replica_groups=[16,4], dimensions={0}
  ROOT %cp = f32[16,64]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    stats = collective_bytes(hlo)
    assert stats["all-reduce"]["count"] == 1
    assert stats["all-reduce"]["bytes"] == 16 * 64 * 4
    # ring factor 2(n-1)/n with n=4
    assert stats["all-reduce"]["wire_bytes"] == pytest.approx(16 * 64 * 4 * 1.5)
    assert stats["all-gather"]["bytes"] == 64 * 64 * 4
    assert stats["collective-permute"]["wire_bytes"] == 16 * 64 * 4
    assert total_collective_bytes(stats) == 16 * 64 * 4 + 64 * 64 * 4 + 16 * 64 * 4


def test_parse_module_handles_tuple_shapes_with_comments():
    hlo = """
%body (t: (s32[], f32[8])) -> (s32[], f32[8]) {
  %t = (s32[], /*index=1*/f32[8]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  ROOT %out = (s32[], f32[8]{0}) tuple(%i, %i)
}
"""
    comps = parse_module(hlo)
    assert "body" in comps
    assert comps["body"].instrs[0].op == "parameter"
