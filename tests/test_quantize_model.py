"""Model-level quantization: policies, tree surgery, struct/real agreement."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.qtensor import QuantizedTensor
from repro.models import forward, init_params, reduced
from repro.quant import QuantPolicy, quantize_params, quantized_bytes, quantized_structs

KEY = jax.random.PRNGKey(0)


def _qt_leaves(tree):
    return [
        l for l in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)
    ]


def test_quantizes_expected_leaves():
    cfg = reduced(get_config("llama3.2-3b"), d_model=128, d_ff=256, vocab=512,
                  n_kv_heads=4)
    params = init_params(KEY, cfg)
    qp = quantize_params(params, QuantPolicy(q=2, g=64, method="greedy"))
    qts = _qt_leaves(qp)
    # per layer: wq,wk,wv,wo,w_gate,w_up,w_down (stacked) = 7 + lm_head
    assert len(qts) == 8
    # embed and norms stay dense
    assert not isinstance(qp["embed"], QuantizedTensor)
    assert qp["final_norm"].dtype == params["final_norm"].dtype
    assert quantized_bytes(qp) < quantized_bytes(params) / 2


def test_mixed_precision_policy_routing():
    pol = QuantPolicy(q=4, g=128, attn=(2, 64), ffn=(5, 256), lm_head=(3, 128))
    assert pol.resolve(("stages", "0", "b0", "attn", "wq")) == (2, 64)
    assert pol.resolve(("stages", "0", "b0", "mlp", "w_up")) == (5, 256)
    assert pol.resolve(("lm_head",)) == (3, 128)
    assert pol.resolve(("stages", "0", "b0", "ln1")) is None
    assert QuantPolicy(skip_lm_head=True).resolve(("lm_head",)) is None


def test_mixed_precision_applies_different_bits():
    cfg = reduced(get_config("llama3.2-3b"), d_model=128, d_ff=256, vocab=512)
    params = init_params(KEY, cfg)
    qp = quantize_params(
        params, QuantPolicy(attn=(2, 64), ffn=(4, 128), skip_lm_head=True,
                            method="greedy")
    )
    attn_qt = qp["stages"][0]["b0"]["attn"]["wq"]
    ffn_qt = qp["stages"][0]["b0"]["mlp"]["w_up"]
    assert attn_qt.q == 2 and attn_qt.g == 64
    assert ffn_qt.q == 4 and ffn_qt.g == 128
    assert not isinstance(qp["lm_head"], QuantizedTensor)


def test_structs_match_real_quantization():
    cfg = reduced(get_config("olmoe-1b-7b"), d_model=128, moe_d_ff=128, vocab=512)
    params = init_params(KEY, cfg)
    pol = QuantPolicy(q=3, g=64, method="greedy")
    real = quantize_params(params, pol)
    structs = quantized_structs(jax.eval_shape(lambda: params), pol)

    real_shapes = jax.tree.map(lambda x: (x.shape, str(x.dtype)), real)
    struct_shapes = jax.tree.map(lambda x: (x.shape, str(x.dtype)), structs)
    assert jax.tree.structure(real_shapes) == jax.tree.structure(struct_shapes)
    for a, b in zip(jax.tree.leaves(real_shapes), jax.tree.leaves(struct_shapes)):
        assert a == b


def test_quantized_forward_close_to_dense():
    cfg = reduced(get_config("llama3.2-3b"), d_model=256, d_ff=512, vocab=512,
                  n_layers=2)
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    dense, _, _ = forward(cfg, params, tokens=toks)
    qp = quantize_params(params, QuantPolicy(q=4, g=64, iters=4))
    quant, _, _ = forward(cfg, qp, tokens=toks)
    # random-init logits are near-uniform, so argmax agreement is a weak
    # signal — require it above chance and the logit error bounded
    agree = float(
        (jnp.argmax(dense, -1) == jnp.argmax(quant, -1)).mean()
    )
    assert agree > 0.3, agree
    rel = float(jnp.linalg.norm(quant - dense) / jnp.linalg.norm(dense))
    assert rel < 0.5, rel


def test_higher_q_is_closer():
    cfg = reduced(get_config("llama3.2-3b"), d_model=256, d_ff=512, vocab=512,
                  n_layers=2)
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    dense, _, _ = forward(cfg, params, tokens=toks)
    errs = []
    for q in (1, 2, 4):
        qp = quantize_params(params, QuantPolicy(q=q, g=64, method="greedy"))
        out, _, _ = forward(cfg, qp, tokens=toks)
        errs.append(float(jnp.linalg.norm(out - dense)))
    assert errs[0] > errs[1] > errs[2], errs
