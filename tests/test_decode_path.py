"""Decode fast path: scanned engine vs step loop, fused projection kernels,
and the (block_k, block_o) autotuner (ISSUE 1 tentpole coverage)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import fuse_tensors, quantize_tensor
from repro.data import MarkovCorpus
from repro.infer import Engine
from repro.kernels import autotune, bcq_mm_fused, quantized_matmul, quantized_matmul_fused
from repro.models import forward, fuse_decode_projections, init_params, reduced
from repro.quant import QuantPolicy, quantize_params

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# scanned decode == step loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["llama3.2-3b", "recurrentgemma-9b", "olmoe-1b-7b"]
)
def test_scan_decode_matches_step_loop_greedy(arch):
    """One lax.scan dispatch must reproduce the per-token loop bit-for-bit."""
    cfg = reduced(get_config(arch))
    params = init_params(KEY, cfg)
    prompts = MarkovCorpus(cfg.vocab, seed=3).sample(2, 8, seed=7).astype(np.int32)[:, :8]
    eng = Engine(cfg, params, max_seq=40)
    r_scan = eng.generate(prompts, 10, scan=True)
    r_step = eng.generate(prompts, 10, scan=False)
    np.testing.assert_array_equal(r_scan.tokens, r_step.tokens)
    assert r_scan.tokens.shape == (2, 18)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "xlstm-125m"])
def test_scan_decode_matches_step_loop_sampled(arch):
    """Seeded categorical sampling: identical key-split order in both paths."""
    cfg = reduced(get_config(arch))
    params = init_params(KEY, cfg)
    prompts = MarkovCorpus(cfg.vocab, seed=1).sample(2, 8, seed=5).astype(np.int32)[:, :8]
    eng = Engine(cfg, params, max_seq=40)
    r_scan = eng.generate(prompts, 12, temperature=1.0, seed=11, scan=True)
    r_step = eng.generate(prompts, 12, temperature=1.0, seed=11, scan=False)
    np.testing.assert_array_equal(r_scan.tokens, r_step.tokens)
    # a different seed must actually change something (sampling is live)
    r_other = eng.generate(prompts, 12, temperature=1.0, seed=12, scan=True)
    assert not np.array_equal(r_scan.tokens, r_other.tokens)


def test_scan_decode_quantized_params():
    cfg = reduced(get_config("llama3.2-3b"))
    params = quantize_params(
        init_params(KEY, cfg), QuantPolicy(q=3, g=64, iters=2)
    )
    prompts = MarkovCorpus(cfg.vocab, seed=2).sample(2, 8, seed=9).astype(np.int32)[:, :8]
    eng = Engine(cfg, params, max_seq=40)
    r_scan = eng.generate(prompts, 8, scan=True)
    r_step = eng.generate(prompts, 8, scan=False)
    np.testing.assert_array_equal(r_scan.tokens, r_step.tokens)


def test_embedding_model_falls_back_to_step_loop():
    """scan=True must not break modality-stub models (host-side embed_fn)."""
    cfg = reduced(get_config("musicgen-medium"), d_model=64, n_layers=2)
    params = init_params(KEY, cfg)
    table = np.random.default_rng(1).standard_normal((cfg.vocab, 64)).astype(np.float32)
    eng = Engine(cfg, params, max_seq=32,
                 embed_fn=lambda toks: table[toks[:, 0]][:, None])
    emb = np.random.default_rng(0).standard_normal((1, 8, 64)).astype(np.float32)
    r = eng.generate(emb, 4, scan=True)
    assert r.steps == 4 and r.tokens.shape == (1, 4)


# ---------------------------------------------------------------------------
# fused multi-projection kernel
# ---------------------------------------------------------------------------


def _fused_case(rng, k, out_dims, q, g):
    ws = [jnp.asarray(rng.standard_normal((k, o)), jnp.float32) for o in out_dims]
    qts = [quantize_tensor(w, q, g, iters=1, scale_dtype=jnp.float32) for w in ws]
    x = jnp.asarray(rng.standard_normal((3, k)), jnp.float32)
    return x, qts, fuse_tensors(qts)


@pytest.mark.parametrize("impl", ["bcq_mm", "lutgemm"])
def test_fused_matches_per_projection(rng, impl):
    """One fused kernel pass == N separate quantized_matmul calls."""
    x, qts, fused = _fused_case(rng, 512, (256, 128, 128), q=3, g=64)
    outs = quantized_matmul_fused(
        x, fused, tuple(t.o for t in qts), impl=impl, interpret=True
    )
    for out, qt in zip(outs, qts):
        ref = quantized_matmul(x, qt, impl="ref")
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4
        )


def test_bcq_mm_fused_kernel_direct(rng):
    """The raw fused kernel splits the fused output at projection offsets."""
    x, qts, fused = _fused_case(rng, 512, (128, 128), q=2, g=128)
    outs = bcq_mm_fused(
        x, fused.packed, fused.scales, g=fused.g, out_dims=(128, 128),
        block_k=256, block_o=128, interpret=True,
    )
    assert [o.shape for o in outs] == [(3, 128), (3, 128)]
    whole = quantized_matmul(x, fused, impl="ref")
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, -1)), np.asarray(whole),
        rtol=2e-4, atol=2e-4,
    )


def test_fuse_tensors_validation(rng):
    a = quantize_tensor(jnp.asarray(rng.standard_normal((128, 64)), jnp.float32), 2, 64)
    b = quantize_tensor(jnp.asarray(rng.standard_normal((128, 64)), jnp.float32), 3, 64)
    c = quantize_tensor(jnp.asarray(rng.standard_normal((256, 64)), jnp.float32), 2, 64)
    with pytest.raises(ValueError):
        fuse_tensors([a, b])  # q mismatch
    with pytest.raises(ValueError):
        fuse_tensors([a, c])  # k mismatch
    with pytest.raises(ValueError):
        quantized_matmul_fused(
            jnp.zeros((1, 128)), a, (32, 16), impl="ref"
        )  # out_dims don't sum to o


def test_fuse_decode_projections_preserves_forward():
    """Fused params tree computes identical logits (dense + quantized)."""
    cfg = reduced(get_config("llama3.2-3b"))
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    for tree in (params, quantize_params(params, QuantPolicy(q=2, g=64, iters=1,
                                                             method="greedy"))):
        base, _, _ = forward(cfg, tree, tokens=toks)
        fused_tree = fuse_decode_projections(cfg, tree)
        attn0 = fused_tree["stages"][0]["b0"]["attn"]
        assert "wqkv" in attn0 and "wq" not in attn0
        assert "w_gate_up" in fused_tree["stages"][0]["b0"]["mlp"]
        out, _, _ = forward(cfg, fused_tree, tokens=toks)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_fuse_skips_cross_attention_kv():
    """VLM cross blocks must keep wk/wv (they project the image memory)."""
    cfg = reduced(get_config("llama-3.2-vision-90b"))
    params = fuse_decode_projections(cfg, init_params(KEY, cfg))
    pattern = cfg.stages[0][0]
    cross_bi = pattern.index("cross")
    cross_attn = params["stages"][0][f"b{cross_bi}"]["attn"]
    assert "wqkv" not in cross_attn and "wk" in cross_attn
    self_attn = params["stages"][0]["b0"]["attn"]
    assert "wqkv" in self_attn


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


@pytest.fixture
def tuner(tmp_path, monkeypatch):
    # isolate both persistence layers: user cache AND the checked-in defaults;
    # re-enable measurement (conftest disables it suite-wide) regardless of
    # the ambient REPRO_AUTOTUNE so the opt-out env var can't redden the suite
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setattr(autotune, "_TABLE_PATH", str(tmp_path / "defaults.json"))
    autotune.clear_cache()
    yield autotune
    autotune.clear_cache()


def test_autotune_cache_roundtrip(tuner, tmp_path):
    """A measured winner persists to JSON and reloads in a fresh process state."""
    kw = dict(B=8, k=512, o=256, q=2, g=64, impl="bcq_mm", interpret=True)
    blocks = tuner.get_blocks(**kw)
    assert 512 % blocks[0] == 0 and 256 % blocks[1] == 0
    path = tmp_path / "autotune.json"
    assert path.exists()
    table = json.loads(path.read_text())
    key = tuner.make_key(8, 512, 256, 2, 64, "bcq_mm", tuner.backend_tag(True))
    assert tuple(table[key]) == blocks
    # fresh in-process state: served from the persisted table, no re-measure
    tuner.clear_cache()
    assert tuner.get_blocks(**kw, allow_measure=False) == blocks


def test_autotune_opt_out_uses_heuristic(tuner, monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    blocks = tuner.get_blocks(B=8, k=1024, o=512, q=2, g=128, impl="bcq_mm",
                              interpret=True)
    assert blocks == tuner.heuristic_blocks(1024, 512, 128)
    assert not (tmp_path / "autotune.json").exists()  # nothing measured/persisted


def test_autotune_unknown_shape_falls_back_safely(tuner):
    """No table entry + measurement disabled → legacy heuristic, never a raise."""
    bk, bo = tuner.get_blocks(B=8, k=768, o=640, q=2, g=96, impl="lutgemm",
                              interpret=True, allow_measure=False)
    assert bk and bo and 768 % bk == 0 and 640 % bo == 0
    assert bk % 96 == 0 or 96 % bk == 0  # g-compatible (irregular g=96 path)


def test_autotune_candidates_respect_group_size():
    bks, bos = autotune.candidate_blocks(768, 512, 96)
    assert all(c % 96 == 0 or 96 % c == 0 for c in bks)
    assert all(768 % c == 0 for c in bks)
    assert all(512 % c == 0 for c in bos)


def test_quantized_matmul_uses_autotuned_blocks(tuner, rng):
    """End-to-end: wrapper dispatch through the tuner still matches the oracle."""
    w = jnp.asarray(rng.standard_normal((768, 200)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((3, 768)), jnp.float32)
    qt = quantize_tensor(w, 3, 96, iters=1, scale_dtype=jnp.float32)
    y = quantized_matmul(x, qt, impl="bcq_mm", interpret=True)
    y_ref = quantized_matmul(x, qt, impl="ref")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
