# Multi-device host platform for the tensor-parallel suite (tests/test_tp_serve
# .py): the device count is fixed at backend init, so the flag must be set
# before ANY jax import — conftest is imported before every test module, which
# makes this the one reliable place. Single-device semantics are unchanged for
# the rest of the suite (unsharded computations stay on device 0). _hostdev is
# jax-free, so this import cannot initialise the backend early.
from repro.launch._hostdev import force_host_devices

force_host_devices(4)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_autotune(tmp_path, monkeypatch):
    """Keep the suite deterministic and off the user's real autotune cache:
    no live timing sweeps (REPRO_AUTOTUNE=0 → table/heuristic blocks), and any
    persistence goes to a per-test tmp file. Tests that exercise measurement
    re-enable it explicitly (see test_decode_path.tuner)."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune_cache.json"))


def pytest_collection_modifyitems(config, items):
    """Honour the `needs_multidevice` marker: TP tests need the forced
    4-device host platform (or real hardware). If a stray environment pinned
    the device count below 4 (e.g. an outer XLA_FLAGS), skip instead of
    failing on mesh construction."""
    import jax

    if len(jax.devices()) >= 4:
        return
    skip = pytest.mark.skip(
        reason="needs >= 4 XLA devices "
        "(XLA_FLAGS=--xla_force_host_platform_device_count=4)"
    )
    for item in items:
        if "needs_multidevice" in item.keywords:
            item.add_marker(skip)
