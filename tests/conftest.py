import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_autotune(tmp_path, monkeypatch):
    """Keep the suite deterministic and off the user's real autotune cache:
    no live timing sweeps (REPRO_AUTOTUNE=0 → table/heuristic blocks), and any
    persistence goes to a per-test tmp file. Tests that exercise measurement
    re-enable it explicitly (see test_decode_path.tuner)."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune_cache.json"))
