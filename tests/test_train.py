"""Training runtime: optimizer math, accumulation equivalence, learning."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import MarkovCorpus, batch_iterator
from repro.models import init_params, reduced
from repro.train import adamw_init, adamw_update, cosine_lr, cross_entropy, make_train_step

KEY = jax.random.PRNGKey(0)


def test_adamw_matches_reference():
    """One AdamW step vs a literal numpy transcription."""
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]], jnp.float32)}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.1
    newp, newst, gnorm = adamw_update(
        g, st, p, lr=jnp.float32(lr), b1=b1, b2=b2, eps=eps, weight_decay=wd,
        grad_clip=0.0,
    )
    gn = np.asarray(g["w"], np.float64)
    m = (1 - b1) * gn
    v = (1 - b2) * gn * gn
    mh = m / (1 - b1)
    vh = v / (1 - b2)
    pn = np.asarray(p["w"], np.float64)
    exp = pn - lr * (mh / (np.sqrt(vh) + eps) + wd * pn)
    np.testing.assert_allclose(np.asarray(newp["w"]), exp, rtol=1e-5)
    assert int(newst.step) == 1
    np.testing.assert_allclose(float(gnorm), np.linalg.norm(gn), rtol=1e-5)


def test_grad_clip():
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0, jnp.float32)}
    st = adamw_init(p)
    _, _, gnorm = adamw_update(g, st, p, lr=jnp.float32(0.0), grad_clip=1.0)
    assert float(gnorm) == 200.0  # reported pre-clip norm


def test_cosine_lr():
    assert float(cosine_lr(jnp.int32(0), peak=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_lr(jnp.int32(10), peak=1.0, warmup=10, total=100)) == 1.0
    assert float(cosine_lr(jnp.int32(100), peak=1.0, warmup=10, total=100)) < 1e-6


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8), jnp.float32)
    labels = jnp.asarray([[1, 2, -1, -1]], jnp.int32)
    # uniform logits → NLL = log(8) per unmasked token
    np.testing.assert_allclose(
        float(cross_entropy(logits, labels)), np.log(8), rtol=1e-6
    )


def test_grad_accumulation_equivalence():
    """accum_steps=4 must give the same update as one full batch (token counts
    equal per microbatch, loss is per-token mean)."""
    cfg = reduced(get_config("llama3.2-3b"), d_model=32, n_layers=2, vocab=64)
    params = init_params(KEY, cfg)
    opt = adamw_init(params)
    toks = jax.random.randint(KEY, (8, 16), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": labels}

    p1, _, m1 = jax.jit(make_train_step(cfg, lr=1e-2, accum_steps=1))(params, opt, batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, lr=1e-2, accum_steps=4))(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    # Accumulated and full-batch gradients differ only by fp32 summation order
    # (4 microbatch partial sums vs one fused reduction). AdamW then divides by
    # sqrt(v)+eps, which amplifies ulp-level grad differences on near-zero
    # second moments — observed worst case across seeds is ~4e-5 abs / 6e-4 rel
    # on <0.1% of elements. Bound the *post-update* params at one order above
    # that; exact equality is not the invariant, reordering-stable fp32 is.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)


def test_training_learns():
    cfg = reduced(get_config("llama3.2-3b"), d_model=64, n_layers=2, vocab=256)
    params = init_params(KEY, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=2e-3))
    corpus = MarkovCorpus(cfg.vocab, seed=0)
    it = batch_iterator(corpus, batch=8, seq_len=48)
    losses = []
    for _ in range(30):
        b = next(it)
        params, opt, m = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
